"""Distributed SM-forest: the paper's index sharded across a device mesh.

Design (DESIGN.md §2): objects are partitioned over the mesh's 'model' axis,
one independent SM-tree shard per device (a *forest*).  Under ``shard_map``:

  * ``forest_knn`` — queries are replicated to every shard (the sharded-in
    queries are all-gathered), each shard runs the jitted local kNN over its
    subtree, and the global top-k is a k-way merge: all_gather the per-shard
    candidate sets and ``lax.top_k`` them.  One collective round-trip per
    query batch — the classic scatter-gather search fan-out.
  * ``forest_delete`` / ``forest_insert_fast`` — updates broadcast; each
    shard applies the ones that belong to it (exact-match id test for
    delete, routing rule for insert).  The SM-tree's O(h) Delete — the
    paper's contribution — is what makes *online eviction* of a live
    distributed datastore possible without a stop-the-world rebuild.

The same code drives 8 host devices in tests and the production mesh's
'model' axis in serving (kNN-LM datastore, serve/knnlm.py).
"""
from __future__ import annotations

import dataclasses
import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import smtree
from repro.core.smtree import TreeArrays, bulk_build
from repro.dist.sharding import shard_map  # version-portable wrapper

_DATA_FIELDS = ("vecs", "radius", "pdist", "child", "oid", "valid", "count",
                "is_leaf", "alive", "parent", "pslot", "root", "n_nodes",
                "height", "free_list", "free_head")


def stack_trees(trees: list[TreeArrays]) -> TreeArrays:
    """Stack per-shard SM-trees into one forest TreeArrays with a leading
    [n_shards] axis, padding every node table to the largest shard's size.
    Padded rows are dead (``alive`` False) so no traversal touches them.
    They are also *not* in the padded shard's free ring (``free_list`` keeps
    only its pre-padding ids), so the device allocator stays conservative:
    a shard never allocates into rows that ``unstack_forest`` would slice
    away again."""
    max_nodes = max(t.max_nodes for t in trees)

    def pad_leaf(leaf, axis0_pad):
        pad = [(0, axis0_pad)] + [(0, 0)] * (leaf.ndim - 1)
        return jnp.pad(leaf, pad)

    stacked = {}
    for name in _DATA_FIELDS:
        leaves = []
        for t in trees:
            leaf = getattr(t, name)
            if leaf.ndim and leaf.shape[:1] == (t.max_nodes,):
                leaf = pad_leaf(leaf, max_nodes - t.max_nodes)
            leaves.append(leaf)
        stacked[name] = jnp.stack(leaves)
    proto = trees[0]
    return TreeArrays(capacity=proto.capacity, dim=proto.dim,
                      metric=proto.metric, max_nodes=max_nodes,
                      min_fill=proto.min_fill, **stacked)


def unstack_forest(forest: TreeArrays,
                   max_nodes: list[int] | None = None) -> list[TreeArrays]:
    """Split a stacked forest back into per-shard trees (inverse of
    ``stack_trees``).  ``max_nodes`` optionally re-slices each shard's node
    table to its original, pre-padding size (stream snapshot restore needs
    this so replay reproduces the straight-line run bitwise)."""
    n_shards = forest.root.shape[0]
    out = []
    for s in range(n_shards):
        n = forest.max_nodes if max_nodes is None else int(max_nodes[s])
        fields = {}
        for name in _DATA_FIELDS:
            leaf = getattr(forest, name)[s]
            if leaf.ndim and leaf.shape[:1] == (forest.max_nodes,):
                leaf = leaf[:n]
            fields[name] = leaf
        out.append(TreeArrays(capacity=forest.capacity, dim=forest.dim,
                              metric=forest.metric, max_nodes=n,
                              min_fill=forest.min_fill, **fields))
    return out


def build_forest_trees(X: np.ndarray, n_shards: int, *, capacity: int = 32,
                       metric: str = "d_inf",
                       seed: int = 0) -> list[TreeArrays]:
    """Partition X round-robin over ``n_shards`` (object i -> shard i mod S,
    ids global) and bulk-build one SM-tree per shard.  Mesh-free: this is
    the host-side forest the stream subsystem mutates shard-at-a-time."""
    trees = []
    for s in range(n_shards):
        idx = np.arange(s, X.shape[0], n_shards)
        trees.append(bulk_build(X[idx], ids=idx, capacity=capacity,
                                metric=metric, seed=seed + s))
    return trees


def build_forest(X: np.ndarray, mesh: Mesh, *, axis: str = "model",
                 capacity: int = 32, metric: str = "d_inf",
                 seed: int = 0) -> TreeArrays:
    """Partition X round-robin over the mesh axis and bulk-build one SM-tree
    per shard.  Returns a TreeArrays whose leaves carry a leading [n_shards]
    axis sharded over ``axis`` (ids are global)."""
    forest = stack_trees(build_forest_trees(
        X, mesh.shape[axis], capacity=capacity, metric=metric, seed=seed))
    spec = jax.tree.map(lambda _: P(axis), forest)
    return jax.device_put(forest, NamedSharding(mesh, P(axis))), spec


def place_forest(trees_or_forest, mesh: Mesh, *,
                 axis: str = "model") -> TreeArrays:
    """Make a host-side forest mesh-resident: shards sharded one-per-device
    over ``axis`` so ``forest_knn`` serves straight from HBM.

    This is the read-replica fan-out step (stream/replica.py): a follower
    restores + tails the WAL entirely on host, then each published epoch's
    shard list is placed here and queried through the same collectives as
    the leader — identical bytes, different devices.  Accepts either a
    ``list[TreeArrays]`` (stacked and padded first) or an
    already-stacked forest."""
    forest = (trees_or_forest if isinstance(trees_or_forest, TreeArrays)
              else stack_trees(list(trees_or_forest)))
    n_shards = forest.root.shape[0]
    if mesh.shape[axis] != n_shards:
        raise ValueError(
            f"mesh axis {axis!r} has {mesh.shape[axis]} devices for "
            f"{n_shards} shards (need exactly one per shard)")
    return jax.device_put(forest, NamedSharding(mesh, P(axis)))


def promote_follower(replica, mesh: Mesh, *, axis: str = "model",
                     expect: tuple[int, str] | None = None,
                     timeout: float = 30.0):
    """Bring a replayed follower into the serving mesh: the failover
    endgame after ``stream.lease.promote`` hands it the WAL.

    ``replica`` is a ``stream.replica.Replica`` (or ``ShippedReplica``)
    whose follower is a ``StreamingForest``; ``expect`` is the leader's
    last ``(seq, digest)`` digest exchange when known — the follower must
    catch up through it and match bitwise before its shards are allowed
    to serve (``DigestMismatch`` otherwise; a diverged replica joining
    the mesh would silently answer queries from a different index).
    Returns ``(placed_forest, epoch)``: the pinned epoch's shard list
    made mesh-resident via :func:`place_forest`, and the epoch number it
    came from, for the router's session-token stamping."""
    if expect is not None:
        seq, digest = expect
        replica.verify(seq, digest, timeout=timeout)
    with replica.epochs.reading(with_epoch=True) as (epoch, pinned):
        shards = list(pinned) if isinstance(pinned, (tuple, list)) \
            else [pinned]
        placed = place_forest(shards, mesh, axis=axis)
    return placed, epoch


def _local_tree(forest_slice: TreeArrays) -> TreeArrays:
    """Strip the leading length-1 shard axis inside shard_map."""
    return dataclasses.replace(
        forest_slice, **{f: getattr(forest_slice, f)[0]
                         for f in _DATA_FIELDS})


def _restack(forest_slice: TreeArrays, tree: TreeArrays) -> TreeArrays:
    """Re-add the length-1 shard axis inside shard_map (inverse of
    ``_local_tree``)."""
    return dataclasses.replace(
        forest_slice, **{f: getattr(tree, f)[None] for f in _DATA_FIELDS})


def common_static_height(forest: TreeArrays) -> int | None:
    """Concrete tree height shared by every shard, or None when shards
    disagree (the cohort descent's static unroll needs one height; unequal
    shards fall back to the per-query engine)."""
    try:
        heights = np.asarray(jax.device_get(forest.height))
    except Exception:  # noqa: BLE001 — abstract/traced forest: no fast path
        return None
    if heights.size and (heights == heights.flat[0]).all():
        return int(heights.flat[0])
    return None


# The collective callables are built once per (mesh, axis, ...) and wrapped
# in jax.jit: a shard_map closure constructed per call would re-trace and
# re-lower the whole collective on EVERY invocation — seconds of compile on
# the mutation hot path (exactly the kind of host-side stall the
# mesh-resident control plane exists to avoid).
@functools.lru_cache(maxsize=None)
def _forest_knn_fn(mesh: Mesh, axis: str, batch_axis: str | None, k: int,
                   max_frontier: int, static_height: int | None,
                   parent_prune: bool):
    in_specs = (P(axis), P(batch_axis))
    out_specs = (P(batch_axis), P(batch_axis))

    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def run(forest_slice, q):
        tree = _local_tree(forest_slice)
        res = smtree.knn(tree, q, k=k, max_frontier=max_frontier,
                         static_height=static_height,
                         parent_prune=parent_prune)
        # k-way merge across shards: gather candidates, top-k
        all_d = jax.lax.all_gather(res.dists, axis)            # [S, b, k]
        all_i = jax.lax.all_gather(res.ids, axis)
        S = all_d.shape[0]
        b = q.shape[0]
        flat_d = all_d.transpose(1, 0, 2).reshape(b, S * k)
        flat_i = all_i.transpose(1, 0, 2).reshape(b, S * k)
        neg, sel = jax.lax.top_k(-flat_d, k)
        return -neg, jnp.take_along_axis(flat_i, sel, axis=1)

    return run


def forest_knn(forest: TreeArrays, mesh: Mesh, queries: jax.Array, *,
               k: int = 8, axis: str = "model", max_frontier: int = 64,
               batch_axis: str | None = None,
               parent_prune: bool | None = None):
    """Batched global kNN over the sharded forest.

    queries: [b, dim] (replicated or sharded over ``batch_axis``).
    Returns (dists [b, k], ids [b, k]) with globally merged results.

    The concrete per-shard heights are read *before* entering shard_map and
    plumbed through as a static argument, so each shard runs the PR-2
    cohort fast path (fused frontier scoring) instead of the per-query
    fallback whenever all shards share one height — which balanced
    round-robin bulk builds guarantee in practice.  ``parent_prune`` is
    resolved here (None → ``REPRO_PARENT_PRUNE``) and baked into the
    cached collective, so the per-shard descents run the parent-distance
    pre-filter with bitwise-identical merged results either way
    (DESIGN.md §17).
    """
    static_height = common_static_height(forest)
    return _forest_knn_fn(mesh, axis, batch_axis, k, max_frontier,
                          static_height,
                          smtree._resolve_parent_prune(parent_prune)
                          )(forest, queries)


@functools.lru_cache(maxsize=None)
def _forest_delete_fn(mesh: Mesh, axis: str):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None), P(None)),
                       out_specs=(P(axis), P(None)), check_rep=False)
    def run(forest_slice, xs, oids):
        tree = _local_tree(forest_slice)

        def body(carry, xo):
            tree = carry
            x, oid = xo
            new_tree, found, underflow, _ = smtree.delete_fast(tree, x, oid)
            # keep the pre-delete tree if underflow (host path resolves later)
            tree = jax.tree.map(
                lambda a, b: jnp.where(underflow, a, b), tree, new_tree)
            return tree, found & ~underflow

        tree, found = jax.lax.scan(body, tree, (xs, oids))
        found = jax.lax.psum(found.astype(jnp.int32), axis) > 0
        return _restack(forest_slice, tree), found

    return run


def forest_delete(forest: TreeArrays, mesh: Mesh, xs: jax.Array,
                  oids: jax.Array, *, axis: str = "model"):
    """Broadcast a delete batch; each shard applies the ids it owns via the
    jitted no-underflow fast path (underflow fallback is host-side per shard;
    eviction workloads delete recent bulk-built entries, so fast-path hit
    rate is high — measured in benchmarks/bench_engine.py).
    Returns (forest, found_mask [n])."""
    return _forest_delete_fn(mesh, axis)(forest, xs, oids)


def _validate_cohort(oids) -> None:
    """Host-side cohort-contract check: unique, non-negative oids.  Forces a
    device sync when ``oids`` lives on the mesh — which is exactly why it is
    opt-in (``validate=True``): the stream pipeline cuts cohorts host-side
    (``repro.stream.batcher.cut_cohorts``), where the contract holds by
    construction and the ids are still numpy."""
    oids_np = np.asarray(jax.device_get(oids))
    if len(np.unique(oids_np)) != len(oids_np):
        raise ValueError(
            "forest_apply_mutations requires unique oids per batch "
            "(conflict-free cohort); cut the log with "
            "repro.stream.batcher.cut_cohorts")
    if len(oids_np) and int(oids_np.min()) < 0:
        raise ValueError("negative object ids are reserved (NOP pad "
                         "sentinel)")


def forest_apply_mutations(forest: TreeArrays, mesh: Mesh, ops: jax.Array,
                           xs: jax.Array, oids: jax.Array,
                           owner: jax.Array, *, axis: str = "model",
                           validate: bool = False):
    """Broadcast a mixed insert/delete batch; each shard applies the rows it
    owns (``owner[i]`` = shard index) through the fused ``apply_mutations``
    scan in one collective step.  Non-owned rows become OP_NOP locally, so
    the psum of masked statuses reconstructs the global per-row outcome
    (ST_NOP is 0).  Returns (forest, statuses [B]).  ST_OVERFLOW rows are
    resolved by a follow-up ``forest_apply_splits`` collective (the stream
    control plane orchestrates it — repro.stream.pipeline); residual
    escalations go to the host.

    The batch must be a *conflict-free cohort* — no object id twice
    (``apply_mutations`` pre-locates delete targets against the pre-batch
    tree, which is unsound across same-id rows) and no negative ids.  Cut
    arbitrary logs with ``repro.stream.batcher.cut_cohorts`` first.
    ``validate=True`` re-checks the contract here at the price of a host
    round-trip per batch; it defaults off — and must stay off under jit —
    because the check syncs ``oids`` back to the host on the hot path."""
    if validate:
        _validate_cohort(oids)
    return _forest_apply_mutations_fn(mesh, axis)(
        forest, jnp.asarray(ops, jnp.int32), jnp.asarray(xs, jnp.float32),
        jnp.asarray(oids, jnp.int32), jnp.asarray(owner, jnp.int32))


@functools.lru_cache(maxsize=None)
def _forest_apply_mutations_fn(mesh: Mesh, axis: str):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None), P(None), P(None), P(None)),
                       out_specs=(P(axis), P(None)), check_rep=False)
    def run(forest_slice, ops, xs, oids, owner):
        tree = _local_tree(forest_slice)
        me = jax.lax.axis_index(axis)
        mine = owner == me
        local_ops = jnp.where(mine, ops, smtree.OP_NOP)
        # splits/merges=False: statuses are abstract here; the split and
        # merge passes run as their own collectives (forest_apply_splits /
        # forest_apply_merges) over the compacted escalation rows
        tree, status = smtree.apply_mutations(tree, local_ops, xs, oids,
                                              donate=False, splits=False,
                                              merges=False)
        status = jax.lax.psum(jnp.where(mine, status, 0), axis)
        return _restack(forest_slice, tree), status

    return run


def forest_apply_splits(forest: TreeArrays, mesh: Mesh, ops: jax.Array,
                        xs: jax.Array, oids: jax.Array, owner: jax.Array, *,
                        axis: str = "model"):
    """On-mesh split collective: resolve a compacted batch of ST_OVERFLOW
    insert rows (in log order, owner-routed like ``forest_apply_mutations``)
    through each shard's device split pass (``smtree.apply_splits``).
    Returns (forest, statuses [K]): ST_SPLIT where a shard absorbed the row
    on device, ST_OVERFLOW where it still needs the host control plane.
    Tree pages never leave HBM; only the status vector does."""
    return _forest_apply_splits_fn(mesh, axis)(
        forest, jnp.asarray(ops, jnp.int32), jnp.asarray(xs, jnp.float32),
        jnp.asarray(oids, jnp.int32), jnp.asarray(owner, jnp.int32))


@functools.lru_cache(maxsize=None)
def _forest_apply_splits_fn(mesh: Mesh, axis: str):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None), P(None), P(None), P(None)),
                       out_specs=(P(axis), P(None)), check_rep=False)
    def run(forest_slice, ops, xs, oids, owner):
        tree = _local_tree(forest_slice)
        me = jax.lax.axis_index(axis)
        mine = owner == me
        local_ops = jnp.where(mine, ops, smtree.OP_NOP)
        tree, status = smtree.apply_splits(tree, local_ops, xs, oids,
                                           donate=False)
        status = jax.lax.psum(jnp.where(mine, status, 0), axis)
        return _restack(forest_slice, tree), status

    return run


def forest_apply_merges(forest: TreeArrays, mesh: Mesh, ops: jax.Array,
                        oids: jax.Array, owner: jax.Array, *,
                        axis: str = "model"):
    """On-mesh merge collective: resolve a compacted batch of ST_UNDERFLOW
    delete rows (in log order, owner-routed like ``forest_apply_splits``)
    through each shard's device merge pass (``smtree.apply_merges``).
    Returns (forest, statuses [K]): ST_MERGE where a shard absorbed the
    row on device (merges never allocate, so no row ever blocks).  Tree
    pages never leave HBM; only the status vector does.  No ``xs``: the
    merge machinery locates targets by object id alone, exactly like the
    host's ``delete_with_merge``."""
    return _forest_apply_merges_fn(mesh, axis)(
        forest, jnp.asarray(ops, jnp.int32), jnp.asarray(oids, jnp.int32),
        jnp.asarray(owner, jnp.int32))


@functools.lru_cache(maxsize=None)
def _forest_apply_merges_fn(mesh: Mesh, axis: str):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None), P(None), P(None)),
                       out_specs=(P(axis), P(None)), check_rep=False)
    def run(forest_slice, ops, oids, owner):
        tree = _local_tree(forest_slice)
        me = jax.lax.axis_index(axis)
        mine = owner == me
        local_ops = jnp.where(mine, ops, smtree.OP_NOP)
        tree, status = smtree.apply_merges(tree, local_ops, oids,
                                           donate=False)
        status = jax.lax.psum(jnp.where(mine, status, 0), axis)
        return _restack(forest_slice, tree), status

    return run


def forest_extract_objects(forest: TreeArrays, mesh: Mesh, oids: jax.Array,
                           owner: jax.Array, *, axis: str = "model"):
    """Owner-routed vector gather across the mesh forest: for each
    requested id, the shard named by ``owner[i]`` looks it up locally
    (``smtree.extract_objects``) and the psum of masked rows reconstructs
    the replicated result.  Returns (vecs [B, dim] f32, found [B] bool);
    rows absent from their owner shard (or with ``owner`` -1 pads) come
    back zero-filled with ``found`` False.

    This is the read half of a mesh migration step: tree pages stay
    device-resident — only the [B, dim] gather leaves the shards — so the
    streaming forest can re-emit the rows as a delete-on-donor /
    insert-on-receiver cohort without unstacking anything to the host."""
    return _forest_extract_objects_fn(mesh, axis)(
        forest, jnp.asarray(oids, jnp.int32), jnp.asarray(owner, jnp.int32))


@functools.lru_cache(maxsize=None)
def _forest_extract_objects_fn(mesh: Mesh, axis: str):
    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None), P(None)),
                       out_specs=(P(None), P(None)), check_rep=False)
    def run(forest_slice, oids, owner):
        tree = _local_tree(forest_slice)
        me = jax.lax.axis_index(axis)
        mine = owner == me
        # non-owned rows become the -1 pad sentinel, which never matches
        local_oids = jnp.where(mine, oids, -1)
        vecs, found = smtree.extract_objects(tree, local_oids)
        found = found & mine
        vecs = jnp.where(found[:, None], vecs, 0.0)
        return (jax.lax.psum(vecs, axis),
                jax.lax.psum(found.astype(jnp.int32), axis) > 0)

    return run


def brute_force_knn(X: jax.Array, mesh: Mesh, queries: jax.Array, *,
                    k: int = 8, axis: str = "model", metric: str = "d_inf"):
    """Flat sharded scan baseline (the paper's 'sequential scan' line) using
    the Pallas distance kernel per shard."""
    from repro.kernels import ops

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis), P(None)),
                       out_specs=(P(None), P(None)), check_rep=False)
    def run(xs, q):
        d = ops.pairwise_distance(q, xs, metric=metric)       # [b, n_loc]
        neg, idx = jax.lax.top_k(-d, k)
        size = xs.shape[0]
        me = jax.lax.axis_index(axis)
        gids = idx + me * size
        all_d = jax.lax.all_gather(-neg, axis)                # [S, b, k]
        all_i = jax.lax.all_gather(gids, axis)
        S, b, _ = all_d.shape
        fd = all_d.transpose(1, 0, 2).reshape(b, S * k)
        fi = all_i.transpose(1, 0, 2).reshape(b, S * k)
        neg2, sel = jax.lax.top_k(-fd, k)
        return -neg2, jnp.take_along_axis(fi, sel, axis=1)

    return run(X, queries)
