"""Metric functions for the (S)M-tree.

The paper (§4.1) uses the Chebyshev / L-infinity metric

    d_inf(x, y) = max_i |x_i - y_i|

over 20-dimensional vectors, with experiment dimensionality varied by
truncating the metric (NOT the stored vectors) to the first ``n_dims``
components.  We mirror that: every metric takes an optional ``n_dims``.

All functions here are pure and work on numpy or jax arrays (they only use
ufuncs + reductions), so the same definitions back the numpy reference
implementation, the JAX engine, and the Pallas kernel oracle.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

MetricFn = Callable[..., "np.ndarray"]

_REGISTRY: dict[str, MetricFn] = {}


def register_metric(name: str):
    def deco(fn: MetricFn) -> MetricFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_metric(name: str) -> MetricFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_REGISTRY)}") from None


def _truncate(x, y, n_dims):
    if n_dims is not None:
        x = x[..., :n_dims]
        y = y[..., :n_dims]
    return x, y


@register_metric("d_inf")
def d_inf(x, y, n_dims: int | None = None):
    """Chebyshev metric; broadcasting pairwise over leading axes."""
    x, y = _truncate(x, y, n_dims)
    return abs(x - y).max(axis=-1)


@register_metric("l2")
def l2(x, y, n_dims: int | None = None):
    x, y = _truncate(x, y, n_dims)
    d = x - y
    return np.sqrt((d * d).sum(axis=-1)) if isinstance(d, np.ndarray) else ((d * d).sum(axis=-1)) ** 0.5


@register_metric("l1")
def l1(x, y, n_dims: int | None = None):
    x, y = _truncate(x, y, n_dims)
    return abs(x - y).sum(axis=-1)


def pairwise(metric: str | MetricFn, X, Y, n_dims: int | None = None):
    """[n, d] x [m, d] -> [n, m] distance matrix (numpy-side helper)."""
    fn = get_metric(metric) if isinstance(metric, str) else metric
    return fn(X[:, None, :], Y[None, :, :], n_dims=n_dims)


def make_metric(name: str, n_dims: int | None = None) -> MetricFn:
    fn = get_metric(name)
    return functools.partial(fn, n_dims=n_dims)
