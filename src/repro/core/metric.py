"""Metric functions for the (S)M-tree.

The paper (§4.1) uses the Chebyshev / L-infinity metric

    d_inf(x, y) = max_i |x_i - y_i|

over 20-dimensional vectors, with experiment dimensionality varied by
truncating the metric (NOT the stored vectors) to the first ``n_dims``
components.  We mirror that: every metric takes an optional ``n_dims``.

All functions here are pure and work on numpy or jax arrays (they only use
ufuncs, slicing and elementwise ops), so the same definitions back the numpy
reference implementation, the JAX engine's cohort descent, and the fused
Pallas frontier kernel (three call sites, one definition — they cannot
drift).

Summing reductions go through ``_sum_last``, a fixed-association pairwise
tree fold: the reduction tree depends only on the axis length, never on the
leading shape or backend, so l1/l2 distances are *bitwise identical* whether
evaluated on a ``[cap, dim]`` Pallas block, a ``[b, F, cap, dim]`` XLA
gather, or a numpy array.  The engine's xla-vs-pallas parity guarantee
(tests/test_cohort_descent.py) rests on this.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

MetricFn = Callable[..., "np.ndarray"]

_REGISTRY: dict[str, MetricFn] = {}


def register_metric(name: str):
    def deco(fn: MetricFn) -> MetricFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_metric(name: str) -> MetricFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_REGISTRY)}") from None


def _truncate(x, y, n_dims):
    if n_dims is not None:
        x = x[..., :n_dims]
        y = y[..., :n_dims]
    return x, y


def _sum_last(x):
    """Sum over the last axis with a fixed pairwise-tree association.

    Floating-point addition is not associative, and XLA's reduce grouping
    varies with the operand's leading shape — the same row summed inside a
    ``[cap, dim]`` kernel block and a ``[b, F, cap, dim]`` gather can differ
    in the last ulp.  This fold's association is a function of ``dim`` alone
    (halve, add, carry the odd tail), so every call site produces bitwise
    identical sums.  Works on numpy and jax arrays (slicing + ``+`` only).
    """
    n = x.shape[-1]
    if n == 0:
        return x.sum(axis=-1)   # empty sum: zeros, association irrelevant
    if n == 1:
        return x[..., 0]
    h = n // 2
    s = _sum_last(x[..., :h] + x[..., h:2 * h])
    if n % 2:
        s = s + x[..., -1]
    return s


_JAX_BARRIER = None


def _jax_barrier():
    """Lazily built vmap-compatible optimization barrier (jax's own
    primitive has no batching rule; batching is shape-preserving here, so a
    pass-through custom_vmap is sound)."""
    global _JAX_BARRIER
    if _JAX_BARRIER is None:
        import jax

        @jax.custom_batching.custom_vmap
        def barrier(x):
            return jax.lax.optimization_barrier(x)

        @barrier.def_vmap
        def _barrier_vmap(axis_size, in_batched, x):
            return barrier(x), in_batched[0]

        _JAX_BARRIER = barrier
    return _JAX_BARRIER


def _pin_rounding(x):
    """Keep XLA:CPU from contracting the squares into the fold's adds as
    FMAs — contraction is fusion-context-dependent, so without this pin
    the same l2 distance can differ by an ulp between e.g. a Pallas
    interpret-mode kernel and a plain gather (breaking bitwise parity).

    The optimization barrier alone is NOT sufficient: XLA:CPU strips
    barriers before fusion, and LLVM then contracts ``fadd(fmul, ·)``
    into an FMA in small fusion contexts (observed on the scalar pdist
    eval inside the fused insert fast path — 1-ulp drift vs the numpy
    fold, caught by tests/test_pdist_invariant.py).  ``max(x, 0)`` is an
    identity for the squares this guards but interposes an op LLVM's
    contraction pattern cannot see through, so the product is rounded to
    f32 exactly once at every call site.  No-op on numpy."""
    if isinstance(x, np.ndarray):
        return x
    import jax.numpy as jnp
    return jnp.maximum(_jax_barrier()(x), 0.0)


@register_metric("d_inf")
def d_inf(x, y, n_dims: int | None = None):
    """Chebyshev metric; broadcasting pairwise over leading axes."""
    x, y = _truncate(x, y, n_dims)
    return abs(x - y).max(axis=-1)


@register_metric("l2")
def l2(x, y, n_dims: int | None = None):
    x, y = _truncate(x, y, n_dims)
    d = x - y
    s = _sum_last(_pin_rounding(d * d))
    if isinstance(s, np.ndarray):
        return np.sqrt(s)
    # true sqrt, not s ** 0.5: pow goes through libm whose rounding varies
    # with vectorisation context (another cross-shape parity breaker); IEEE
    # sqrt is correctly rounded everywhere
    import jax.numpy as jnp
    return jnp.sqrt(s)


@register_metric("l1")
def l1(x, y, n_dims: int | None = None):
    x, y = _truncate(x, y, n_dims)
    return _sum_last(abs(x - y))


def pairwise(metric: str | MetricFn, X, Y, n_dims: int | None = None):
    """[n, d] x [m, d] -> [n, m] distance matrix (numpy-side helper)."""
    fn = get_metric(metric) if isinstance(metric, str) else metric
    return fn(X[:, None, :], Y[None, :, :], n_dims=n_dims)


def make_metric(name: str, n_dims: int | None = None) -> MetricFn:
    fn = get_metric(name)
    return functools.partial(fn, n_dims=n_dims)
