"""TPU-native SM-tree engine in JAX.

The paper's pointer-machine structure is re-expressed as a fixed-capacity
structure-of-arrays (one row per node / one lane per entry) so traversal is
frontier-at-a-time: every level of the descent scores *all entries of all
frontier nodes* of *all queries in the cohort* in one batched metric
evaluation, prunes with the triangle inequality, and compacts the surviving
children into the next frontier with a fixed-size top-F selection.

On TPU the per-level scoring runs through the fused Pallas frontier kernel
(kernels/frontier.py): frontier node ids are scalar-prefetched, node pages
stream HBM→VMEM double-buffered, and distances + d_max bounds + prune scores
are emitted in one VMEM-resident pass.  ``REPRO_FRONTIER_IMPL=xla`` is the
escape hatch forcing the plain-XLA gather path (bitwise identical results —
the shared fixed-association metric in core/metric.py guarantees it);
``=perquery`` selects the legacy vmap(per-query) engine kept as a benchmark
baseline.  On non-TPU backends the default is the XLA path, and
``=pallas`` runs the kernel through the Pallas interpreter (CI parity).

Roles (mirrors production vector-store engines):
  * data plane  — ``knn``, ``range_search``, ``insert`` fast path, ``delete``
    fast path: pure jitted functions on the ``TreeArrays`` pytree
    (lax.while_loop / fori_loop control flow, donate-friendly).
  * control plane — node splits/merges (amortised-rare structure edits):
    host-side numpy on the same arrays, sharing the exact split policy of the
    paper-faithful reference implementation (core/split.py).

The SM-tree invariant r(entry) = max(pdist_child + r_child) is what makes the
functional formulation possible at all: radius maintenance is a *fold over
the descent path*, no subtree walks (DESIGN.md §2).

All arrays are padded to static bounds (max_nodes, capacity, max height, max
frontier) — required for jit and exactly analogous to page-file layout.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metric import get_metric

MAX_HEIGHT = 16          # supports capacity^15 objects; plenty
_INF = jnp.inf
# the SM radius is a sum of f32-rounded terms; a directly computed distance
# can exceed the folded bound by an ulp — pad the prune test so borderline
# subtrees are visited rather than (incorrectly) pruned
_EPS = 1e-5
# leaf-level chunk count in the cohort descent: the frontier is scored in
# this many sequential slices with a top-k merge between them, so r_q
# tightens toward the true kth-NN distance before the far leaves are
# scored (see _knn_cohort).  Purely a schedule knob — results are exact
# kNN for any value >= 1.
_LEAF_CHUNKS = 4


# --------------------------------------------------------------------------
# Tree state
# --------------------------------------------------------------------------
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["vecs", "radius", "pdist", "child", "oid",
                                "valid", "count", "is_leaf", "alive",
                                "parent", "pslot", "root", "n_nodes",
                                "height", "free_list", "free_head"],
                   meta_fields=["capacity", "dim", "metric", "max_nodes",
                                "min_fill"])
@dataclasses.dataclass
class TreeArrays:
    vecs: jax.Array      # [N, cap, dim] f32 — entry reference values
    radius: jax.Array    # [N, cap] f32 — covering radii (0 at leaf entries)
    pdist: jax.Array     # [N, cap] f32 — d(entry, parent routing object)
    child: jax.Array     # [N, cap] i32 — child node id; -1 for leaf entries
    oid: jax.Array       # [N, cap] i32 — object id at leaf entries; -1 else
    valid: jax.Array     # [N, cap] bool
    count: jax.Array     # [N] i32
    is_leaf: jax.Array   # [N] bool
    alive: jax.Array     # [N] bool — allocated node slots (free-list support)
    parent: jax.Array    # [N] i32 — parent node id (-1 at root)
    pslot: jax.Array     # [N] i32 — slot within parent pointing here
    root: jax.Array      # [] i32
    n_nodes: jax.Array   # [] i32
    height: jax.Array    # [] i32
    free_list: jax.Array # [N] i32 — dead node ids, packed descending; -1 pad
    free_head: jax.Array # [] i32 — ring occupancy: free_list[:free_head] live
    capacity: int
    dim: int
    metric: str
    max_nodes: int
    min_fill: int

    @property
    def n_objects(self) -> int:
        # dead (freed) node slots may keep stale valid bits — e.g. a batched
        # merge that marks the donor dead on device without scrubbing its
        # rows — so the alive mask must gate the count
        live = self.alive[..., None] & self.is_leaf[..., None] & self.valid
        return int(jnp.sum(live))

    @property
    def n_free_nodes(self) -> int:
        """Unallocated node slots (free-list headroom for splits)."""
        return int(jnp.sum(~self.alive))


def packed_free_list(alive) -> tuple[np.ndarray, np.ndarray]:
    """Device free-ring representation of the dead node set.

    ``free_list[:free_head]`` holds the dead node ids in **descending**
    order, so the top of the stack (``free_list[free_head-1]``) is the
    *lowest* free id — popping on device allocates exactly the node the
    host control plane's ``_HostView.alloc`` (lowest free index) would
    pick, which is what keeps device splits bitwise-equal to host splits.
    Descending order is maintained on both ends: device pops scrub the
    top slot, device frees (the merge pass) insert at the sorted position
    (``_push_free``), and the rare host escalation recomputes the ring
    wholesale here via ``to_tree`` — all three leave the identical packed
    representation, so arbitrary push/pop interleavings keep device and
    host allocation choices aligned."""
    alive = np.asarray(alive)
    free = np.nonzero(~alive)[0][::-1].astype(np.int32)
    out = np.full(alive.shape[0], -1, np.int32)
    out[:len(free)] = free
    return out, np.int32(len(free))


def empty_tree(*, dim: int, capacity: int = 32, max_nodes: int = 1024,
               metric: str = "d_inf", min_fill_frac: float = 0.4) -> TreeArrays:
    cap, N = capacity, max_nodes
    alive = np.zeros((N,), bool)
    alive[0] = True
    free_list, free_head = packed_free_list(alive)
    return TreeArrays(
        vecs=jnp.zeros((N, cap, dim), jnp.float32),
        radius=jnp.zeros((N, cap), jnp.float32),
        pdist=jnp.zeros((N, cap), jnp.float32),
        child=jnp.full((N, cap), -1, jnp.int32),
        oid=jnp.full((N, cap), -1, jnp.int32),
        valid=jnp.zeros((N, cap), bool),
        count=jnp.zeros((N,), jnp.int32),
        is_leaf=jnp.ones((N,), bool),
        alive=jnp.asarray(alive),
        parent=jnp.full((N,), -1, jnp.int32),
        pslot=jnp.full((N,), -1, jnp.int32),
        root=jnp.int32(0), n_nodes=jnp.int32(1), height=jnp.int32(1),
        free_list=jnp.asarray(free_list), free_head=jnp.asarray(free_head),
        capacity=cap, dim=dim, metric=metric, max_nodes=N,
        min_fill=max(1, math.ceil(min_fill_frac * cap)))


def _metric_eval(metric: str, q, e):
    """q: [..., d]; e: [..., d] broadcast; returns distances [...].

    Thin shim over the core/metric.py registry — the single metric
    definition shared with the numpy reference implementation and the fused
    Pallas frontier kernel, so the three call sites cannot drift."""
    try:
        fn = get_metric(metric)
    except KeyError:
        raise ValueError(metric) from None
    return fn(q, e)


# --------------------------------------------------------------------------
# Bulk build (host-side, numpy): balanced bottom-up construction
# --------------------------------------------------------------------------
def bulk_build(X: np.ndarray, ids: np.ndarray | None = None, *,
               capacity: int = 32, metric: str = "d_inf",
               fill_frac: float = 0.7, min_fill_frac: float = 0.4,
               seed: int = 0, slack: float = 1.5) -> TreeArrays:
    """Construct a valid SM-tree over X [n, d] (balanced recursive-bisection
    grouping, medoid routing objects, exact SM radii).  O(n log n) distance
    evaluations, fully vectorised per group."""
    from repro.core.metric import make_metric
    mfn = make_metric(metric, None)
    X = np.asarray(X, np.float32)
    n, dim = X.shape
    ids = np.arange(n, dtype=np.int64) if ids is None else np.asarray(ids)
    target = max(2, int(capacity * fill_frac))
    min_fill = max(1, math.ceil(min_fill_frac * capacity))
    rng = np.random.default_rng(seed)

    def group(indices: np.ndarray, tgt: int, pts: np.ndarray) -> list[np.ndarray]:
        """Partition `indices` into groups of near-equal size via recursive
        2-pivot bisection.  Sizes land in [floor(n/parts), ceil(n/parts)];
        parts is capped at n // min_fill so every group meets the min-fill
        floor (a group below it would violate the non-root invariant the
        engine's validate() and the cohort descent's d_max bound rely on —
        e.g. n=23 at capacity 32 must stay one node, not split 11/12).
        The cap can only force parts to 1 when n < 2*min_fill <= capacity,
        so single groups always fit a node."""
        n_idx = len(indices)
        parts = min(-(-n_idx // tgt), n_idx // min_fill)
        if parts <= 1:
            return [indices]
        P = pts[indices]
        a = int(rng.integers(n_idx))
        da = mfn(P[a][None, :], P)
        b = int(np.argmax(da))
        db = mfn(P[b][None, :], P)
        order = np.argsort(da - db, kind="stable")   # closest-to-a first
        left_parts = parts // 2
        cut = round(n_idx * left_parts / parts)
        return (group(indices[order[:cut]], tgt, pts)
                + group(indices[order[cut:]], tgt, pts))

    # --- leaves ---
    leaf_groups = group(np.arange(n), target, X)
    levels = [leaf_groups]

    # node table accumulators
    nodes: list[dict] = []

    def medoid(P: np.ndarray, extra: np.ndarray | None = None) -> int:
        D = np.asarray(mfn(P[:, None, :], P[None, :, :]))
        if extra is not None:
            D = D + extra[None, :]
        return int(D.max(axis=1).argmin())

    # build leaf nodes
    level_nodes = []   # (node_id, routing_vec, covering_radius)
    for g in leaf_groups:
        P = X[g]
        mi = medoid(P)
        d_to_m = np.asarray(mfn(P[mi][None, :], P))
        nid = len(nodes)
        nodes.append(dict(is_leaf=True, vecs=P, radius=np.zeros(len(g)),
                          pdist=d_to_m, oid=ids[g], child=np.full(len(g), -1)))
        level_nodes.append((nid, P[mi], float(d_to_m.max())))

    height = 1
    while len(level_nodes) > 1:
        height += 1
        routing = np.stack([v for _, v, _ in level_nodes])
        radii = np.array([r for _, _, r in level_nodes])
        nids = np.array([i for i, _, _ in level_nodes])
        parent_groups = group(np.arange(len(level_nodes)), target, routing)
        next_level = []
        for g in parent_groups:
            P = routing[g]
            rg = radii[g]
            mi = medoid(P, rg)
            d_to_m = np.asarray(mfn(P[mi][None, :], P))
            nid = len(nodes)
            nodes.append(dict(is_leaf=False, vecs=P, radius=rg, pdist=d_to_m,
                              oid=np.full(len(g), -1), child=nids[g]))
            next_level.append((nid, P[mi], float((d_to_m + rg).max())))
        level_nodes = next_level

    root = level_nodes[0][0]
    N = max(16, int(len(nodes) * slack))
    t = empty_tree(dim=dim, capacity=capacity, max_nodes=N, metric=metric,
                   min_fill_frac=min_fill_frac)
    vecs = np.zeros((N, capacity, dim), np.float32)
    radius = np.zeros((N, capacity), np.float32)
    pdist = np.zeros((N, capacity), np.float32)
    child = np.full((N, capacity), -1, np.int32)
    oid = np.full((N, capacity), -1, np.int32)
    valid = np.zeros((N, capacity), bool)
    count = np.zeros((N,), np.int32)
    is_leaf = np.ones((N,), bool)
    parent = np.full((N,), -1, np.int32)
    pslot = np.full((N,), -1, np.int32)
    alive = np.zeros((N,), bool)
    alive[:len(nodes)] = True
    for i, nd in enumerate(nodes):
        m = len(nd["oid"])
        assert m <= capacity, (m, capacity)
        vecs[i, :m] = nd["vecs"]
        radius[i, :m] = nd["radius"]
        pdist[i, :m] = nd["pdist"]
        child[i, :m] = nd["child"]
        oid[i, :m] = nd["oid"]
        valid[i, :m] = True
        count[i] = m
        is_leaf[i] = nd["is_leaf"]
        if not nd["is_leaf"]:
            for s, c in enumerate(nd["child"]):
                parent[c] = i
                pslot[c] = s
    free_list, free_head = packed_free_list(alive)
    return dataclasses.replace(
        t, vecs=jnp.asarray(vecs), radius=jnp.asarray(radius),
        pdist=jnp.asarray(pdist), child=jnp.asarray(child),
        oid=jnp.asarray(oid), valid=jnp.asarray(valid),
        count=jnp.asarray(count), is_leaf=jnp.asarray(is_leaf),
        alive=jnp.asarray(alive), parent=jnp.asarray(parent),
        pslot=jnp.asarray(pslot),
        root=jnp.int32(root), n_nodes=jnp.int32(len(nodes)),
        height=jnp.int32(height),
        free_list=jnp.asarray(free_list), free_head=jnp.asarray(free_head))


# --------------------------------------------------------------------------
# Batched queries (jitted data plane)
# --------------------------------------------------------------------------
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["dists", "ids", "page_hits", "dist_evals",
                                "overflow"], meta_fields=[])
@dataclasses.dataclass
class QueryResult:
    dists: jax.Array     # [b, k] (inf-padded)
    ids: jax.Array       # [b, k] (-1-padded)
    page_hits: jax.Array # [b] nodes visited
    dist_evals: jax.Array# [b] metric evaluations
    overflow: jax.Array  # [b] bool — frontier truncated (result approximate)


_IMPLS = ("pallas", "xla", "perquery")


def _resolve_impl(impl: str | None) -> str:
    """Resolve the frontier-scoring implementation.

    None → the ``REPRO_FRONTIER_IMPL`` env var (default 'auto': the fused
    Pallas kernel on TPU, the XLA gather path elsewhere).  On non-TPU
    backends 'pallas' means the interpret-mode kernel — identical code,
    exercised by CPU CI."""
    if impl is None:
        impl = os.environ.get("REPRO_FRONTIER_IMPL", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in _IMPLS:
        raise ValueError(
            f"impl must be one of {_IMPLS} or 'auto'; got {impl!r}")
    return impl


_PARENT_PRUNE_VALUES = ("auto", "0", "1")


def _resolve_parent_prune(parent_prune: bool | None) -> bool:
    """Resolve the parent-distance pre-filter toggle (DESIGN.md §17).

    None → the ``REPRO_PARENT_PRUNE`` env var ('auto'/'1' = on — the
    default, since results are bitwise identical either way; '0' = off,
    the A/B lever the benches and parity tests use).  Anything else raises
    rather than silently running unfiltered."""
    if parent_prune is not None:
        return bool(parent_prune)
    v = os.environ.get("REPRO_PARENT_PRUNE", "auto")
    if v not in _PARENT_PRUNE_VALUES:
        raise ValueError(
            f"REPRO_PARENT_PRUNE must be one of {_PARENT_PRUNE_VALUES}; "
            f"got {v!r}")
    return v != "0"


def knn(tree: TreeArrays, queries: jax.Array, *, k: int = 1,
        max_frontier: int = 64, impl: str | None = None,
        static_height: int | None = None, level_stats: bool = False,
        parent_prune: bool | None = None):
    """Batched k-NN: level-synchronous cohort descent with dynamic radius.

    queries: [b, dim].  Exact when ``overflow`` is False (frontier never
    truncated); otherwise best-effort (closest-first truncation).  ``impl``
    overrides the frontier-scoring backend (see ``_resolve_impl``).
    ``static_height`` supplies the concrete tree height in traced contexts
    (the sharded forest's shard_map) where ``tree.height`` is abstract, so
    the cohort fast path can unroll instead of falling back to the
    per-query engine.

    ``level_stats=True`` returns ``(QueryResult, pruned)`` where pruned is
    a ``(by_bound, by_parent)`` pair of int32 stacks — ``by_bound``
    ``[n_internal_levels, b]`` counts entries whose d_min bound excluded
    their subtree; ``by_parent`` ``[height, b]`` counts entries the
    parent-distance pre-filter dropped *before* any metric eval
    (DESIGN.md §17; all-zero with ``parent_prune`` off, and at the root
    level, which has no parent).  It is a *static* flag: a separate jit
    cache entry that leaves the default geometry untouched
    (observability's paper counters; DESIGN.md §15).  ``pruned`` is None
    when the per-query fallback engine served the call.

    ``parent_prune`` toggles the triangle-inequality pre-filter
    ``|d(q,parent) − pdist| > r_q + r`` ahead of each level's metric eval
    (None → ``REPRO_PARENT_PRUNE`` env, default on).  Results are bitwise
    identical on or off; only ``dist_evals`` (which counts *performed*
    evaluations) changes.
    """
    queries = jnp.asarray(queries, jnp.float32)
    return _query(tree, queries, k, max_frontier, jnp.float32(_INF),
                  _resolve_impl(impl), static_height,
                  level_stats=level_stats,
                  parent_prune=_resolve_parent_prune(parent_prune))


def range_search(tree: TreeArrays, queries: jax.Array, radius: jax.Array, *,
                 max_results: int = 128, max_frontier: int = 64,
                 impl: str | None = None,
                 parent_prune: bool | None = None) -> QueryResult:
    """Batched range query: all objects within ``radius`` (per-query scalar or
    broadcast).  Returns the closest ``max_results`` matches.  The overflow
    flag is conservative: it is set whenever ``max_results`` rows are
    returned — at *exactly* ``max_results`` matches the engine cannot know no
    further object matched, so the flag reads "results may be truncated"."""
    queries = jnp.asarray(queries, jnp.float32)
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32),
                              (queries.shape[0],))
    res = _query(tree, queries, max_results, max_frontier, radius,
                 _resolve_impl(impl),
                 parent_prune=_resolve_parent_prune(parent_prune))
    return _range_filter(res, radius, max_results)


@functools.partial(jax.jit, static_argnames=("max_results",))
def _range_filter(res: QueryResult, radius, max_results: int) -> QueryResult:
    keep = res.dists <= radius[:, None]
    return QueryResult(jnp.where(keep, res.dists, _INF),
                       jnp.where(keep, res.ids, -1),
                       res.page_hits, res.dist_evals,
                       res.overflow | (jnp.sum(keep, 1) == max_results))


def _query(tree: TreeArrays, queries: jax.Array, k: int, F: int, r_cap,
           impl: str, static_height: int | None = None, *,
           level_stats: bool = False, parent_prune: bool = True):
    """Dispatch: the cohort engine unrolls the descent over the concrete tree
    height (leaves are all at one depth, so each level is statically either
    internal or leaf).  In traced contexts (e.g. the sharded forest's
    shard_map, where ``height`` is abstract) fall back to the per-query
    engine, which carries dynamic control flow — unless the caller plumbed
    the concrete height through as ``static_height``
    (core/distributed.py:forest_knn)."""
    if impl == "perquery":
        res = _knn_perquery(tree, queries, k, F, r_cap)
        return (res, None) if level_stats else res
    if static_height is not None:
        height = int(static_height)
    else:
        try:
            height = int(tree.height)
        except jax.errors.ConcretizationTypeError:
            res = _knn_perquery(tree, queries, k, F, r_cap)
            return (res, None) if level_stats else res
    interpret = jax.default_backend() != "tpu"
    return _knn_cohort(tree, queries, r_cap, k=k, F=F, height=height,
                       impl=impl, interpret=interpret,
                       level_stats=level_stats, prune=parent_prune)


@functools.partial(jax.jit,
                   static_argnames=("k", "F", "height", "impl", "interpret",
                                    "level_stats", "prune"))
def _knn_cohort(tree: TreeArrays, queries: jax.Array, r_cap, *, k: int,
                F: int, height: int, impl: str, interpret: bool,
                level_stats: bool = False, prune: bool = True):
    """Level-synchronous query-cohort descent (the fast path).

    All ``b`` queries advance one level per step, sharing one fused frontier
    scoring (Pallas kernel or XLA gather) and one batched top-k compaction
    per level.  The loop is unrolled over the static tree height with
    per-level frontier widths ``w(0)=1, w(l+1)=min(F, w(l)*cap)`` — early
    levels touch only the pages that exist, and because every leaf sits at
    the same depth (balance invariant), each level is statically a pure
    internal level (bound + prune + compact) or the leaf level (candidate
    merge); the other phase's work is not emitted at all.

    Exactness argument under batched truncation: the d_max bound ``ub`` is
    the j-th smallest d + r seen so far (+_EPS, j = ceil(k / min_fill^rem),
    usually 1) — r covers the entry's whole disjoint subtree of >=
    min_fill^rem objects, so ub is a true upper bound on the kth-NN distance
    for *this* query regardless of which frontier slots other queries keep.
    Truncation to w_out slots
    keeps the w_out smallest d - r; a dropped subtree can only matter if its
    d - r exceeds every kept one AND ≤ r_q — exactly the case the per-query
    ``overflow`` flag reports (DESIGN.md §8).

    ``level_stats`` is static so the default (False) trace emits exactly
    the ops it always did; the True variant additionally stacks per-level
    pruned-by-bound and pruned-by-parent counts and only ever compiles
    when observability asks for it.

    ``prune`` (static) turns on the parent-distance pre-filter
    (DESIGN.md §17): each frontier slot carries ``qpd`` — the distance
    d(q, routing object) computed at the level that *admitted* the node —
    and the scorer drops entries with ``|qpd − pdist| > r_q + r`` before
    the metric eval.  The filter threshold pads by 2·_EPS (the prune
    test's _EPS plus f32 triangle rounding), so every filtered entry
    provably fails the d − r ≤ r_q + _EPS test and results stay bitwise
    identical; ``dist_evals`` counts evaluations actually performed, so
    it (alone) shrinks.  The root level has no parent — level 0 always
    scores unfiltered.

    The leaf level is *chunked*: the frontier arrives sorted by d − r
    (top_k compaction order), so scoring it in _LEAF_CHUNKS sequential
    slices and merging the top-k between slices tightens r_q toward the
    true kth-NN distance before the far leaves are touched — that is
    where the pre-filter earns its keep (DESIGN.md §17).  Chunking is
    emitted identically with the filter on and off (per-chunk r_q is the
    same value in both traces), so the bitwise-identity argument applies
    chunk by chunk, and the unpruned path still evaluates every valid
    entry — only wall-clock layout changes, not its dist_evals.
    """
    from repro.kernels.frontier import frontier_scores

    b = queries.shape[0]
    cap = tree.capacity
    r_cap = jnp.broadcast_to(jnp.asarray(r_cap, jnp.float32), (b,))

    widths = [1]
    for _ in range(height - 1):
        widths.append(min(F, widths[-1] * cap))

    internal_valid = tree.valid & ~tree.is_leaf[:, None]
    leaf_valid = tree.valid & tree.is_leaf[:, None]

    frontier = jnp.full((b, 1), tree.root, jnp.int32)
    qpd = jnp.full((b, 1), _INF, jnp.float32)   # d(q, parent) per slot
    topk_d = jnp.full((b, k), _INF, jnp.float32)
    topk_i = jnp.full((b, k), -1, jnp.int32)
    ub = jnp.full((b,), _INF, jnp.float32)
    page_hits = jnp.zeros((b,), jnp.int32)
    dist_evals = jnp.zeros((b,), jnp.int32)
    overflow = jnp.zeros((b,), bool)
    pruned_levels = []          # level_stats only: [b] per internal level
    parent_levels = []          # level_stats only: [b] per level

    for lvl in range(height):
        w = widths[lvl]
        fvalid = frontier >= 0                              # [b, w]
        nodes = jnp.maximum(frontier, 0)
        page_hits += jnp.sum(fvalid, axis=1, dtype=jnp.int32)

        # the root has no parent routing object: level 0 scores unfiltered
        use_filter = prune and lvl > 0
        if lvl > 0:
            # pre-eval kth-NN upper bound from parent distances alone
            # (DESIGN.md §17): two triangle hops give d(q, x) <= qpd +
            # pdist(e) + r(e) for every object x under entry e, and each
            # valid entry covers >= min_fill^rem disjoint objects, so the
            # j-th smallest such bound caps the kth-NN distance before
            # this level runs a single metric eval — exactly when the
            # pre-filter needs a tight r_q.  It feeds r_q in the pruned
            # AND unpruned traces (identical values), so on/off bitwise
            # identity is untouched.
            pd_ub = tree.pdist[nodes] + tree.radius[nodes]   # [b, w, cap]
            ok = tree.valid[nodes] & fvalid[:, :, None]
            ubnd = jnp.where(ok, qpd[:, :, None] + pd_ub,
                             _INF).reshape(b, w * cap)
            j_pre = -(-k // max(1, tree.min_fill) ** (height - 1 - lvl))
            if j_pre == 1:
                ub = jnp.minimum(ub, jnp.min(ubnd, axis=1) + _EPS)
            elif j_pre <= w * cap:
                ub = jnp.minimum(
                    ub, -jax.lax.top_k(-ubnd, j_pre)[0][:, j_pre - 1]
                    + _EPS)

        if lvl < height - 1:
            if use_filter:
                # pre-level query radius — what the filter may assume.
                # The level body's r_q is computed after this level's ub
                # update and can only shrink, so filtering against the
                # pre-level value is conservative (never drops an entry
                # the prune test would have kept; DESIGN.md §17).
                rq_pre = jnp.minimum(jnp.minimum(topk_d[:, k - 1], r_cap),
                                     ub)
                filt = dict(pdist=tree.pdist, qpd=qpd, rq=rq_pre)
            else:
                filt = {}
            dmax, score, leaf_d, dq = frontier_scores(
                frontier, queries, tree.vecs, tree.radius, internal_valid,
                leaf_valid, metric=tree.metric, impl=impl,
                interpret=interpret, **filt)

            # evaluations actually performed: finite outputs ⇔ the scorer
            # ran the metric for that entry (valid, on a live slot, not
            # filtered).  With the filter off this equals the old
            # valid-entry count.
            performed = jnp.isfinite(dmax) | jnp.isfinite(leaf_d)
            n_eval = jnp.sum(performed, axis=(1, 2), dtype=jnp.int32)
            dist_evals += n_eval
            if level_stats:
                evalid = tree.valid[nodes] & fvalid[:, :, None]
                parent_levels.append(
                    jnp.sum(evalid, axis=(1, 2), dtype=jnp.int32) - n_eval)
            # --- internal level: d_max bound, prune, compact the frontier
            # r covers the *whole* subtree, and every non-root node holds at
            # least min_fill entries, so an entry at this level covers >=
            # min_fill^rem objects — the j-th smallest d + r with
            # j = ceil(k / min_fill^rem) already bounds the kth-NN distance.
            # Usually j == 1: a plain min, no top_k (tighter than the
            # per-query engine's kth-smallest bound, and ~free).
            dmax = dmax.reshape(b, w * cap)
            rem = height - 1 - lvl
            cover = max(1, tree.min_fill) ** rem
            j = -(-k // cover)
            if j == 1:
                ub = jnp.minimum(ub, jnp.min(dmax, axis=1) + _EPS)
            elif j <= w * cap:
                jth_dmax = -jax.lax.top_k(-dmax, j)[0][:, j - 1] + _EPS
                ub = jnp.minimum(ub, jth_dmax)
            # (fewer than j subtree bounds visible: no update possible)
            r_q = jnp.minimum(jnp.minimum(topk_d[:, k - 1], r_cap), ub)
            score = score.reshape(b, w * cap)
            # score is +inf at masked entries; the explicit < _INF term keeps
            # them out of imask when r_q itself is still infinite
            imask = (score <= r_q[:, None] + _EPS) & (score < _INF)
            if level_stats:
                # scored entries whose d_min bound excluded their subtree
                # (isfinite(score) ⇔ the metric ran for this entry, so
                # parent-filtered entries are not double-counted here)
                pruned_levels.append(jnp.sum(
                    jnp.isfinite(score) & ~imask,
                    axis=1, dtype=jnp.int32))
            sc = jnp.where(imask, score, _INF)
            childs = tree.child[nodes].reshape(b, w * cap)
            w_out = widths[lvl + 1]
            neg_s, order = jax.lax.top_k(-sc, w_out)
            sel_ok = -neg_s < _INF
            frontier = jnp.where(
                sel_ok, jnp.take_along_axis(childs, order, axis=1), -1)
            overflow |= jnp.sum(imask, axis=1) > w_out
            # carry d(q, routing object) of each admitted entry: it is
            # the next level's d(q, parent), and the child's pdist was
            # computed against this exact routing object.  Selected
            # slots always came through imask, so their dq is finite.
            # Carried even with the filter off — the pre-eval upper
            # bound above consumes it in both traces.
            qpd = jnp.where(
                sel_ok,
                jnp.take_along_axis(dq.reshape(b, w * cap), order,
                                    axis=1),
                _INF)
        else:
            # --- leaf level: merge candidates into the running top-k,
            # chunked over the (score-sorted) frontier so each chunk's
            # merge tightens r_q for the next.  Chunk 1 holds the closest
            # subtrees and usually drives topk_d[k-1] to near-final, so
            # the remaining chunks — most of the leaf entries — see a
            # near-oracle radius both in the candidate test and in the
            # parent-distance pre-filter.
            chw = -(-w // min(_LEAF_CHUNKS, w))
            parent_acc = jnp.zeros((b,), jnp.int32)
            for c0 in range(0, w, chw):
                fr_c = frontier[:, c0:c0 + chw]
                nodes_c = nodes[:, c0:c0 + chw]
                wc = fr_c.shape[1]
                # per-chunk query radius: identical formula (and value)
                # with the filter on or off — the bitwise-identity proof
                # applies per chunk
                r_q = jnp.minimum(jnp.minimum(topk_d[:, k - 1], r_cap), ub)
                filt = (dict(pdist=tree.pdist, qpd=qpd[:, c0:c0 + chw],
                             rq=r_q)
                        if use_filter else {})
                dmax_c, _, leaf_d, _ = frontier_scores(
                    fr_c, queries, tree.vecs, tree.radius, internal_valid,
                    leaf_valid, metric=tree.metric, impl=impl,
                    interpret=interpret, **filt)
                performed = jnp.isfinite(dmax_c) | jnp.isfinite(leaf_d)
                n_eval = jnp.sum(performed, axis=(1, 2), dtype=jnp.int32)
                dist_evals += n_eval
                if level_stats:
                    evalid = tree.valid[nodes_c] & (fr_c >= 0)[:, :, None]
                    parent_acc += jnp.sum(
                        evalid, axis=(1, 2), dtype=jnp.int32) - n_eval
                leaf_d = leaf_d.reshape(b, wc * cap)
                cd = jnp.where(leaf_d <= r_q[:, None], leaf_d, _INF)
                eoid = tree.oid[nodes_c].reshape(b, wc * cap)
                ci = jnp.where(cd < _INF, eoid, -1)
                all_d = jnp.concatenate([topk_d, cd], axis=1)
                all_i = jnp.concatenate([topk_i, ci], axis=1)
                neg, sel = jax.lax.top_k(-all_d, k)
                topk_d = -neg
                topk_i = jnp.take_along_axis(all_i, sel, axis=1)
            if level_stats:
                parent_levels.append(parent_acc)

    res = QueryResult(topk_d, topk_i, page_hits, dist_evals, overflow)
    if level_stats:
        by_bound = (jnp.stack(pruned_levels) if pruned_levels
                    else jnp.zeros((0, b), jnp.int32))
        by_parent = jnp.stack(parent_levels)
        return res, (by_bound, by_parent)
    return res


@functools.partial(jax.jit, static_argnames=("k", "F"))
def _knn_perquery(tree: TreeArrays, queries: jax.Array, k: int, F: int,
                  r_cap) -> QueryResult:
    """Legacy vmap(per-query) engine: dynamic while_loop descent.

    Kept as (a) the fallback for traced-height contexts (sharded forest)
    and (b) the benchmark baseline the cohort path is measured against
    (benchmarks/bench_engine.py)."""
    b = queries.shape[0]
    cap = tree.capacity
    r_cap = jnp.broadcast_to(jnp.asarray(r_cap, jnp.float32), (b,))

    def per_query(q, rc):
        frontier = jnp.full((F,), -1, jnp.int32).at[0].set(tree.root)
        topk_d = jnp.full((k,), _INF, jnp.float32)
        topk_i = jnp.full((k,), -1, jnp.int32)
        ub = jnp.float32(_INF)  # upper bound on kth-NN distance (d_max bound)
        stats = jnp.zeros((3,), jnp.int32)  # page_hits, dist_evals, overflow
        lvl = jnp.int32(0)

        def cond(state):
            frontier, *_, lvl = state
            return (lvl < tree.height) & jnp.any(frontier >= 0)

        def body(state):
            frontier, topk_d, topk_i, ub, stats, lvl = state
            fvalid = frontier >= 0
            nodes = jnp.maximum(frontier, 0)
            evalid = tree.valid[nodes] & fvalid[:, None]        # [F, cap]
            evecs = tree.vecs[nodes]                            # [F, cap, d]
            erad = tree.radius[nodes]
            echild = tree.child[nodes]
            eoid = tree.oid[nodes]
            leafy = tree.is_leaf[nodes][:, None]                # [F, 1]

            d = _metric_eval(tree.metric, q[None, None, :], evecs)  # [F, cap]
            stats = stats.at[0].add(jnp.sum(fvalid.astype(jnp.int32)))
            stats = stats.at[1].add(jnp.sum(evalid.astype(jnp.int32)))

            # d_max bound: each internal entry's (disjoint, non-empty) subtree
            # holds an object within d + r, so the kth smallest of all d + r
            # seen is an upper bound on the kth-NN distance.  This is what
            # lets level-synchronous descent prune before any leaf is seen.
            imask0 = evalid & ~leafy
            dmax = jnp.where(imask0, d + erad, _INF).reshape(-1)
            kth_dmax = -jax.lax.top_k(-dmax, k)[0][k - 1] + _EPS
            ub = jnp.minimum(ub, kth_dmax)

            r_q = jnp.minimum(jnp.minimum(topk_d[k - 1], rc), ub)
            # --- leaf candidates -> merge into running top-k
            lmask = evalid & leafy & (d <= r_q)
            cd = jnp.where(lmask, d, _INF).reshape(-1)
            ci = jnp.where(lmask, eoid, -1).reshape(-1)
            all_d = jnp.concatenate([topk_d, cd])
            all_i = jnp.concatenate([topk_i, ci])
            neg, sel = jax.lax.top_k(-all_d, k)
            topk_d, topk_i = -neg, all_i[sel]
            r_q = jnp.minimum(jnp.minimum(topk_d[k - 1], rc), ub)

            # --- surviving internal entries -> next frontier (closest-first)
            imask = imask0 & ((d - erad) <= r_q + _EPS)
            score = jnp.where(imask, d - erad, _INF).reshape(-1)
            childs = echild.reshape(-1)
            neg_s, order = jax.lax.top_k(-score, F)
            sel_ok = -neg_s < _INF
            frontier = jnp.where(sel_ok, childs[order], -1)
            stats = stats.at[2].max(
                (jnp.sum(imask) > F).astype(jnp.int32))
            return frontier, topk_d, topk_i, ub, stats, lvl + 1

        frontier, topk_d, topk_i, ub, stats, _ = jax.lax.while_loop(
            cond, body, (frontier, topk_d, topk_i, ub, stats, lvl))
        return topk_d, topk_i, stats

    topk_d, topk_i, stats = jax.vmap(per_query)(queries, r_cap)
    return QueryResult(topk_d, topk_i, stats[:, 0], stats[:, 1],
                       stats[:, 2].astype(bool))


# --------------------------------------------------------------------------
# Jitted insert fast path + host-side split fallback
# --------------------------------------------------------------------------
def _descend_path(tree: TreeArrays, x: jax.Array):
    """SM-tree choose-subtree (closest entry) from root to leaf.
    Returns (path_nodes [MAX_HEIGHT], path_slots [MAX_HEIGHT], leaf_id)."""
    def body(state):
        node, lvl, pn, ps = state
        d = _metric_eval(tree.metric, x[None, :], tree.vecs[node])
        d = jnp.where(tree.valid[node], d, _INF)
        slot = jnp.argmin(d)
        pn = pn.at[lvl].set(node)
        ps = ps.at[lvl].set(slot.astype(jnp.int32))
        return tree.child[node, slot], lvl + 1, pn, ps

    def cond(state):
        node, *_ = state
        return ~tree.is_leaf[node]

    pn = jnp.full((MAX_HEIGHT,), -1, jnp.int32)
    ps = jnp.full((MAX_HEIGHT,), -1, jnp.int32)
    leaf, _, pn, ps = jax.lax.while_loop(cond, body, (tree.root, 0, pn, ps))
    return pn, ps, leaf


_descend = jax.jit(_descend_path)


def _refresh_path_radii(tree: TreeArrays, pn: jax.Array, ps: jax.Array) -> TreeArrays:
    """Bottom-up radius fold along the descent path: the SM invariant.
    r(entry at (pn[i], ps[i])) = max over its child node's valid entries of
    (pdist [+ radius])."""
    def body(i, t):
        lvl = MAX_HEIGHT - 1 - i
        node = pn[lvl]
        slot = ps[lvl]
        ok = node >= 0
        n = jnp.maximum(node, 0)
        c = t.child[n, jnp.maximum(slot, 0)]
        cn = jnp.maximum(c, 0)
        contrib = t.pdist[cn] + jnp.where(t.is_leaf[cn], 0.0, t.radius[cn])
        r = jnp.max(jnp.where(t.valid[cn], contrib, -_INF))
        new_rad = t.radius.at[n, jnp.maximum(slot, 0)].set(
            jnp.where(ok, jnp.maximum(r, 0.0), t.radius[n, jnp.maximum(slot, 0)]))
        return dataclasses.replace(t, radius=new_rad)

    return jax.lax.fori_loop(0, MAX_HEIGHT, body, tree)


def _insert_fast_impl(tree: TreeArrays, x: jax.Array, obj_id: jax.Array):
    """No-split insert.  Returns (tree, fits: bool, leaf_id).  When the leaf
    is full the tree is returned UNCHANGED with fits=False — the caller runs
    the host-side split path."""
    pn, ps, leaf = _descend(tree, x)
    cnt = tree.count[leaf]
    fits = cnt < tree.capacity
    slot = jnp.minimum(cnt, tree.capacity - 1)
    # parent routing vec: entry pointing at `leaf`
    has_parent = pn[0] >= 0
    plast = jnp.argmax(jnp.where(pn >= 0, jnp.arange(MAX_HEIGHT), -1))
    pnode = pn[plast]
    pslot = ps[plast]
    pvec = tree.vecs[jnp.maximum(pnode, 0), jnp.maximum(pslot, 0)]
    pd = jnp.where(has_parent, _metric_eval(tree.metric, x, pvec), 0.0)

    def apply(t: TreeArrays) -> TreeArrays:
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[leaf, slot].set(x),
            radius=t.radius.at[leaf, slot].set(0.0),
            pdist=t.pdist.at[leaf, slot].set(pd),
            child=t.child.at[leaf, slot].set(-1),
            oid=t.oid.at[leaf, slot].set(obj_id.astype(jnp.int32)),
            valid=t.valid.at[leaf, slot].set(True),
            count=t.count.at[leaf].add(1))
        return _refresh_path_radii(t, pn, ps)

    new_tree = jax.lax.cond(fits, apply, lambda t: t, tree)
    return new_tree, fits, leaf


insert_fast = jax.jit(_insert_fast_impl)


@jax.jit
def path_to_root(tree: TreeArrays, leaf: jax.Array):
    """Climb parent pointers: returns (path_nodes, path_slots) root-first,
    padded with -1 — same layout as _descend's output."""
    def body(state):
        node, chain_n, chain_s, depth = state
        p = tree.parent[node]
        s = tree.pslot[node]
        chain_n = chain_n.at[depth].set(p)
        chain_s = chain_s.at[depth].set(s)
        return p, chain_n, chain_s, depth + 1

    def cond(state):
        node, *_ , _d = state
        return tree.parent[node] >= 0

    cn = jnp.full((MAX_HEIGHT,), -1, jnp.int32)
    cs = jnp.full((MAX_HEIGHT,), -1, jnp.int32)
    _, cn, cs, depth = jax.lax.while_loop(cond, body, (leaf, cn, cs, 0))
    # chain is leaf-first; reverse the filled prefix to be root-first
    idx = depth - 1 - jnp.arange(MAX_HEIGHT)
    ok = idx >= 0
    pn = jnp.where(ok, cn[jnp.maximum(idx, 0)], -1)
    ps = jnp.where(ok, cs[jnp.maximum(idx, 0)], -1)
    return pn, ps


def _delete_fast_impl(tree: TreeArrays, x: jax.Array, obj_id: jax.Array):
    """No-underflow delete.  Returns (tree, found, underflow, leaf_id).
    On underflow the tree is returned UNCHANGED with underflow=True — caller
    runs the host-side merge path.  Locates the object by exact id match and
    climbs parent pointers for the O(h) radius fold.  Negative ids (the NOP
    pad sentinel) never match."""
    hit = (tree.oid == obj_id) & tree.valid & (obj_id >= 0)
    found = jnp.any(hit)
    flat = jnp.argmax(hit.reshape(-1))
    leaf = (flat // tree.capacity).astype(jnp.int32)
    slot = (flat % tree.capacity).astype(jnp.int32)
    cnt = tree.count[leaf]
    # root never underflows
    underflow = found & (cnt - 1 < tree.min_fill) & (leaf != tree.root)

    pn, ps = path_to_root(tree, leaf)

    def apply(t: TreeArrays) -> TreeArrays:
        last = cnt - 1
        # swap-remove: move last entry into the hole
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[leaf, slot].set(t.vecs[leaf, last]),
            radius=t.radius.at[leaf, slot].set(t.radius[leaf, last]),
            pdist=t.pdist.at[leaf, slot].set(t.pdist[leaf, last]),
            child=t.child.at[leaf, slot].set(t.child[leaf, last]),
            oid=t.oid.at[leaf, slot].set(t.oid[leaf, last]))
        t = dataclasses.replace(
            t,
            valid=t.valid.at[leaf, last].set(False),
            oid=t.oid.at[leaf, last].set(-1),
            count=t.count.at[leaf].add(-1))
        return _refresh_path_radii(t, pn, ps)

    ok = found & ~underflow
    new_tree = jax.lax.cond(ok, apply, lambda t: t, tree)
    return new_tree, found, underflow, leaf


delete_fast = jax.jit(_delete_fast_impl)


# --------------------------------------------------------------------------
# Batched mutation apply (the repro.stream data plane)
# --------------------------------------------------------------------------
# Mutation opcodes for ``apply_mutations`` / the stream batcher.  OP_NOP is 0
# so padding rows are all-zeros and masked statuses psum cleanly in the
# sharded forest (core/distributed.py).
OP_NOP, OP_INSERT, OP_DELETE = 0, 1, 2
# Per-row outcomes.  ST_NOP must stay 0 (same psum argument).
ST_NOP, ST_APPLIED, ST_OVERFLOW, ST_UNDERFLOW, ST_NOTFOUND = 0, 1, 2, 3, 4
# Resolved by the on-device split pass (apply_splits): either a single-level
# leaf split or an escalation-time re-check that found room.  Callers
# (stream/batcher.py) normalise it to ST_APPLIED after counting.
ST_SPLIT = 5
# Resolved by the on-device merge pass (apply_merges): an underflow delete
# absorbed without leaving HBM.  Normalised to ST_APPLIED like ST_SPLIT.
ST_MERGE = 6


def _apply_row(t: TreeArrays, vecs0: jax.Array, op, x, oid, leaf0, found0):
    """One mutation as a branch-free masked update (the scan body of
    ``apply_mutations``).

    Semantically identical to dispatching to ``insert_fast``/``delete_fast``
    per row, but shaped so XLA:CPU keeps the scan carry **in place** and
    each step's work stays O(h·cap); every deviation below is load-bearing
    for that (each was worth 2-4x on the batch throughput at n=100k):

      * no ``lax.cond``/``switch`` on tree state — branches returning whole
        trees materialise both versions of every array per step.  Rows that
        do not apply redirect their scatters out of bounds instead
        (``mode="drop"``), so no masking read of the current cell is needed.
      * the choose-subtree descent and the parent-routing-vector gather read
        ``vecs0`` — the *loop-invariant* pre-batch vecs.  Both only ever
        touch internal-node rows, which the fast path never writes, so the
        values are identical; reading the carried ``t.vecs`` instead would
        put a gather and a scatter on the same buffer in one fusion, which
        XLA resolves by copying all of ``vecs`` every step.
      * the delete target's leaf (``leaf0``/``found0``) is located once,
        vectorised, before the scan (``_locate_oids``): within a
        conflict-free batch nothing moves an object across leaves, so only
        the *slot* must be re-derived per step — an O(cap) row probe
        instead of an O(N·cap) table scan.
      * leaf ``child`` rows are always -1 and leaf ``radius`` rows always
        0.0 (bulk build, host splits and this fast path all maintain that),
        so the insert/swap writes to them are dropped outright.
    """
    cap = t.capacity
    is_ins_op = op == OP_INSERT
    is_del_op = op == OP_DELETE
    N = t.max_nodes   # out-of-bounds scatter target for inactive rows

    # --- insert probe: choose-subtree descent (invariant routing pages)
    t_inv = dataclasses.replace(t, vecs=vecs0)
    pn_i, ps_i, leaf_i = _descend_path(t_inv, x)
    cnt_i = t.count[leaf_i]
    fits = cnt_i < cap
    slot_i = jnp.minimum(cnt_i, cap - 1)
    has_parent = pn_i[0] >= 0
    plast = jnp.argmax(jnp.where(pn_i >= 0, jnp.arange(MAX_HEIGHT), -1))
    pvec = vecs0[jnp.maximum(pn_i[plast], 0), jnp.maximum(ps_i[plast], 0)]
    pd = jnp.where(has_parent, _metric_eval(t.metric, x, pvec), 0.0)

    # --- delete probe: pre-located leaf, slot re-derived from the live row
    # (earlier swap-removes may have moved the target within its leaf)
    found = found0 & is_del_op
    leaf_d = jnp.maximum(leaf0, 0)
    row_hit = (t.oid[leaf_d] == oid) & t.valid[leaf_d]      # [cap]
    slot_d = jnp.argmax(row_hit).astype(jnp.int32)
    cnt_d = t.count[leaf_d]
    underflow = found & (cnt_d - 1 < t.min_fill) & (leaf_d != t.root)
    last_d = jnp.maximum(cnt_d - 1, 0)
    pn_d, ps_d = path_to_root(t, leaf_d)

    do_ins = is_ins_op & fits
    do_del = found & ~underflow
    act = do_ins | do_del

    # --- write 1: the edited slot (insert target / swap-remove fill);
    # inactive rows scatter out of bounds and are dropped
    n1 = jnp.where(act, jnp.where(do_ins, leaf_i, leaf_d), N)
    s1 = jnp.where(do_ins, slot_i, slot_d)

    _flags = dict(mode="drop", unique_indices=True, indices_are_sorted=True)

    def w1(arr, ins_val):
        src = arr[leaf_d, last_d]
        return arr.at[n1, s1].set(jnp.where(do_ins, ins_val, src), **_flags)

    vecs = w1(t.vecs, x)
    pdist = w1(t.pdist, pd)
    oid_a = w1(t.oid, oid.astype(jnp.int32))
    valid = t.valid.at[n1, s1].set(True, **_flags)

    # --- write 2: clear the delete tail slot (after write 1, matching the
    # swap-remove order — handles slot == last)
    n2 = jnp.where(do_del, leaf_d, N)
    valid = valid.at[n2, last_d].set(False, **_flags)
    oid_a = oid_a.at[n2, last_d].set(-1, **_flags)

    delta = jnp.where(do_ins, 1, -1).astype(jnp.int32)
    count = t.count.at[n1].add(delta, **_flags)

    t = dataclasses.replace(t, vecs=vecs, pdist=pdist, oid=oid_a,
                            valid=valid, count=count)

    # --- radius fold along the touched path (no-op rows fold nothing)
    pn = jnp.where(do_ins, pn_i, jnp.where(do_del, pn_d, -1))
    ps = jnp.where(do_ins, ps_i, jnp.where(do_del, ps_d, -1))
    t = _refresh_path_radii(t, pn, ps)

    status = jnp.where(
        is_ins_op, jnp.where(fits, ST_APPLIED, ST_OVERFLOW),
        jnp.where(is_del_op,
                  jnp.where(found, jnp.where(underflow, ST_UNDERFLOW,
                                             ST_APPLIED), ST_NOTFOUND),
                  ST_NOP)).astype(jnp.int32)
    return t, status


def _locate_slots(tree: TreeArrays, oids: jax.Array):
    """Vectorised exact-id lookup at slot granularity: for each requested
    oid, the flat slot index ``node * cap + slot`` holding it (``N * cap``
    when absent) and a found mask.  One O(N·cap·log B) sorted-join pass
    replaces B sequential O(N·cap) table scans; first-hit semantics (lowest
    flat slot wins) match the scan the fast path used to do.  Requires the
    batch's oids to be unique (the conflict-free-cohort contract)."""
    B = oids.shape[0]
    N, cap = tree.oid.shape
    order = jnp.argsort(oids)
    sorted_oids = oids[order]
    pos = jnp.searchsorted(sorted_oids, tree.oid)            # [N, cap]
    pos_c = jnp.minimum(pos, B - 1)
    # negative requested oids never match: they are the NOP pad sentinel
    # (stream/batcher.py pads cohorts with oid = -1), and pads repeat — so
    # without this guard a sentinel-colliding stored id would break both the
    # uniqueness contract and the pad-rows-are-inert one
    match = ((sorted_oids[pos_c] == tree.oid) & tree.valid
             & (sorted_oids[pos_c] >= 0))
    row = jnp.where(match, order[pos_c], B)                  # B → dropped
    flat = jnp.arange(N * cap, dtype=jnp.int32).reshape(N, cap)
    first = jnp.full((B,), N * cap, jnp.int32).at[row].min(flat, mode="drop")
    return first, first < N * cap


def _locate_oids(tree: TreeArrays, oids: jax.Array):
    """Node-granularity wrapper over ``_locate_slots``: the node holding
    each requested oid, or -1 when absent."""
    _, cap = tree.oid.shape
    first, found = _locate_slots(tree, oids)
    return jnp.where(found, first // cap, -1).astype(jnp.int32), found


@jax.jit
def _extract_objects_impl(tree: TreeArrays, oids: jax.Array):
    N, cap = tree.oid.shape
    first, found = _locate_slots(tree, oids)
    flat_vecs = tree.vecs.reshape(N * cap, -1)
    idx = jnp.minimum(first, N * cap - 1)
    vecs = jnp.where(found[:, None], flat_vecs[idx], 0.0)
    return vecs.astype(jnp.float32), found


def extract_objects(tree: TreeArrays, oids):
    """Gather the stored vectors for a batch of object ids.

    oids: [B] int32, unique (conflict-free-cohort contract; -1 pads never
    match).  Returns (vecs [B, dim] f32, found [B] bool); rows whose id is
    not live in ``tree`` come back zero-filled with ``found`` False.  This
    is the read half of a migration step: the stream layer re-emits the
    extracted rows as a delete-on-donor / insert-on-receiver cohort, so a
    move rides the same jitted apply scan as any other mutation batch."""
    return _extract_objects_impl(tree, jnp.asarray(oids, jnp.int32))


def move_objects(donor: TreeArrays, receiver: TreeArrays, oids, *,
                 splits: bool = True, merges: bool = True):
    """Host reference for a batch move: re-home ``oids`` from ``donor``
    into ``receiver``.  Returns (donor, receiver, moved [B] bool).

    Order is insert-before-delete so a structural failure can only leave
    an object visible twice across the pair, never zero times; ids absent
    from the donor, or whose insert/delete escalation did not complete,
    report ``moved`` False and leave both trees consistent.  The streaming
    forest's migration steps use the same extract + cohort-apply shape but
    route through its batcher/mesh plumbing (stream/pipeline.py)."""
    oids = jnp.asarray(oids, jnp.int32)
    vecs, found = extract_objects(donor, oids)
    found = np.asarray(found)
    ins_ops = jnp.where(found, OP_INSERT, OP_NOP)
    ins_oids = jnp.where(found, oids, -1)
    receiver, st_i = apply_mutations(receiver, ins_ops, vecs, ins_oids,
                                     splits=splits, merges=merges)
    placed = found & np.isin(np.asarray(st_i), (ST_APPLIED, ST_SPLIT))
    del_ops = jnp.where(jnp.asarray(placed), OP_DELETE, OP_NOP)
    del_oids = jnp.where(jnp.asarray(placed), oids, -1)
    donor, st_d = apply_mutations(donor, del_ops, vecs, del_oids,
                                  splits=splits, merges=merges)
    moved = placed & np.isin(np.asarray(st_d), (ST_APPLIED, ST_MERGE))
    return donor, receiver, jnp.asarray(moved)


def _apply_mutations_impl(tree: TreeArrays, ops: jax.Array, xs: jax.Array,
                          oids: jax.Array):
    """One fused ``lax.scan`` over a mutation log: per row the branch-free
    insert/delete fast path (``_apply_row``) plus a status.

    The log must be a *conflict-free cohort* — no object id appears twice
    (deletes are pre-located against the pre-batch tree, which is only
    sound when no earlier row in the same batch touches the same id).  The
    stream batcher (repro.stream.batcher) cuts arbitrary logs into such
    cohorts.

    Rows the fast path cannot absorb leave the tree untouched and report
    ST_OVERFLOW / ST_UNDERFLOW / ST_NOTFOUND — the stream batcher escalates
    them to the host control plane.  The whole batch is one device dispatch
    with an in-place carry, which is where the throughput over a Python
    insert_fast/delete_fast loop comes from (benchmarks/bench_stream.py).
    """
    vecs0 = tree.vecs   # invariant routing pages (see _apply_row)
    leaf0, found0 = _locate_oids(tree, oids)

    def step(t, row):
        op, x, oid, l0, f0 = row
        return _apply_row(t, vecs0, op, x, oid, l0, f0)

    return jax.lax.scan(step, tree, (ops, xs, oids, leaf0, found0),
                        unroll=2)


@functools.cache
def _apply_mutations_jit(donate: bool):
    return jax.jit(_apply_mutations_impl,
                   donate_argnums=(0,) if donate else ())


def apply_mutations(tree: TreeArrays, ops, xs, oids, *,
                    donate: bool | None = None, splits: bool = True,
                    merges: bool = True):
    """Batched insert/delete apply.  Returns (tree, statuses [B] int32).

    ops: [B] int32 opcodes, xs: [B, dim] f32, oids: [B] int32.  Ops apply in
    log order; see ``_apply_mutations_impl`` for escalation statuses.  With
    ``donate`` (default: on accelerators) the input tree's buffers are
    donated — callers must treat the argument as consumed.

    With ``splits`` (default), overflow rows are resolved by the on-device
    split pass (``apply_splits``) before returning: the common single-level
    leaf split never leaves HBM, and such rows come back as ``ST_SPLIT``.
    With ``merges`` (default), underflow rows are then resolved by the
    on-device merge pass (``apply_merges``, rows come back ``ST_MERGE``) —
    but only when *no* ST_OVERFLOW row survived the split pass: the host
    reference (``escalate_rows``) resolves all overflows before any
    underflow, so a residual blocked overflow must reach the host first or
    the structure-edit order (and hence the bitwise tree) would diverge.
    The orchestration reads the status vector (a [B]-int sync the stream
    batcher pays anyway); in traced contexts (shard_map — where statuses
    are abstract) both flags are no-ops and the caller runs the
    collectives itself (``core.distributed.forest_apply_splits`` /
    ``forest_apply_merges``)."""
    if donate is None:
        donate = jax.default_backend() not in ("cpu",)
    ops = jnp.asarray(ops, jnp.int32)
    xs = jnp.asarray(xs, jnp.float32)
    oids = jnp.asarray(oids, jnp.int32)
    tree, status = _apply_mutations_jit(bool(donate))(tree, ops, xs, oids)
    if splits or merges:
        try:
            st_host = np.asarray(status)
        except (jax.errors.ConcretizationTypeError,
                jax.errors.TracerArrayConversionError):
            return tree, status
        dirty = 0
        # the post-scan tree is an exclusively-owned intermediate (callers
        # only ever see the final return), so the split/merge chain can
        # donate its buffers even where the scan itself must not (the scan
        # input is the caller's live tree, typically pinned by an epoch)
        if splits:
            tree, st_host, n_split = resolve_overflows(
                tree, ops, xs, oids, st_host, donate=True)
            dirty += n_split
        if merges and not (st_host == ST_OVERFLOW).any():
            tree, st_host, n_merge = resolve_underflows(
                tree, ops, oids, st_host, donate=True)
            dirty += n_merge
        if dirty:
            status = jnp.asarray(st_host)
    return tree, status


# --------------------------------------------------------------------------
# On-device node splits (the mesh-resident mutation control plane)
# --------------------------------------------------------------------------
def _promote_and_partition(t: TreeArrays, D, Radd, uvalid, n, min_side,
                           max_moves: int):
    """mM_RAD promotion + generalized-hyperplane partition of one pending
    entry set, decision-for-decision equal to core/split.py:minmax_split.

    D: [m, m] pairwise distances between the pending reference values;
    Radd: [m] the per-entry radius term of the radius scoring matrix
    C = D + Radd[None, :] (zeros for leaf sets); uvalid: [m] member mask —
    the split pass passes all-true (its pending set is exactly cap + 1
    rows), the merge pass's re-split passes ``arange(2*cap) < n`` for the
    dynamically-sized union of two nodes.  ``n``/``min_side`` may be
    traced; ``max_moves`` is a static upper bound on rebalance moves (the
    loop body no-ops once both sides meet min_side, so a loose bound only
    costs dead iterations).  Returns the slot layout both halves will be
    written with: (pi, pj, sel_i, sel_j, pres_i, pres_j, n_i, n_j, r_i,
    r_j), where sel_*/pres_* are [cap] member indices / occupancy masks in
    the exact member order the host's sequential ``_rebalance`` produces.
    """
    cap = t.capacity
    m = D.shape[0]
    # all ordered pairs in one fused 3-D reduction ([P, m] gather forms
    # cost ~25x more per scan step on XLA:CPU); the row-major argmin over
    # the masked upper triangle keeps the first minimal pair, matching
    # np.argmin over triu_indices exactly (padding sits at indices >= n,
    # so masking the j axis of the triangle drops every invalid pair).
    # Values are f32-identical to the host's f64-cast copies, so every
    # comparison agrees.
    Cmat = D + Radd[None, :]                                     # [m, m]
    toi3 = D[:, None, :] <= D[None, :, :]                        # [m, m, m]
    kval = uvalid[None, None, :]
    cand_ri = jnp.max(jnp.where(toi3 & kval, Cmat[:, None, :], -_INF),
                      axis=-1)
    cand_rj = jnp.max(jnp.where(~toi3 & kval, Cmat[None, :, :], -_INF),
                      axis=-1)
    cand_ri = jnp.where(jnp.isfinite(cand_ri), cand_ri, 0.0)
    cand_rj = jnp.where(jnp.isfinite(cand_rj), cand_rj, 0.0)
    triu = jnp.asarray(np.triu(np.ones((m, m), bool), k=1))
    best = jnp.argmin(jnp.where(
        triu & uvalid[None, :], jnp.maximum(cand_ri, cand_rj),
        _INF).reshape(-1))
    pi = (best // m).astype(jnp.int32)
    pj = (best % m).astype(jnp.int32)
    mask_i = (D[pi] <= D[pj]) & uvalid                           # [m]

    # sequential min-fill rebalance, order-exactly: host side lists are the
    # ascending initial members plus moved entries in move order (only one
    # of the two while-loops can run, so the donating side stays ascending
    # and argmin's first-minimal == Python min's first-minimal).  ``stamp``
    # encodes that order so argsort reproduces the host's slot layout.
    # (fori rather than unrolled: same runtime, ~1s less compile — and the
    # split scan's compile is the one-time cost every new tree geometry
    # pays.)
    Dpi = D[pi]
    Dpj = D[pj]

    def _rb(k, carry):
        mask, stamp = carry
        n_i = jnp.sum(mask)
        need_i = n_i < min_side
        need_j = (n - n_i) < min_side
        cand_i = jnp.argmin(
            jnp.where(mask | ~uvalid, _INF, Dpi)).astype(jnp.int32)
        cand_j = jnp.argmin(jnp.where(mask, Dpj, _INF)).astype(jnp.int32)
        mv = jnp.where(need_i, cand_i, cand_j)
        do = need_i | need_j
        mask = jnp.where(do, mask.at[mv].set(need_i), mask)
        stamp = jnp.where(do, stamp.at[mv].set(m + k), stamp)
        return mask, stamp

    mask_i, stamp = jax.lax.fori_loop(
        0, max_moves, _rb, (mask_i, jnp.arange(m, dtype=jnp.int32)))
    n_i = jnp.sum(mask_i).astype(jnp.int32)
    n_j = n - n_i
    BIG = jnp.int32(2 * m + 2)
    ord_i = jnp.argsort(jnp.where(mask_i, stamp, BIG))
    ord_j = jnp.argsort(jnp.where(mask_i | ~uvalid, BIG, stamp))
    slots = jnp.arange(cap, dtype=jnp.int32)
    sel_i = ord_i[:cap]      # n_i, n_j <= cap (min_side >= m - cap)
    sel_j = ord_j[:cap]
    pres_i = slots < n_i
    pres_j = slots < n_j
    r_i = jnp.max(jnp.where(pres_i, (Dpi + Radd)[sel_i], -_INF))
    r_j = jnp.max(jnp.where(pres_j, (Dpj + Radd)[sel_j], -_INF))
    return pi, pj, sel_i, sel_j, pres_i, pres_j, n_i, n_j, r_i, r_j


def _write_half(t: TreeArrays, row, V, R, C, O, Dp, sel, pres, n):
    """write_node equivalent: rewrite node ``row`` with the ``sel``-ordered
    members of the pending set.  Slots beyond the new count keep their
    stale vecs/radius/pdist exactly as the host's write_node leaves them
    (oid/child/valid tails are scrubbed), and each member child's
    parent/pslot pointers are re-aimed (leaf members have child -1 and
    drop out).  ``row`` may be out of bounds (masked no-op)."""
    N = t.max_nodes
    cap = t.capacity
    rc = jnp.minimum(row, N - 1)     # clamped gather source for stale keeps
    slots = jnp.arange(cap, dtype=jnp.int32)
    vecs = t.vecs.at[row].set(
        jnp.where(pres[:, None], V[sel], t.vecs[rc]), mode="drop")
    radius = t.radius.at[row].set(
        jnp.where(pres, R[sel], t.radius[rc]), mode="drop")
    pdist = t.pdist.at[row].set(
        jnp.where(pres, Dp[sel], t.pdist[rc]), mode="drop")
    child = t.child.at[row].set(jnp.where(pres, C[sel], -1), mode="drop")
    oid = t.oid.at[row].set(jnp.where(pres, O[sel], -1), mode="drop")
    valid = t.valid.at[row].set(pres, mode="drop")
    count = t.count.at[row].set(n, mode="drop")
    kids = jnp.where(pres & (row < N), C[sel], -1)
    kid_rows = jnp.where(kids >= 0, kids, N)
    parent = t.parent.at[kid_rows].set(jnp.minimum(row, N - 1), mode="drop",
                                       unique_indices=True)
    pslot = t.pslot.at[kid_rows].set(slots, mode="drop",
                                     unique_indices=True)
    return dataclasses.replace(t, vecs=vecs, radius=radius, pdist=pdist,
                               child=child, oid=oid, valid=valid,
                               count=count, parent=parent, pslot=pslot)


def _pop_free(t: TreeArrays, do):
    """Masked free-ring pop: allocates the same lowest free id the host's
    ``alloc`` picks (the ring is packed descending), scrubbing the popped
    slot so the packed representation matches the host recompute.  When
    ``do`` is False the ring is untouched (the returned id is garbage and
    must be dropped by the caller's masked writes)."""
    top = jnp.maximum(t.free_head - 1, 0)
    n2 = t.free_list[top]
    pos = jnp.where(do, top, t.max_nodes)
    free_list = t.free_list.at[pos].set(-1, mode="drop")
    inc = do.astype(jnp.int32)
    return dataclasses.replace(
        t, free_list=free_list, free_head=t.free_head - inc,
        n_nodes=jnp.where(do, jnp.maximum(t.n_nodes, n2 + 1),
                          t.n_nodes)), n2


def _push_free(t: TreeArrays, f, do):
    """Masked free-ring push: insert node id ``f`` at its *descending-
    sorted* position, not on top of the stack.  The ring's contract is
    ``free_list[:free_head] == packed_free_list(alive)`` — the dead ids in
    descending order, so the top of the stack is the lowest free id and a
    device pop allocates exactly what the host's ``alloc`` would.  A plain
    LIFO push of a freed id would break that the moment a later split pops
    it back while a lower id sits buried below; the sorted insert keeps the
    packed representation bitwise-equal to the host's wholesale recompute
    in ``to_tree``.  O(N) masked shift per push — merges free at most
    O(height) nodes per row, and N-sized masked moves are exactly what the
    rest of the pass does anyway."""
    N = t.max_nodes
    fl = t.free_list
    idx = jnp.arange(N, dtype=jnp.int32)
    live = idx < t.free_head
    pos = jnp.sum((live & (fl > f)).astype(jnp.int32))
    shifted = fl[jnp.maximum(idx - 1, 0)]
    newfl = jnp.where(idx < pos, fl,
                      jnp.where(idx == pos, f, shifted))
    inc = do.astype(jnp.int32)
    return dataclasses.replace(
        t, free_list=jnp.where(do, newfl, fl), free_head=t.free_head + inc)


def _split_row(t: TreeArrays, op, x, oid, blocked):
    """One overflow insert resolved on device: the scan body of
    ``apply_splits``.

    Bitwise-faithful to the host escalation
    (``_HostView.insert_with_split``) in every case:

      * re-descend from the root on the *live* tree and re-check occupancy
        — earlier rows in this pass may have freed space or changed
        routing — and plain-append when the leaf has room;
      * otherwise run the full multi-level split loop: mM_RAD promotion
        with minmax_split's exact tie-breaks and member order, free-ring
        allocation (the same lowest-free-id the host's alloc picks),
        parent entry replacement + append, pending-set splice on parent
        overflow, and on-device root growth.

    Escalation ladder: only a near-empty free ring (the host would have to
    ``_grow`` the node table, a resize no fixed-shape kernel can do)
    blocks the row — and, to preserve log order, every later overflow row
    in the pass; merges (delete underflow) remain host-side.

    Shaped like ``_apply_row``: straight-line masked updates, no
    cond/switch on tree state — on XLA:CPU a conditional returning the
    tree copies every array at the branch boundary, which at production
    node counts costs more than the split itself.  Inactive rows enter the
    split loop with ``done`` already set, so they pay zero iterations.
    """
    cap = t.capacity
    N = t.max_nodes
    want = (op == OP_INSERT) & ~blocked
    pn, ps, leaf = _descend_path(t, x)
    cnt = t.count[leaf]
    has_room = cnt < cap
    # worst case allocs: one split per level + a root growth
    can_split = (~has_room) & (t.free_head >= t.height + 1)
    do_append = want & has_room
    do_split = want & can_split
    ok = do_append | do_split
    blocked = blocked | (want & ~ok)

    # --- append case: the host's re-check branch (append_entry + fold_up)
    parentL = t.parent[leaf]
    has_parent = parentL >= 0
    pvec = t.vecs[jnp.maximum(parentL, 0), jnp.maximum(t.pslot[leaf], 0)]
    pd_app = jnp.where(has_parent, _metric_eval(t.metric, x, pvec), 0.0)
    na = jnp.where(do_append, leaf, N)
    sa = jnp.minimum(cnt, cap - 1)
    _fl = dict(mode="drop", unique_indices=True)
    t = dataclasses.replace(
        t,
        vecs=t.vecs.at[na, sa].set(x, **_fl),
        # explicit 0.0 (not elided as in _apply_row): a leaf reusing an
        # ex-internal freed slot can carry stale nonzero radius beyond its
        # count, and the host path writes the zero
        radius=t.radius.at[na, sa].set(0.0, **_fl),
        pdist=t.pdist.at[na, sa].set(pd_app, **_fl),
        oid=t.oid.at[na, sa].set(oid.astype(jnp.int32), **_fl),
        valid=t.valid.at[na, sa].set(True, **_fl),
        count=t.count.at[na].add(1, **_fl))

    # --- split case: the host's overflow loop as a bounded while_loop.
    # Each iteration splits the pending set across the reused node and a
    # fresh allocation, then installs the promoted pair in the parent
    # (done), splices the full parent and ascends, or grows a new root
    # (done).  R carries the node's *stored* radius row — semantically
    # zero at leaves, but _apply_row elides leaf radius writes, so stale
    # nonzero values survive there and the host's write_node permutes
    # them; copying the row keeps the split bitwise-faithful.
    state = dict(
        t=t,
        V=jnp.concatenate([t.vecs[leaf], x[None, :]], axis=0),
        R=jnp.concatenate([t.radius[leaf], jnp.zeros((1,), jnp.float32)]),
        C=jnp.concatenate([t.child[leaf],
                           jnp.full((1,), -1, jnp.int32)]),
        O=jnp.concatenate([t.oid[leaf],
                           jnp.reshape(oid.astype(jnp.int32), (1,))]),
        pend_leaf=jnp.asarray(True),
        cur=leaf,
        done=~do_split,
        grew_root=jnp.asarray(False),
    )

    def cond_fn(s):
        return ~s["done"]

    def body(s):
        t = s["t"]
        V, R, C, O = s["V"], s["R"], s["C"], s["O"]
        cur = s["cur"]
        D = _metric_eval(t.metric, V[:, None, :], V[None, :, :])
        Radd = jnp.where(s["pend_leaf"], jnp.zeros_like(R), R)
        from repro.core.split import min_side_for
        ms = min_side_for(cap + 1, cap, t.min_fill)
        (pi, pj, sel_i, sel_j, pres_i, pres_j, n_i, n_j, r_i,
         r_j) = _promote_and_partition(
            t, D, Radd, jnp.ones((cap + 1,), bool), cap + 1, ms,
            max_moves=ms)

        parent = t.parent[cur]          # read before any pointer writes
        pslot_c = jnp.maximum(t.pslot[cur], 0)
        is_root = parent < 0
        p_n = jnp.maximum(parent, 0)

        t, n2 = _pop_free(t, jnp.asarray(True))
        t = _write_half(t, cur, V, R, C, O, D[pi], sel_i, pres_i, n_i)
        t = _write_half(t, n2, V, R, C, O, D[pj], sel_j, pres_j, n_j)
        t = dataclasses.replace(
            t, alive=t.alive.at[n2].set(True),
            is_leaf=t.is_leaf.at[n2].set(s["pend_leaf"]))

        # --- parent present: replace the entry pointing at cur with
        # promoted i (the pending splice below must see this write)
        gp = t.parent[p_n]
        gv = t.vecs[jnp.maximum(gp, 0), jnp.maximum(t.pslot[p_n], 0)]
        has_gp = gp >= 0
        pd_i = jnp.where(has_gp, _metric_eval(t.metric, V[pi], gv), 0.0)
        pd_j = jnp.where(has_gp, _metric_eval(t.metric, V[pj], gv), 0.0)
        rowP = jnp.where(is_root, N, p_n)
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowP, pslot_c].set(V[pi], **_fl),
            radius=t.radius.at[rowP, pslot_c].set(r_i, **_fl),
            pdist=t.pdist.at[rowP, pslot_c].set(pd_i, **_fl),
            child=t.child.at[rowP, pslot_c].set(cur, **_fl))

        # --- parent has room: append promoted j, terminal
        parent_room = t.count[p_n] < cap
        app = ~is_root & parent_room
        ap = t.count[p_n]
        apc = jnp.minimum(ap, cap - 1)
        rowA = jnp.where(app, p_n, N)
        rowA2 = jnp.where(app, n2, N)
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowA, apc].set(V[pj], **_fl),
            radius=t.radius.at[rowA, apc].set(r_j, **_fl),
            pdist=t.pdist.at[rowA, apc].set(pd_j, **_fl),
            child=t.child.at[rowA, apc].set(n2, **_fl),
            oid=t.oid.at[rowA, apc].set(-1, **_fl),
            valid=t.valid.at[rowA, apc].set(True, **_fl),
            count=t.count.at[rowA].add(1, **_fl),
            parent=t.parent.at[rowA2].set(p_n, **_fl),
            pslot=t.pslot.at[rowA2].set(ap, **_fl))

        # --- no parent: grow a new root (host: alloc + two append_entry
        # calls — slots 0/1 written, vecs/radius/pdist beyond stay stale)
        t, nr = _pop_free(t, is_root)
        nrc = jnp.minimum(nr, N - 1)
        rowR = jnp.where(is_root, nrc, N)
        two = jnp.arange(cap) < 2
        slot01 = jnp.where(jnp.arange(cap) == 0, cur, n2)
        rowRc = jnp.where(is_root, cur, N)
        rowRn = jnp.where(is_root, n2, N)
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowR, 0].set(V[pi], **_fl),
            radius=t.radius.at[rowR, 0].set(r_i, **_fl),
            pdist=t.pdist.at[rowR, 0].set(0.0, **_fl))
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowR, 1].set(V[pj], **_fl),
            radius=t.radius.at[rowR, 1].set(r_j, **_fl),
            pdist=t.pdist.at[rowR, 1].set(0.0, **_fl),
            child=t.child.at[rowR].set(jnp.where(two, slot01, -1),
                                       mode="drop"),
            oid=t.oid.at[rowR].set(jnp.full((cap,), -1, jnp.int32),
                                   mode="drop"),
            valid=t.valid.at[rowR].set(two, mode="drop"),
            count=t.count.at[rowR].set(2, mode="drop"),
            is_leaf=t.is_leaf.at[rowR].set(False, mode="drop"),
            alive=t.alive.at[rowR].set(True, mode="drop"),
            parent=(t.parent.at[rowR].set(-1, mode="drop")
                    .at[rowRc].set(nrc, **_fl).at[rowRn].set(nrc, **_fl)),
            pslot=(t.pslot.at[rowR].set(-1, mode="drop")
                   .at[rowRc].set(0, **_fl).at[rowRn].set(1, **_fl)),
            root=jnp.where(is_root, nrc, t.root),
            height=t.height + is_root.astype(jnp.int32))

        # --- parent full: splice its (post-replacement) entries + promoted
        # j as the next pending set and ascend; n2's parent pointer is
        # fixed by the next level's _write_half, exactly like the host
        splice = ~is_root & ~parent_room
        V2 = jnp.concatenate([t.vecs[p_n], V[pj][None, :]], axis=0)
        R2 = jnp.concatenate([t.radius[p_n], r_j[None]])
        C2 = jnp.concatenate([t.child[p_n], n2[None]])
        O2 = jnp.concatenate([t.oid[p_n], jnp.full((1,), -1, jnp.int32)])
        return dict(
            t=t,
            V=jnp.where(splice, V2, V),
            R=jnp.where(splice, R2, R),
            C=jnp.where(splice, C2, C),
            O=jnp.where(splice, O2, O),
            pend_leaf=s["pend_leaf"] & ~splice,
            cur=jnp.where(splice, p_n, cur),
            done=~splice,
            grew_root=is_root,
        )

    s = jax.lax.while_loop(cond_fn, body, state)
    t = s["t"]

    # --- radius fold: the append case folds the descent path; a split that
    # ended in a parent append folds from the last split node (the host's
    # fold_up(cur)); root growth folds nothing (promoted radii are exact).
    # Non-fold rows climb from the root so the walk exits immediately.
    fold_split = do_split & ~s["grew_root"]
    pn2, ps2 = path_to_root(t, jnp.where(fold_split, s["cur"], t.root))
    pn_f = jnp.where(do_append, pn, jnp.where(fold_split, pn2, -1))
    ps_f = jnp.where(do_append, ps, jnp.where(fold_split, ps2, -1))
    t = _refresh_path_radii(t, pn_f, ps_f)

    status = jnp.where(ok, ST_SPLIT,
                       jnp.where(op == OP_INSERT, ST_OVERFLOW, ST_NOP))
    return t, status.astype(jnp.int32), blocked


def _apply_splits_impl(tree: TreeArrays, ops: jax.Array, xs: jax.Array,
                       oids: jax.Array):
    def step(carry, row):
        t, blocked = carry
        op, x, oid = row
        t, st, blocked = _split_row(t, op, x, oid, blocked)
        return (t, blocked), st

    (tree, _), st = jax.lax.scan(step, (tree, jnp.zeros((), bool)),
                                 (ops, xs, oids))
    return tree, st


@functools.cache
def _apply_splits_jit(donate: bool):
    return jax.jit(_apply_splits_impl,
                   donate_argnums=(0,) if donate else ())


def apply_splits(tree: TreeArrays, ops, xs, oids, *,
                 donate: bool | None = None):
    """On-device split pass over a compacted batch of overflow inserts.

    ops/xs/oids: [K] rows previously reported ST_OVERFLOW by
    ``apply_mutations`` (pad with OP_NOP / oid -1 / zero vecs), in log
    order.  Returns (tree, statuses [K]): ST_SPLIT for rows resolved on
    device, ST_OVERFLOW for rows needing the host control plane (multi-level
    or root splits, or an empty free ring — and, to preserve log order,
    every row after the first such failure), ST_NOP for pads."""
    if donate is None:
        donate = jax.default_backend() not in ("cpu",)
    ops = jnp.asarray(ops, jnp.int32)
    xs = jnp.asarray(xs, jnp.float32)
    oids = jnp.asarray(oids, jnp.int32)
    return _apply_splits_jit(bool(donate))(tree, ops, xs, oids)


# Fixed dispatch width for the split pass: exactly ONE jit entry per tree
# geometry.  A per-count bucket ladder halves the padded-NOP waste but
# multiplies the (seconds-scale) split-scan compile by the ladder depth,
# which dominates every realistic serving window.
SPLIT_CHUNK = 8


def split_chunks(n: int):
    """Fixed-width cover of ``n`` rows (the last chunk padded by the
    dispatcher)."""
    return [SPLIT_CHUNK] * ((n + SPLIT_CHUNK - 1) // SPLIT_CHUNK)


def resolve_overflows(tree: TreeArrays, ops, xs, oids, statuses, *,
                      donate: bool | None = None):
    """Compact a batch's ST_OVERFLOW rows and run the device split pass.

    statuses: [B] int32 on the host.  Returns (tree, statuses, n_resolved)
    with resolved rows re-marked ST_SPLIT.  The compaction keeps log order
    and dispatches power-of-two-ladder scans (``split_chunks``); a chunk
    reporting a blocked row stops the chunk loop, so the residual rows
    reach the host in log order exactly as if a single scan had processed
    the whole set.  Tree data never leaves the device — only the tiny
    status vector does, and callers (the stream batcher) sync that anyway
    to drive escalation."""
    statuses = np.asarray(statuses)
    ops_np = np.asarray(ops)
    idx = np.nonzero((statuses == ST_OVERFLOW) & (ops_np == OP_INSERT))[0]
    if not len(idx):
        return tree, statuses, 0
    xs_np = np.asarray(xs, np.float32)
    oids_np = np.asarray(oids, np.int32)
    out = statuses.copy()
    n_resolved = 0
    c0 = 0
    for w in split_chunks(len(idx)):
        chunk = idx[c0:c0 + w]
        c0 += w
        k = len(chunk)
        ops_k = np.full(w, OP_NOP, np.int32)
        ops_k[:k] = OP_INSERT
        xs_k = np.zeros((w, xs_np.shape[1]), np.float32)
        xs_k[:k] = xs_np[chunk]
        oids_k = np.full(w, -1, np.int32)
        oids_k[:k] = oids_np[chunk]
        tree, st = apply_splits(tree, ops_k, xs_k, oids_k, donate=donate)
        st = np.asarray(jax.device_get(st))[:k]
        out[chunk[st == ST_SPLIT]] = ST_SPLIT
        n_resolved += int((st == ST_SPLIT).sum())
        if (st == ST_OVERFLOW).any():
            break   # blocked: the rest goes to the host in log order
    return tree, out, n_resolved


# --------------------------------------------------------------------------
# On-device node merges (delete underflow — the symmetric half of the
# mesh-resident mutation control plane)
# --------------------------------------------------------------------------
def _remove_entry_masked(t: TreeArrays, node, s, do):
    """Host ``remove_entry`` as masked writes: swap-remove slot ``s`` of
    ``node`` (the last entry fills the hole; a swapped *internal* child's
    pslot is re-aimed), clear the tail slot, decrement count.  Write
    ordering handles ``s == last`` exactly like ``_apply_row``'s delete;
    everything drops when ``do`` is False."""
    N = t.max_nodes
    _fl = dict(mode="drop", unique_indices=True)
    nc = jnp.minimum(jnp.maximum(node, 0), N - 1)
    last = jnp.maximum(t.count[nc] - 1, 0)
    row = jnp.where(do, nc, N)
    t = dataclasses.replace(
        t,
        vecs=t.vecs.at[row, s].set(t.vecs[nc, last], **_fl),
        radius=t.radius.at[row, s].set(t.radius[nc, last], **_fl),
        pdist=t.pdist.at[row, s].set(t.pdist[nc, last], **_fl),
        child=t.child.at[row, s].set(t.child[nc, last], **_fl),
        oid=t.oid.at[row, s].set(t.oid[nc, last], **_fl))
    # swapped child's pslot: host skips when s == last (the entry at s IS
    # the removed one) and at leaves (child -1 drops the write anyway)
    c_sw = t.child[nc, last]
    sw_do = do & (s != last) & ~t.is_leaf[nc] & (c_sw >= 0)
    t = dataclasses.replace(
        t, pslot=t.pslot.at[jnp.where(sw_do, c_sw, N)].set(s, **_fl))
    return dataclasses.replace(
        t,
        valid=t.valid.at[row, last].set(False, **_fl),
        child=t.child.at[row, last].set(-1, **_fl),
        oid=t.oid.at[row, last].set(-1, **_fl),
        count=t.count.at[row].add(-1, **_fl))


def _free_node_masked(t: TreeArrays, node, do):
    """Host ``free`` as masked writes: alive/valid cleared, count zeroed,
    parent/pslot detached — vecs/radius/pdist/child/oid stay *stale*,
    exactly as the host leaves them (``alloc`` scrubs on reuse) — plus the
    sorted free-ring push."""
    N = t.max_nodes
    cap = t.capacity
    row = jnp.where(do, node, N)
    t = dataclasses.replace(
        t,
        alive=t.alive.at[row].set(False, mode="drop"),
        valid=t.valid.at[row].set(jnp.zeros((cap,), bool), mode="drop"),
        count=t.count.at[row].set(0, mode="drop"),
        parent=t.parent.at[row].set(-1, mode="drop"),
        pslot=t.pslot.at[row].set(-1, mode="drop"))
    return _push_free(t, node, do)


def _merge_row(t: TreeArrays, op, oid):
    """One underflow delete resolved on device: the scan body of
    ``apply_merges``, bitwise-faithful to ``_HostView.delete_with_merge``:

      * re-locate the object on the *live* tree (earlier rows in this pass
        may have moved entries across nodes) with the host's first-hit
        (row-major) semantics, and swap-remove it from its leaf;
      * propagate underflow as a bounded while_loop: pick the nearest
        sibling by routing-object distance (first-minimal, self excluded),
        then either **merge** into it (total fits: ordered appends, free
        the donor onto the ring at its sorted position, swap-remove the
        parent entry, refresh the sibling entry's radius) or
        **redistribute** (re-split the union with minmax_split's exact
        promotion/member order across the same two nodes, parent entries
        rewritten in place);
      * fold radii up the final node's parent chain and collapse
        single-entry internal roots on device, freeing each onto the ring.

    Merges only ever *free* nodes, so — unlike the split pass — no row can
    block on ring exhaustion: the device absorbs every underflow.  Same
    shape discipline as ``_split_row``: straight-line masked ``mode="drop"``
    writes, no cond/switch on tree state."""
    cap = t.capacity
    N = t.max_nodes
    _fl = dict(mode="drop", unique_indices=True)
    want = op == OP_DELETE
    # negative oids (the NOP pad sentinel) never match, mirroring
    # delete_fast — a pad row in a merge chunk must be inert even against
    # a (boundary-rejected, but defence-in-depth) planted -1 entry
    hit = (t.oid == oid) & t.valid & (oid >= 0)
    found = want & jnp.any(hit)
    flat = jnp.argmax(hit.reshape(-1))
    leaf = (flat // cap).astype(jnp.int32)
    slot = (flat % cap).astype(jnp.int32)
    t = _remove_entry_masked(t, leaf, slot, found)

    def cond_fn(s):
        return s["go"]

    def body(s):
        t = s["t"]
        cur = s["cur"]
        parent = t.parent[cur]          # >= 0: the loop excludes the root
        p = jnp.maximum(parent, 0)
        islot = jnp.maximum(t.pslot[cur], 0)
        m = t.count[p]
        slots = jnp.arange(cap, dtype=jnp.int32)
        # nearest sibling entry by routing-object distance; invalid slots
        # and self are +inf, so argmin's first-minimal matches the host's
        # argmin over d[:m] with d[islot] = inf (f64 casts of f32 values
        # compare identically)
        d = _metric_eval(t.metric, t.vecs[p, islot][None, :], t.vecs[p])
        d = jnp.where((slots < m) & (slots != islot), d, _INF)
        j = jnp.argmin(d).astype(jnp.int32)
        sib = t.child[p, j]
        sb = jnp.maximum(sib, 0)
        cm = t.count[cur]
        ns = t.count[sb]
        total = ns + cm
        do_merge = total <= cap

        # ---- merge branch: append cur's entries to sib in slot order
        # (the host's append_entry loop), free cur, swap-remove the parent
        # entry, refresh the sibling entry's covering radius
        sv = t.vecs[p, j]
        pd_m = _metric_eval(t.metric, t.vecs[cur], sv[None, :])   # [cap]
        rowM = jnp.where(do_merge, sb, N)
        # targets of valid members stay < cap (total <= cap here); masked
        # rows land at cap + k — distinct and all dropped
        tgt = jnp.where(slots < cm, ns + slots, cap + slots)
        kids = t.child[cur]
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowM, tgt].set(t.vecs[cur], **_fl),
            radius=t.radius.at[rowM, tgt].set(t.radius[cur], **_fl),
            pdist=t.pdist.at[rowM, tgt].set(pd_m, **_fl),
            child=t.child.at[rowM, tgt].set(kids, **_fl),
            oid=t.oid.at[rowM, tgt].set(t.oid[cur], **_fl),
            valid=t.valid.at[rowM, tgt].set(True, **_fl),
            count=t.count.at[rowM].add(cm, **_fl))
        kidrow = jnp.where(do_merge & (slots < cm) & (kids >= 0), kids, N)
        t = dataclasses.replace(
            t,
            parent=t.parent.at[kidrow].set(sb, **_fl),
            pslot=t.pslot.at[kidrow].set(ns + slots, **_fl))
        t = _free_node_masked(t, cur, do_merge)
        t = _remove_entry_masked(t, p, islot, do_merge)
        # islot removal may have moved entry j — re-read sib's live pslot
        jj = jnp.maximum(t.pslot[sb], 0)
        contrib = t.pdist[sb] + jnp.where(t.is_leaf[sb], 0.0, t.radius[sb])
        fr = jnp.max(jnp.where(t.valid[sb], contrib, -_INF))
        t = dataclasses.replace(
            t, radius=t.radius.at[jnp.where(do_merge, p, N), jj].set(
                fr, **_fl))

        # ---- redistribute branch: re-split the union of sib + cur across
        # the same two nodes (no alloc, no free).  All merge-branch writes
        # above dropped in this case, so the reads below see the pre-branch
        # state.  The union is dynamically sized (cap < total <= 2*cap):
        # sib's entries first, then cur's — the host's vstack order.
        do_rs = ~do_merge
        M = 2 * cap
        ks = jnp.arange(M, dtype=jnp.int32)
        in_sib = ks < ns
        src_row = jnp.where(in_sib, sb, cur)
        src_slot = jnp.clip(jnp.where(in_sib, ks, ks - ns), 0, cap - 1)
        V = t.vecs[src_row, src_slot]
        R = t.radius[src_row, src_slot]
        C = t.child[src_row, src_slot]
        O = t.oid[src_row, src_slot]
        uvalid = ks < total
        D = _metric_eval(t.metric, V[:, None, :], V[None, :, :])
        Radd = jnp.where(t.is_leaf[cur], jnp.zeros_like(R), R)
        # min_side_for, with the dynamic member count: the total - cap
        # term guarantees neither side overflows
        min_side = jnp.maximum(
            2, jnp.maximum(jnp.minimum(t.min_fill, total // 2),
                           total - cap))
        (pi, pj, sel_i, sel_j, pres_i, pres_j, n_i, n_j, r_i,
         r_j) = _promote_and_partition(t, D, Radd, uvalid, total, min_side,
                                       max_moves=cap)
        t = _write_half(t, jnp.where(do_rs, sb, N), V, R, C, O, D[pi],
                        sel_i, pres_i, n_i)
        t = _write_half(t, jnp.where(do_rs, cur, N), V, R, C, O, D[pj],
                        sel_j, pres_j, n_j)
        gp = t.parent[p]
        gv = t.vecs[jnp.maximum(gp, 0), jnp.maximum(t.pslot[p], 0)]
        has_gp = gp >= 0
        pd_i = jnp.where(has_gp, _metric_eval(t.metric, V[pi], gv), 0.0)
        pd_j = jnp.where(has_gp, _metric_eval(t.metric, V[pj], gv), 0.0)
        rowP = jnp.where(do_rs, p, N)
        rowPs = jnp.where(do_rs, sb, N)
        rowPc = jnp.where(do_rs, cur, N)
        t = dataclasses.replace(
            t,
            vecs=t.vecs.at[rowP, j].set(V[pi], **_fl)
                       .at[rowP, islot].set(V[pj], **_fl),
            radius=t.radius.at[rowP, j].set(r_i, **_fl)
                           .at[rowP, islot].set(r_j, **_fl),
            pdist=t.pdist.at[rowP, j].set(pd_i, **_fl)
                         .at[rowP, islot].set(pd_j, **_fl),
            child=t.child.at[rowP, j].set(sb, **_fl)
                         .at[rowP, islot].set(cur, **_fl),
            parent=t.parent.at[rowPs].set(p, **_fl)
                           .at[rowPc].set(p, **_fl),
            pslot=t.pslot.at[rowPs].set(j, **_fl)
                         .at[rowPc].set(islot, **_fl))

        go = (p != t.root) & (t.count[p] < t.min_fill)
        return dict(t=t, cur=p, go=go)

    s = jax.lax.while_loop(
        cond_fn, body,
        dict(t=t, cur=leaf,
             go=found & (leaf != t.root) & (t.count[leaf] < t.min_fill)))
    t = s["t"]

    # fold_up(cur): recompute radii along the final node's parent chain
    # (not-found rows climb from the root, an empty chain)
    pnF, psF = path_to_root(t, jnp.where(found, s["cur"], t.root))
    t = _refresh_path_radii(t, pnF, psF)

    # root collapse: free single-entry internal roots onto the ring (the
    # host loop, including multi-level collapse after deep cascades)
    def rc_cond(s2):
        return s2["go"]

    def rc_body(s2):
        t = s2["t"]
        old = t.root
        newr = t.child[old, 0]
        t = dataclasses.replace(
            t, root=newr, height=t.height - 1,
            parent=t.parent.at[newr].set(-1),
            pslot=t.pslot.at[newr].set(-1))
        t = _free_node_masked(t, old, jnp.asarray(True))
        return dict(t=t, go=~t.is_leaf[t.root] & (t.count[t.root] == 1))

    s2 = jax.lax.while_loop(
        rc_cond, rc_body,
        dict(t=t, go=found & ~t.is_leaf[t.root] & (t.count[t.root] == 1)))
    t = s2["t"]

    status = jnp.where(want, jnp.where(found, ST_MERGE, ST_NOTFOUND),
                       ST_NOP).astype(jnp.int32)
    return t, status


def _apply_merges_impl(tree: TreeArrays, ops: jax.Array, oids: jax.Array):
    def step(t, row):
        op, oid = row
        return _merge_row(t, op, oid)

    return jax.lax.scan(step, tree, (ops, oids))


@functools.cache
def _apply_merges_jit(donate: bool):
    return jax.jit(_apply_merges_impl,
                   donate_argnums=(0,) if donate else ())


def apply_merges(tree: TreeArrays, ops, oids, *,
                 donate: bool | None = None):
    """On-device merge pass over a compacted batch of underflow deletes.

    ops/oids: [K] rows previously reported ST_UNDERFLOW by
    ``apply_mutations`` (pad with OP_NOP / oid -1), in log order.  Returns
    (tree, statuses [K]): ST_MERGE for resolved rows, ST_NOTFOUND for
    targets that vanished (cannot happen inside a conflict-free cohort,
    kept for the host path's semantics), ST_NOP for pads.  Merges never
    allocate, so — unlike ``apply_splits`` — no row ever blocks."""
    if donate is None:
        donate = jax.default_backend() not in ("cpu",)
    ops = jnp.asarray(ops, jnp.int32)
    oids = jnp.asarray(oids, jnp.int32)
    return _apply_merges_jit(bool(donate))(tree, ops, oids)


# Dispatch widths for the merge pass.  Unlike the split ladder (one fixed
# SPLIT_CHUNK entry, because a blocked row forces a host decision between
# chunks), merge chunks dispatch back-to-back with no intervening sync —
# so per-dispatch overhead, not padded-NOP waste, dominates bulk
# underflow batches (delete-heavy streams routinely underflow ~25% of a
# 256-row cohort).  Two widths bound the jit cache at two entries per
# geometry: the bulk width swallows big runs in one dispatch, the small
# width keeps sparse batches (the common case) from paying 56 NOP rows.
MERGE_CHUNK = 8
MERGE_CHUNK_MAX = 64


def merge_chunks(n: int):
    """Dispatch-width cover of ``n`` rows (each chunk padded by the
    dispatcher).  Full MERGE_CHUNK_MAX chunks, then either one more MAX
    chunk (when the remainder would need >2 small dispatches — overhead
    beats pad waste) or small chunks."""
    out = []
    while n >= MERGE_CHUNK_MAX:
        out.append(MERGE_CHUNK_MAX)
        n -= MERGE_CHUNK_MAX
    if n > 2 * MERGE_CHUNK:
        out.append(MERGE_CHUNK_MAX)
        n = 0
    while n > 0:
        out.append(MERGE_CHUNK)
        n -= MERGE_CHUNK
    return out


def resolve_underflows(tree: TreeArrays, ops, oids, statuses, *,
                       donate: bool | None = None):
    """Compact a batch's ST_UNDERFLOW rows and run the device merge pass.

    statuses: [B] int32 on the host.  Returns (tree, statuses, n_resolved)
    with resolved rows re-marked ST_MERGE.  Callers must only invoke this
    once no ST_OVERFLOW rows remain (the host reference resolves *all*
    overflows before *any* underflow — ``escalate_rows`` — and the device
    path must replay the same structure-edit order to stay bitwise-
    transparent); ``apply_mutations``/the stream pipeline enforce that."""
    statuses = np.asarray(statuses)
    ops_np = np.asarray(ops)
    idx = np.nonzero((statuses == ST_UNDERFLOW) & (ops_np == OP_DELETE))[0]
    if not len(idx):
        return tree, statuses, 0
    oids_np = np.asarray(oids, np.int32)
    out = statuses.copy()
    c0 = 0
    pending = []
    # dispatch every chunk back-to-back and sync the statuses once at the
    # end: merges never block (unlike the split ladder, which must stop at
    # the first blocked chunk), so there is no decision to make between
    # chunks and no reason to stall the dispatch queue on a host
    # round-trip per chunk
    for w in merge_chunks(len(idx)):
        chunk = idx[c0:c0 + w]
        c0 += w
        k = len(chunk)
        ops_k = np.full(w, OP_NOP, np.int32)
        ops_k[:k] = OP_DELETE
        oids_k = np.full(w, -1, np.int32)
        oids_k[:k] = oids_np[chunk]
        tree, st = apply_merges(tree, ops_k, oids_k, donate=donate)
        pending.append((chunk, k, st))
    for chunk, k, st in pending:
        out[chunk] = np.asarray(jax.device_get(st))[:k]
    return tree, out, len(idx)


# --------------------------------------------------------------------------
# Ahead-of-time free-ring headroom (node-table growth off the hot path)
# --------------------------------------------------------------------------
def needs_headroom(tree: TreeArrays, *, frac: float = 1 / 16) -> bool:
    """True when the free ring is low enough that a mutation batch could
    plausibly exhaust it mid-pass (the one split-path escalation left).
    The watermark is ``frac`` of the node table, floored at MAX_HEIGHT + 1
    — the worst case a *single* overflow row can allocate — so growth
    always fires before a row can block.  Syncs one scalar."""
    wm = max(MAX_HEIGHT + 1, int(tree.max_nodes * frac))
    return int(jax.device_get(tree.free_head)) < wm


def grow_tree(tree: TreeArrays, *, factor: int = 2) -> TreeArrays:
    """Host-side node-table growth: pad every [N, ...] leaf to
    ``factor * max_nodes`` dead rows (the host ``_HostView._grow`` layout:
    child/oid/parent/pslot pad to -1, is_leaf to True) and recompute the
    packed free ring.  The new ids are the *highest*, so they join the
    descending ring at the bottom and every pre-growth allocation decision
    is unchanged — growth is behaviour-transparent to the mutation order.

    This is the ahead-of-time escape from the last host escalation: the
    stream pipelines call it at snapshot/rebalance/epoch-publish points
    when ``needs_headroom`` fires, so ring exhaustion stops being a
    mid-batch event at all.  Changes array shapes (one recompile per new
    geometry — the cost doubling amortises away)."""
    if factor < 2:
        raise ValueError(f"factor must be >= 2, got {factor}")
    N = tree.max_nodes
    pad_n = N * (factor - 1)
    fields = {}
    alive_np = None
    for name in ("vecs", "radius", "pdist", "child", "oid", "valid",
                 "count", "is_leaf", "alive", "parent", "pslot"):
        a = np.asarray(jax.device_get(getattr(tree, name)))
        pad = np.zeros((pad_n,) + a.shape[1:], a.dtype)
        if name in ("child", "oid", "parent", "pslot"):
            pad -= 1
        if name == "is_leaf":
            pad |= True
        a = np.concatenate([a, pad], axis=0)
        if name == "alive":
            alive_np = a
        fields[name] = jnp.asarray(a)
    free_list, free_head = packed_free_list(alive_np)
    return dataclasses.replace(
        tree, **fields, free_list=jnp.asarray(free_list),
        free_head=jnp.asarray(free_head), max_nodes=N * factor)
