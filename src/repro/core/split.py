"""Split policies for (S)M-tree nodes — shared by the numpy reference
implementation and the JAX engine's host-side structure maintenance.

``minmax_split`` is the original M-tree's mM_RAD promotion (try every pair of
entries as the promoted routing objects; minimise the larger covering radius)
with generalized-hyperplane distribution and a minimum-fill rebalance.
Vectorised over candidate pairs.

The paper (§5) notes SM-trees prefer tightly *centred* subtrees; we also ship
``central_split`` (promote the two entries with the smallest eccentricity,
then hyperplane-assign) as a cheaper SM-oriented policy — compared in
benchmarks/paper_queries.py.
"""
from __future__ import annotations

import numpy as np

__all__ = ["minmax_split", "central_split", "SPLIT_POLICIES", "min_side_for"]


def min_side_for(m: int, capacity: int, min_fill: int) -> int:
    """Minimum entries per side when splitting m entries into two nodes of
    ``capacity``.  The ``m - capacity`` term is load-bearing for delete
    re-splits (union of two nodes can reach ~1.4*capacity): it guarantees
    neither side overflows."""
    return max(2, min(min_fill, m // 2), m - capacity)


def _assign_and_radii(D, C, pi, pj):
    to_i = D[pi] <= D[pj]
    r_i = C[pi][to_i].max() if to_i.any() else 0.0
    r_j = C[pj][~to_i].max() if (~to_i).any() else 0.0
    return to_i, r_i, r_j


def _rebalance(D, pi, pj, side_i, side_j, min_side):
    side_i, side_j = list(side_i), list(side_j)
    while len(side_i) < min_side:
        mv = min(side_j, key=lambda k: D[pi, k])
        side_j.remove(mv); side_i.append(mv)
    while len(side_j) < min_side:
        mv = min(side_i, key=lambda k: D[pj, k])
        side_i.remove(mv); side_j.append(mv)
    return np.array(side_i), np.array(side_j)


def minmax_split(D: np.ndarray, child_radii: np.ndarray, is_leaf: bool,
                 min_side: int):
    """mM_RAD promotion + generalized hyperplane distribution.

    D: [m, m] pairwise distances between the m entries' reference values.
    child_radii: [m] covering radii of the entries (zeros for leaf entries).
    Returns (pi, pj, members_i, members_j, r_i, r_j) — promoted indices, the
    member index arrays (including the promoted entries themselves) and the
    covering radii of the two routing entries.

    All m(m-1)/2 candidate pairs are scored in one vectorised pass
    (``np.argmin`` keeps the first minimal pair, matching the original
    lexicographic loop's strict-< tie-breaking exactly); this is the stream
    batcher's escalation hot path, where the per-pair Python loop dominated
    sustained mutation throughput.
    """
    m = D.shape[0]
    C = D if is_leaf else D + np.asarray(child_radii)[None, :]
    ii, jj = np.triu_indices(m, k=1)
    to_i = D[ii] <= D[jj]                           # [P, m]: hyperplane side
    r_i = np.where(to_i, C[ii], -np.inf).max(axis=1)
    r_j = np.where(to_i, -np.inf, C[jj]).max(axis=1)
    r_i = np.where(np.isfinite(r_i), r_i, 0.0)      # empty side covers 0
    r_j = np.where(np.isfinite(r_j), r_j, 0.0)
    best = int(np.argmin(np.maximum(r_i, r_j)))
    pi, pj, to_i = int(ii[best]), int(jj[best]), to_i[best]
    idx = np.arange(m)
    side_i, side_j = _rebalance(D, pi, pj, idx[to_i], idx[~to_i], min_side)
    r_i = float(C[pi, side_i].max())
    r_j = float(C[pj, side_j].max())
    return pi, pj, side_i, side_j, r_i, r_j


def central_split(D: np.ndarray, child_radii: np.ndarray, is_leaf: bool,
                  min_side: int):
    """SM-oriented O(m^2) policy: promote the two lowest-eccentricity entries
    that are not too close to each other, hyperplane-assign, rebalance."""
    m = D.shape[0]
    C = D if is_leaf else D + np.asarray(child_radii)[None, :]
    ecc = C.max(axis=1)                       # eccentricity of each candidate
    order = np.argsort(ecc)
    pi = int(order[0])
    # second promoter: low eccentricity but far from pi (avoid twin centres)
    score = ecc + 1e-3 - 0.5 * D[pi]
    score[pi] = np.inf
    pj = int(np.argmin(score))
    to_i, _, _ = _assign_and_radii(D, C, pi, pj)
    idx = np.arange(m)
    side_i, side_j = _rebalance(D, pi, pj, idx[to_i], idx[~to_i], min_side)
    r_i = float(C[pi, side_i].max())
    r_j = float(C[pj, side_j].max())
    return pi, pj, side_i, side_j, r_i, r_j


SPLIT_POLICIES = {"minmax": minmax_split, "central": central_split}
