"""SMTreeEngine: the composable front door to the JAX SM-tree.

Data plane (jit, accelerator): bulk-built tree + batched knn/range_search +
insert/delete fast paths (core/smtree.py).  Control plane (host, numpy):
node splits, merges and re-splits — the amortised-rare structure edits —
implemented here on a mutable numpy view of the same SoA and sharing
core/split.py with the paper-faithful reference implementation.

Engine-level invariants (property-tested in tests/test_engine.py):
  * SM radius invariant: r(entry) == max over child entries (pdist [+ r])
  * balance: all leaves at equal depth; parent/pslot pointers consistent
  * capacity/min-fill bounds away from the root
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.metric import make_metric
from repro.core.smtree import (TreeArrays, bulk_build, delete_fast, empty_tree,
                               insert_fast, knn, range_search)
from repro.core.split import SPLIT_POLICIES, min_side_for


class _HostView:
    """Mutable numpy mirror of TreeArrays for structure edits."""

    def __init__(self, t: TreeArrays):
        self.t = t
        for f in ("vecs", "radius", "pdist", "child", "oid", "valid",
                  "count", "is_leaf", "alive", "parent", "pslot"):
            setattr(self, f, np.array(getattr(t, f)))
        self.root = int(t.root)
        self.n_nodes = int(t.n_nodes)
        self.height = int(t.height)
        self.cap = t.capacity
        self.min_fill = t.min_fill
        self.metric = make_metric(t.metric, None)
        self.split = SPLIT_POLICIES["minmax"]

    # ---- storage management ------------------------------------------------
    def alloc(self, is_leaf: bool) -> int:
        free = np.nonzero(~self.alive)[0]
        if len(free) == 0:
            self._grow()
            free = np.nonzero(~self.alive)[0]
        i = int(free[0])
        self.alive[i] = True
        self.is_leaf[i] = is_leaf
        self.count[i] = 0
        self.valid[i] = False
        self.child[i] = -1
        self.oid[i] = -1
        self.parent[i] = -1
        self.pslot[i] = -1
        self.n_nodes = max(self.n_nodes, i + 1)
        return i

    def free(self, i: int):
        self.alive[i] = False
        self.valid[i] = False
        self.count[i] = 0
        self.parent[i] = -1
        self.pslot[i] = -1

    def _grow(self):
        N = len(self.count)
        for f in ("vecs", "radius", "pdist", "child", "oid", "valid",
                  "count", "is_leaf", "alive", "parent", "pslot"):
            a = getattr(self, f)
            pad = np.zeros((N,) + a.shape[1:], a.dtype)
            if f in ("child", "oid", "parent", "pslot"):
                pad -= 1
            if f == "is_leaf":
                pad |= True
            setattr(self, f, np.concatenate([a, pad], axis=0))

    # ---- helpers -------------------------------------------------------------
    def entries(self, n: int):
        m = int(self.count[n])
        return (self.vecs[n, :m].copy(), self.radius[n, :m].copy(),
                self.child[n, :m].copy(), self.oid[n, :m].copy())

    def write_node(self, n: int, vecs, radius, pdist, child, oid):
        m = len(oid)
        assert m <= self.cap
        self.vecs[n, :m] = vecs
        self.radius[n, :m] = radius
        self.pdist[n, :m] = pdist
        self.child[n, :m] = child
        self.oid[n, :m] = oid
        self.valid[n] = False
        self.valid[n, :m] = True
        self.child[n, m:] = -1
        self.oid[n, m:] = -1
        self.count[n] = m
        if not self.is_leaf[n]:
            for s, c in enumerate(child):
                self.parent[c] = n
                self.pslot[c] = s

    def routing_vec_of(self, n: int) -> Optional[np.ndarray]:
        """Reference value of the entry pointing at node n (None at root)."""
        p = int(self.parent[n])
        if p < 0:
            return None
        return self.vecs[p, int(self.pslot[n])]

    def fold_radius(self, n: int) -> float:
        """SM invariant value for the entry pointing at node n."""
        m = int(self.count[n])
        if m == 0:
            return 0.0
        contrib = self.pdist[n, :m] + (0.0 if self.is_leaf[n]
                                       else self.radius[n, :m])
        return float(contrib.max())

    def fold_up(self, n: int):
        """Recompute radii along the parent chain from node n to the root."""
        while True:
            p = int(self.parent[n])
            if p < 0:
                return
            self.radius[p, int(self.pslot[n])] = self.fold_radius(n)
            n = p

    def remove_entry(self, n: int, s: int):
        """Swap-remove slot s of node n, fixing the swapped child's pslot."""
        m = int(self.count[n]) - 1
        if s != m:
            for f in ("vecs", "radius", "pdist", "child", "oid"):
                getattr(self, f)[n, s] = getattr(self, f)[n, m]
            if not self.is_leaf[n]:
                c = int(self.child[n, s])
                self.pslot[c] = s
        self.valid[n, m] = False
        self.child[n, m] = -1
        self.oid[n, m] = -1
        self.count[n] = m

    def append_entry(self, n: int, vec, radius, pdist, child, oid) -> int:
        s = int(self.count[n])
        assert s < self.cap
        self.vecs[n, s] = vec
        self.radius[n, s] = radius
        self.pdist[n, s] = pdist
        self.child[n, s] = child
        self.oid[n, s] = oid
        self.valid[n, s] = True
        self.count[n] = s + 1
        if child >= 0:
            self.parent[child] = n
            self.pslot[child] = s
        return s

    # ---- split-insert (overflow path) ---------------------------------------
    def insert_with_split(self, x: np.ndarray, obj_id: int):
        # descend (closest-entry choose-subtree)
        node = self.root
        while not self.is_leaf[node]:
            m = int(self.count[node])
            d = self.metric(x[None, :], self.vecs[node, :m])
            node = int(self.child[node, int(np.argmin(d))])
        if int(self.count[node]) < self.cap:
            # leaf has room after all — the stream batcher escalates against
            # a *scan-time* overflow verdict, and earlier escalated ops may
            # have freed space by now; splitting a non-full leaf would
            # produce undersized sides.  Plain append + radius fold.
            pv = self.routing_vec_of(node)
            pd = 0.0 if pv is None else float(self.metric(x, pv))
            self.append_entry(node, x, 0.0, pd, -1, obj_id)
            self.fold_up(node)
            return
        # pending entry set at the current level
        vecs, radius, child, oid = self.entries(node)
        vecs = np.vstack([vecs, x[None, :]])
        radius = np.append(radius, 0.0)
        child = np.append(child, -1)
        oid = np.append(oid, obj_id)
        cur = node
        while True:
            is_leaf = bool(self.is_leaf[cur])
            D = np.asarray(self.metric(vecs[:, None, :], vecs[None, :, :]),
                           dtype=np.float64)
            min_side = min_side_for(len(oid), self.cap, self.min_fill)
            pi, pj, side_i, side_j, r_i, r_j = self.split(
                D, radius, is_leaf, min_side)
            parent = int(self.parent[cur])
            pslot = int(self.pslot[cur]) if parent >= 0 else -1
            n2 = self.alloc(is_leaf)
            # write both halves (cur reused for side_i)
            self.is_leaf[cur] = is_leaf
            self.write_node(cur, vecs[side_i], radius[side_i],
                            D[pi, side_i], child[side_i], oid[side_i])
            self.write_node(n2, vecs[side_j], radius[side_j],
                            D[pj, side_j], child[side_j], oid[side_j])
            prom = [(vecs[pi], r_i, cur), (vecs[pj], r_j, n2)]
            if parent < 0:
                # grow a new root
                nr = self.alloc(is_leaf=False)
                for v, r, c in prom:
                    self.append_entry(nr, v, r, 0.0, c, -1)
                self.root = nr
                self.height += 1
                return
            # replace the entry pointing at cur, append the second promoted
            pv = self.routing_vec_of(parent)
            for idx, (v, r, c) in enumerate(prom):
                pd = 0.0 if pv is None else float(self.metric(v, pv))
                if idx == 0:
                    self.vecs[parent, pslot] = v
                    self.radius[parent, pslot] = r
                    self.pdist[parent, pslot] = pd
                    self.child[parent, pslot] = c
                    self.parent[c] = parent
                    self.pslot[c] = pslot
                elif int(self.count[parent]) < self.cap:
                    self.append_entry(parent, v, r, pd, c, -1)
                else:
                    # parent overflows: splice the pending entry set and loop
                    e_vecs, e_rad, e_child, e_oid = self.entries(parent)
                    vecs = np.vstack([e_vecs, v[None, :]])
                    radius = np.append(e_rad, r)
                    child = np.append(e_child, c)
                    oid = np.append(e_oid, -1)
                    cur = parent
                    break
            else:
                self.fold_up(cur)   # exact radii upward from here
                return

    # ---- underflow-delete (merge path) --------------------------------------
    def delete_with_merge(self, x: np.ndarray, obj_id: int) -> bool:
        hits = np.nonzero((self.oid == obj_id) & self.valid)
        if len(hits[0]) == 0:
            return False
        leaf, slot = int(hits[0][0]), int(hits[1][0])
        self.remove_entry(leaf, slot)
        cur = leaf
        while (cur != self.root and int(self.count[cur]) < self.min_fill):
            parent = int(self.parent[cur])
            islot = int(self.pslot[cur])
            # nearest sibling entry by routing-object distance
            m = int(self.count[parent])
            d = np.asarray(self.metric(self.vecs[parent, islot][None, :],
                                       self.vecs[parent, :m]), np.float64)
            d[islot] = np.inf
            j = int(np.argmin(d))
            sib = int(self.child[parent, j])
            total = int(self.count[sib]) + int(self.count[cur])
            if total <= self.cap:
                # merge cur's entries into sib
                sv = self.vecs[parent, j]
                cm = int(self.count[cur])
                for kk in range(cm):
                    pd = float(self.metric(self.vecs[cur, kk], sv))
                    self.append_entry(sib, self.vecs[cur, kk],
                                      self.radius[cur, kk], pd,
                                      int(self.child[cur, kk]),
                                      int(self.oid[cur, kk]))
                self.free(cur)
                self.remove_entry(parent, islot)
                # islot removal may have moved entry j
                jj = int(self.pslot[sib])
                self.radius[parent, jj] = self.fold_radius(sib)
            else:
                # re-split the union across cur and sib
                sv_, sr_, sc_, so_ = self.entries(sib)
                cv_, cr_, cc_, co_ = self.entries(cur)
                vecs = np.vstack([sv_, cv_])
                radius = np.concatenate([sr_, cr_])
                child = np.concatenate([sc_, cc_])
                oid = np.concatenate([so_, co_])
                is_leaf = bool(self.is_leaf[cur])
                D = np.asarray(self.metric(vecs[:, None, :], vecs[None, :, :]),
                               dtype=np.float64)
                min_side = min_side_for(len(oid), self.cap, self.min_fill)
                pi, pj, side_i, side_j, r_i, r_j = self.split(
                    D, radius, is_leaf, min_side)
                self.write_node(sib, vecs[side_i], radius[side_i],
                                D[pi, side_i], child[side_i], oid[side_i])
                self.write_node(cur, vecs[side_j], radius[side_j],
                                D[pj, side_j], child[side_j], oid[side_j])
                pv = self.routing_vec_of(parent)
                for (v, r, c, s_) in ((vecs[pi], r_i, sib, j),
                                      (vecs[pj], r_j, cur, islot)):
                    pd = 0.0 if pv is None else float(self.metric(v, pv))
                    self.vecs[parent, s_] = v
                    self.radius[parent, s_] = r
                    self.pdist[parent, s_] = pd
                    self.child[parent, s_] = c
                    self.parent[c] = parent
                    self.pslot[c] = s_
            cur = parent
        self.fold_up(cur)
        # root collapse
        while (not self.is_leaf[self.root]) and int(self.count[self.root]) == 1:
            old = self.root
            self.root = int(self.child[old, 0])
            self.parent[self.root] = -1
            self.pslot[self.root] = -1
            self.free(old)
            self.height -= 1
        return True

    # ---- back to device ------------------------------------------------------
    def to_tree(self) -> TreeArrays:
        # the device free ring is recomputed wholesale from the alive mask:
        # host-side allocs/frees (and _grow resizes) invalidate the packed
        # descending representation the device allocator maintains in place
        from repro.core.smtree import packed_free_list
        free_list, free_head = packed_free_list(self.alive)
        return dataclasses.replace(
            self.t,
            vecs=jnp.asarray(self.vecs), radius=jnp.asarray(self.radius),
            pdist=jnp.asarray(self.pdist), child=jnp.asarray(self.child),
            oid=jnp.asarray(self.oid), valid=jnp.asarray(self.valid),
            count=jnp.asarray(self.count), is_leaf=jnp.asarray(self.is_leaf),
            alive=jnp.asarray(self.alive), parent=jnp.asarray(self.parent),
            pslot=jnp.asarray(self.pslot), root=jnp.int32(self.root),
            n_nodes=jnp.int32(self.n_nodes), height=jnp.int32(self.height),
            free_list=jnp.asarray(free_list),
            free_head=jnp.asarray(free_head),
            max_nodes=len(self.count))


class SMTreeEngine:
    """High-level API over the JAX SM-tree."""

    def __init__(self, tree: TreeArrays):
        self.tree = tree

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, X, ids=None, **kw) -> "SMTreeEngine":
        return cls(bulk_build(np.asarray(X), ids, **kw))

    @classmethod
    def empty(cls, **kw) -> "SMTreeEngine":
        return cls(empty_tree(**kw))

    # -- queries (jit) ---------------------------------------------------------
    def knn(self, queries, k: int = 1, **kw):
        return knn(self.tree, jnp.asarray(queries, jnp.float32), k=k, **kw)

    def range_search(self, queries, radius, **kw):
        return range_search(self.tree, jnp.asarray(queries, jnp.float32),
                            radius, **kw)

    # -- updates ----------------------------------------------------------------
    def insert(self, x, obj_id: int):
        x = jnp.asarray(x, jnp.float32)
        new_tree, fits, _leaf = insert_fast(self.tree, x, jnp.int32(obj_id))
        if bool(fits):
            self.tree = new_tree
            return
        hv = _HostView(self.tree)
        hv.insert_with_split(np.asarray(x), int(obj_id))
        self.tree = hv.to_tree()

    def delete(self, x, obj_id: int) -> bool:
        x = jnp.asarray(x, jnp.float32)
        new_tree, found, underflow, _leaf = delete_fast(
            self.tree, x, jnp.int32(obj_id))
        if not bool(found):
            return False
        if not bool(underflow):
            self.tree = new_tree
            return True
        hv = _HostView(self.tree)
        ok = hv.delete_with_merge(np.asarray(x), int(obj_id))
        self.tree = hv.to_tree()
        return ok

    # -- validation ---------------------------------------------------------------
    def validate(self):
        """Structural + SM-invariant checks (host-side, exhaustive)."""
        t = _HostView(self.tree)
        mfn = t.metric
        leaf_depths = set()

        def rec(n: int, depth: int, pv):
            assert t.alive[n], f"dead node {n} reachable"
            m = int(t.count[n])
            assert (t.valid[n, :m].all() and not t.valid[n, m:].any()), \
                f"valid/count mismatch at {n}"
            assert m <= t.cap
            if n != t.root:
                assert m >= t.min_fill, f"underflown node {n}: {m}"
            if t.is_leaf[n]:
                leaf_depths.add(depth)
            if pv is not None:
                pd = np.asarray(mfn(t.vecs[n, :m], pv[None, :]))
                np.testing.assert_allclose(pd, t.pdist[n, :m], atol=1e-4,
                                           err_msg=f"stale pdist at node {n}")
            if not t.is_leaf[n]:
                for s in range(m):
                    c = int(t.child[n, s])
                    assert t.parent[c] == n and t.pslot[c] == s, \
                        f"parent pointer broken at {c}"
                    want = t.fold_radius(c)
                    np.testing.assert_allclose(
                        t.radius[n, s], want, atol=1e-4,
                        err_msg=f"SM invariant broken at node {n} slot {s}")
                    rec(c, depth + 1, t.vecs[n, s])

        rec(t.root, 0, None)
        assert len(leaf_depths) <= 1, f"unbalanced: {leaf_depths}"
        return True

    @property
    def n_objects(self) -> int:
        return self.tree.n_objects
