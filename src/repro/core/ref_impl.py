"""Paper-exact reference implementation of the M-tree and SM-tree.

This module is the *oracle* for everything else in the repo: it follows the
pseudocode of Sexton & Swinbank, "Symmetric M-tree" (CSR-04-2 / arXiv cs.DB
2010) line by line, plus the original M-tree (Ciaccia et al., VLDB'97) as the
baseline the paper compares against.  It is numpy-vectorised *per node* but
deliberately keeps the paper's sequential pointer-machine structure so that
page-hit (IO) counts reproduce the paper's Figures 5-10.

Corrections relative to the paper's pseudocode (see DESIGN.md §1):
  * Delete assigns the returned covering radius unconditionally (the printed
    pseudocode's ``if r > r(O_n)`` guard is an erratum copied from Insert —
    it would prevent radii from ever contracting).
  * Delete stops after the object is found (objects are stored once).
  * Root handling: root split grows the tree; an internal root left with a
    single entry is collapsed (its child becomes the new root).

Cost model: ``tree.ios`` counts node accesses (page hits) and
``tree.dist_calcs`` counts metric evaluations; queries reset both via
``tree.reset_counters()``.  Infinite buffer pool per query (the tree is a
tree: within one query each node is visited at most once anyway).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.metric import make_metric

__all__ = ["MTree", "SMTree", "Node", "TreeStats"]


# --------------------------------------------------------------------------
# Node storage: parallel arrays per node (vectorised distance evaluation).
# --------------------------------------------------------------------------
class Node:
    __slots__ = ("vecs", "radii", "pdists", "ids", "children", "is_leaf")

    def __init__(self, dim: int, is_leaf: bool):
        self.vecs = np.empty((0, dim), dtype=np.float32)
        self.radii = np.empty((0,), dtype=np.float64)      # 0.0 for leaf entries
        self.pdists = np.empty((0,), dtype=np.float64)     # d(entry, parent routing obj)
        self.ids = []                                       # leaf: object ids; internal: None
        self.children = []                                  # internal: child Nodes
        self.is_leaf = is_leaf

    def __len__(self) -> int:
        return self.vecs.shape[0]

    def add(self, vec, radius, pdist, obj_id=None, child=None):
        self.vecs = np.vstack([self.vecs, vec[None, :]])
        self.radii = np.append(self.radii, radius)
        self.pdists = np.append(self.pdists, pdist)
        self.ids.append(obj_id)
        self.children.append(child)

    def remove(self, idx: int):
        keep = np.arange(len(self)) != idx
        self.vecs = self.vecs[keep]
        self.radii = self.radii[keep]
        self.pdists = self.pdists[keep]
        del self.ids[idx]
        del self.children[idx]

    def set_all(self, vecs, radii, pdists, ids, children):
        self.vecs = np.asarray(vecs, dtype=np.float32).reshape(len(ids), -1)
        self.radii = np.asarray(radii, dtype=np.float64)
        self.pdists = np.asarray(pdists, dtype=np.float64)
        self.ids = list(ids)
        self.children = list(children)


@dataclass
class TreeStats:
    n_objects: int = 0
    n_nodes: int = 0
    n_leaves: int = 0
    height: int = 0
    occupancy: float = 0.0  # mean fill fraction over all nodes


# --------------------------------------------------------------------------
# Shared base: storage parameters, queries, split, validation.
# --------------------------------------------------------------------------
class _BaseTree:
    """Common machinery; Insert/Delete differ per subclass."""

    def __init__(self, dim: int = 20, *, capacity: int = 42,
                 min_fill_frac: float = 0.4, metric: str = "d_inf",
                 n_dims: Optional[int] = None, split_policy: str = "minmax"):
        if capacity < 4:
            raise ValueError("capacity must be >= 4")
        from repro.core.split import SPLIT_POLICIES
        self.dim = dim
        self.capacity = capacity
        self.min_fill = max(1, int(np.ceil(min_fill_frac * capacity)))
        self.metric_name = metric
        self.n_dims = n_dims
        self.split_policy = SPLIT_POLICIES[split_policy]
        self._metric = make_metric(metric, n_dims)
        self.root = Node(dim, is_leaf=True)
        self.height = 1
        self.n_objects = 0
        self.ios = 0
        self.dist_calcs = 0

    # -- metric helpers (instrumented) ------------------------------------
    def _d(self, x: np.ndarray, y: np.ndarray) -> float:
        self.dist_calcs += 1
        return float(self._metric(x, y))

    def _d_many(self, q: np.ndarray, vecs: np.ndarray) -> np.ndarray:
        """Distances from q to each row of vecs."""
        if len(vecs) == 0:
            return np.empty((0,), dtype=np.float64)
        self.dist_calcs += len(vecs)
        return np.asarray(self._metric(q[None, :], vecs), dtype=np.float64)

    def reset_counters(self):
        self.ios = 0
        self.dist_calcs = 0

    # -- queries -----------------------------------------------------------
    def range_query(self, q: np.ndarray, radius: float) -> list[int]:
        """All object ids within ``radius`` of q (paper's Range query)."""
        q = np.asarray(q, dtype=np.float32)
        out: list[int] = []
        self._range(self.root, q, radius, None, out)
        return out

    def _range(self, node: Node, q, r_q, d_q_parent, out):
        self.ios += 1
        if len(node) == 0:
            return
        if node.is_leaf:
            if d_q_parent is None:
                cand = np.arange(len(node))
            else:  # parent-distance prefilter: saves distance computations
                cand = np.nonzero(np.abs(d_q_parent - node.pdists) <= r_q)[0]
            if len(cand):
                d = self._d_many(q, node.vecs[cand])
                for i, di in zip(cand, d):
                    if di <= r_q:
                        out.append(node.ids[i])
        else:
            if d_q_parent is None:
                cand = np.arange(len(node))
            else:
                cand = np.nonzero(np.abs(d_q_parent - node.pdists)
                                  <= r_q + node.radii)[0]
            if len(cand):
                d = self._d_many(q, node.vecs[cand])
                for i, di in zip(cand, d):
                    if di <= r_q + node.radii[i]:
                        self._range(node.children[i], q, r_q, di, out)

    def knn_query(self, q: np.ndarray, k: int) -> list[tuple[float, int]]:
        """k nearest neighbours, paper-faithful (§4.1): 'a search begins as a
        range query with infinite range and the search radius is contracted
        as objects within it are encountered' — i.e. depth-first descent with
        a dynamic radius, children visited in ascending d_min order.

        (``knn_query_bestfirst`` below is the Hjaltason–Samet optimal-IO
        variant — a beyond-paper optimisation; for q in the database it
        provably visits exactly the R-0 node set, collapsing the paper's
        Fig.5-vs-Fig.7 gap.  Benchmarked separately.)"""
        q = np.asarray(q, dtype=np.float32)
        best: list[tuple[float, int]] = []       # max-heap via negated dist
        state = {"r_q": np.inf}

        def visit(node: Node, d_parent):
            self.ios += 1
            if len(node) == 0:
                return
            r_q = state["r_q"]
            if node.is_leaf:
                if d_parent is None:
                    cand = np.arange(len(node))
                else:
                    cand = np.nonzero(np.abs(d_parent - node.pdists) <= r_q)[0]
                if len(cand):
                    d = self._d_many(q, node.vecs[cand])
                    for i, di in zip(cand, d):
                        if di <= state["r_q"]:
                            heapq.heappush(best, (-di, node.ids[i]))
                            if len(best) > k:
                                heapq.heappop(best)
                            if len(best) == k:
                                state["r_q"] = -best[0][0]
            else:
                if d_parent is None:
                    cand = np.arange(len(node))
                else:
                    cand = np.nonzero(np.abs(d_parent - node.pdists)
                                      <= r_q + node.radii)[0]
                if len(cand):
                    d = self._d_many(q, node.vecs[cand])
                    dmin = np.maximum(d - node.radii[cand], 0.0)
                    for o in np.argsort(dmin):
                        if dmin[o] <= state["r_q"]:   # re-check: radius shrinks
                            visit(node.children[cand[o]], d[o])

        visit(self.root, None)
        return sorted((-nd, oid) for nd, oid in best)

    def knn_query_bestfirst(self, q: np.ndarray, k: int) -> list[tuple[float, int]]:
        """Optimal-IO kNN (beyond paper): global best-first priority queue."""
        q = np.asarray(q, dtype=np.float32)
        # heap of (d_min(Q, subtree), counter, node, d(Q, routing) or None)
        cnt = itertools.count()
        pq: list = [(0.0, next(cnt), self.root, None)]
        best: list[tuple[float, int]] = []   # max-heap via negated distance
        r_q = np.inf
        while pq:
            d_min, _, node, d_parent = heapq.heappop(pq)
            if d_min > r_q:
                break  # nothing reachable can beat current kth distance
            self.ios += 1
            if len(node) == 0:
                continue
            if node.is_leaf:
                if d_parent is None:
                    cand = np.arange(len(node))
                else:
                    cand = np.nonzero(np.abs(d_parent - node.pdists) <= r_q)[0]
                if len(cand):
                    d = self._d_many(q, node.vecs[cand])
                    for i, di in zip(cand, d):
                        if di <= r_q:
                            heapq.heappush(best, (-di, node.ids[i]))
                            if len(best) > k:
                                heapq.heappop(best)
                            if len(best) == k:
                                r_q = -best[0][0]
            else:
                if d_parent is None:
                    cand = np.arange(len(node))
                else:
                    cand = np.nonzero(np.abs(d_parent - node.pdists)
                                      <= r_q + node.radii)[0]
                if len(cand):
                    d = self._d_many(q, node.vecs[cand])
                    for i, di in zip(cand, d):
                        dmin_child = max(di - node.radii[i], 0.0)
                        if dmin_child <= r_q:
                            heapq.heappush(
                                pq, (dmin_child, next(cnt), node.children[i], di))
        return sorted((-nd, oid) for nd, oid in best)

    # -- split: mM_RAD promotion + generalized-hyperplane distribution ----
    def _split(self, vecs, radii, ids, children, is_leaf):
        """Partition the overflown entry set into two nodes.

        Promotion: MinMax (mM_RAD) — try every pair of entries as promoted
        routing objects, pick the pair minimising the larger covering radius.
        Distribution: generalized hyperplane (each entry to the closer
        promoted object) followed by a minimum-fill rebalance.

        Returns (node1, vec1, r1), (node2, vec2, r2): two fresh nodes and
        their routing entries' reference values + covering radii.  Entry
        parent distances inside each node are set here; the *promoted*
        entries' own parent distances are the caller's job.
        """
        m = len(ids)
        vecs = np.asarray(vecs, dtype=np.float32).reshape(m, -1)
        radii = np.asarray(radii, dtype=np.float64)
        D = np.asarray(self._metric(vecs[:, None, :], vecs[None, :, :]),
                       dtype=np.float64)
        self.dist_calcs += m * m
        from repro.core.split import min_side_for
        min_side = min_side_for(m, self.capacity, self.min_fill)
        pi, pj, side_i, side_j, r_i, r_j = self.split_policy(
            D, radii, is_leaf, min_side)

        def build(promoter, members, r):
            members = np.asarray(members)
            node = Node(self.dim, is_leaf)
            node.set_all(vecs[members], radii[members], D[promoter, members],
                         [ids[k] for k in members],
                         [children[k] for k in members])
            return node, vecs[promoter].copy(), float(r)

        n1 = build(pi, side_i, r_i)
        n2 = build(pj, side_j, r_j)
        return n1, n2

    # -- stats & validation -------------------------------------------------
    def stats(self) -> TreeStats:
        n_nodes = n_leaves = 0
        fill = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            n_nodes += 1
            fill.append(len(node) / self.capacity)
            if node.is_leaf:
                n_leaves += 1
            else:
                stack.extend(node.children)
        return TreeStats(self.n_objects, n_nodes, n_leaves, self.height,
                         float(np.mean(fill)) if fill else 0.0)

    def leaf_io_count(self) -> int:
        """IOs for a sequential scan of the leaf level (paper's horizontal
        'efficiency limit' lines in Figs. 5-8)."""
        return self.stats().n_leaves

    def all_objects(self) -> list[tuple[int, np.ndarray]]:
        out = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node.is_leaf:
                out.extend((node.ids[i], node.vecs[i]) for i in range(len(node)))
            else:
                stack.extend(node.children)
        return out

    def validate(self, *, check_sm_invariant: bool = False,
                 check_min_fill: bool = False, sm_exact: bool | None = None):
        """Structural invariants; raises AssertionError on violation.

        ``sm_exact`` — require r == max(pdist+r) over immediate children
        (the SM-tree's stated invariant); defaults to the tree's
        ``tighten_on_insert`` flag.  When False only the upper bound
        r >= max(pdist+r) is required (pseudocode-literal insert).
        """
        if sm_exact is None:
            sm_exact = getattr(self, "tighten_on_insert", True)
        leaf_depths = set()

        def rec(node: Node, depth: int, parent_vec):
            if node.is_leaf:
                leaf_depths.add(depth)
            assert len(node) <= self.capacity, "capacity overflow"
            if check_min_fill and node is not self.root:
                assert len(node) >= self.min_fill, (
                    f"underflown node: {len(node)} < {self.min_fill}")
            for i in range(len(node)):
                if parent_vec is not None:
                    pd = float(self._metric(node.vecs[i], parent_vec))
                    assert abs(pd - node.pdists[i]) < 1e-4, (
                        f"stale parentDistance {node.pdists[i]} vs {pd}")
            if not node.is_leaf:
                for i, child in enumerate(node.children):
                    assert child is not None
                    # coverage: every object in subtree within r of routing vec
                    objs = []
                    st = [child]
                    while st:
                        nd = st.pop()
                        if nd.is_leaf:
                            objs.extend(nd.vecs)
                        else:
                            st.extend(nd.children)
                    if objs:
                        dmax = float(np.max(self._metric(
                            node.vecs[i][None, :], np.asarray(objs))))
                        assert dmax <= node.radii[i] + 1e-4, (
                            f"covering radius violated: {dmax} > {node.radii[i]}")
                    if check_sm_invariant:
                        # r vs max(pdist + r_child) over immediate children
                        if len(child):
                            want = float(np.max(child.pdists + child.radii))
                            if sm_exact:
                                assert abs(want - node.radii[i]) < 1e-4, (
                                    f"SM invariant broken: r={node.radii[i]} "
                                    f"vs max(pdist+r)={want}")
                            else:
                                assert want <= node.radii[i] + 1e-4, (
                                    f"SM bound broken: r={node.radii[i]} "
                                    f"< max(pdist+r)={want}")
                    rec(child, depth + 1, node.vecs[i])

        rec(self.root, 0, None)
        assert len(leaf_depths) <= 1, f"unbalanced tree: leaf depths {leaf_depths}"

    # -- helpers for root growth/shrink ------------------------------------
    def _grow_root(self, split_result):
        (n1, v1, r1), (n2, v2, r2) = split_result
        new_root = Node(self.dim, is_leaf=False)
        new_root.add(v1, r1, 0.0, None, n1)
        new_root.add(v2, r2, 0.0, None, n2)
        self.root = new_root
        self.height += 1


# --------------------------------------------------------------------------
# M-tree (baseline; Ciaccia et al. '97): lazy top-down radius expansion.
# --------------------------------------------------------------------------
class MTree(_BaseTree):
    supports_delete = False

    def insert(self, vec: np.ndarray, obj_id: int):
        vec = np.asarray(vec, dtype=np.float32)
        res = self._insert(self.root, vec, obj_id, None)
        if res is not None:
            self._grow_root(res)
        self.n_objects += 1

    def _insert(self, node: Node, vec, obj_id, parent_vec):
        self.ios += 1
        if node.is_leaf:
            pd = self._d(vec, parent_vec) if parent_vec is not None else 0.0
            node.add(vec, 0.0, pd, obj_id, None)
            if len(node) > self.capacity:
                return self._split(node.vecs, node.radii, node.ids,
                                   node.children, True)
            return None
        # choose subtree: zero-expansion if possible (closest such), else
        # minimal expansion (then expand the radius top-down: the asymmetry).
        d = self._d_many(vec, node.vecs)
        inside = d <= node.radii
        if inside.any():
            i = int(np.where(inside, d, np.inf).argmin())
        else:
            i = int((d - node.radii).argmin())
            node.radii[i] = d[i]          # lazy top-down expansion
        res = self._insert(node.children[i], vec, obj_id, node.vecs[i])
        if res is not None:
            (n1, v1, r1), (n2, v2, r2) = res
            node.remove(i)
            pd1 = self._d(v1, parent_vec) if parent_vec is not None else 0.0
            pd2 = self._d(v2, parent_vec) if parent_vec is not None else 0.0
            node.add(v1, r1, pd1, None, n1)
            node.add(v2, r2, pd2, None, n2)
            if len(node) > self.capacity:
                return self._split(node.vecs, node.radii, node.ids,
                                   node.children, False)
        return None


# --------------------------------------------------------------------------
# SM-tree (the paper): bottom-up radius maintenance; symmetric insert/delete.
# --------------------------------------------------------------------------
class SMTree(_BaseTree):
    """SM-tree.

    ``tighten_on_insert`` (default True) assigns the radius returned by the
    recursive Insert unconditionally, maintaining the paper's *stated*
    invariant exactly: r(O_n) == max(pdist + r) over immediate children ("at
    the size they would be were they newly promoted from below", §3.1).  The
    printed pseudocode instead guards with ``if r > r(O_bestSubtree)``; after
    a split in the subtree the recomputed bound can legitimately *shrink*, so
    the literal pseudocode degrades the invariant to an upper bound.  Set
    ``tighten_on_insert=False`` for the pseudocode-literal behaviour (still
    correct, slightly looser radii).  See DESIGN.md §1.
    """
    supports_delete = True

    def __init__(self, *args, tighten_on_insert: bool = True, **kw):
        super().__init__(*args, **kw)
        self.tighten_on_insert = tighten_on_insert

    # ---- Insert (paper §3.1) ---------------------------------------------
    def insert(self, vec: np.ndarray, obj_id: int):
        vec = np.asarray(vec, dtype=np.float32)
        res = self._insert(self.root, vec, obj_id, None)
        if isinstance(res, tuple):
            self._grow_root(res)
        self.n_objects += 1

    def _insert(self, node: Node, vec, obj_id, parent_vec):
        """Returns new covering radius (float) or split result (tuple)."""
        self.ios += 1
        if node.is_leaf:
            pd = self._d(vec, parent_vec) if parent_vec is not None else 0.0
            node.add(vec, 0.0, pd, obj_id, None)
            if len(node) > self.capacity:
                return self._split(node.vecs, node.radii, node.ids,
                                   node.children, True)
            return float(node.pdists.max())
        # choose subtree: closest entry (paper §3.1 — radius expansion can no
        # longer be predicted during descent, so centre subtrees tightly)
        d = self._d_many(vec, node.vecs)
        i = int(d.argmin())
        res = self._insert(node.children[i], vec, obj_id, node.vecs[i])
        if isinstance(res, tuple):            # entries promoted from below
            (n1, v1, r1), (n2, v2, r2) = res
            node.remove(i)
            pd1 = self._d(v1, parent_vec) if parent_vec is not None else 0.0
            pd2 = self._d(v2, parent_vec) if parent_vec is not None else 0.0
            node.add(v1, r1, pd1, None, n1)
            node.add(v2, r2, pd2, None, n2)
            if len(node) > self.capacity:
                return self._split(node.vecs, node.radii, node.ids,
                                   node.children, False)
        else:                                  # (possibly expanded) radius
            if self.tighten_on_insert or res > node.radii[i]:
                node.radii[i] = res
        return float((node.pdists + node.radii).max())

    # ---- Delete (paper §3.2, with erratum fixes) ---------------------------
    def delete(self, vec: np.ndarray, obj_id: int) -> bool:
        """Delete object ``obj_id`` located at ``vec``; True if found."""
        vec = np.asarray(vec, dtype=np.float32)
        res = self._delete(self.root, vec, obj_id, None)
        if res is None:
            return False
        self.n_objects -= 1
        # root collapse: internal root with a single entry -> child is root
        while (not self.root.is_leaf) and len(self.root) == 1:
            self.root = self.root.children[0]
            self.root.pdists = np.zeros(len(self.root))  # root entries: no parent
            self.height -= 1
        # root entries have no parent routing object; normalise pdists
        return True

    def _delete(self, node: Node, vec, obj_id, parent_vec):
        """Returns None (not found), ('r', radius) or ('uf', node) where the
        node's entries are to be redistributed by the caller."""
        self.ios += 1
        if node.is_leaf:
            try:
                idx = next(i for i in range(len(node))
                           if node.ids[i] == obj_id)
            except StopIteration:
                return None
            node.remove(idx)
            if node is not self.root and len(node) < self.min_fill:
                return ("uf", node)
            return ("r", float(node.pdists.max()) if len(node) else 0.0)

        d = self._d_many(vec, node.vecs)
        order = np.argsort(d)                      # visit closest-first
        for i in order:
            i = int(i)
            if d[i] > node.radii[i]:
                continue                            # triangle-inequality prune
            res = self._delete(node.children[i], vec, obj_id, node.vecs[i])
            if res is None:
                continue                            # not in that subtree
            if res[0] == "r":
                node.radii[i] = res[1]              # UNCONDITIONAL (erratum fix)
            else:                                    # child underflow
                self._handle_underflow(node, i, res[1], parent_vec)
            if node is not self.root and len(node) < self.min_fill:
                return ("uf", node)
            if len(node):
                return ("r", float((node.pdists + node.radii).max()))
            return ("r", 0.0)
        return None

    def _handle_underflow(self, node: Node, i: int, child: Node, parent_vec):
        """Merge underflown child(i)'s entries into the nearest sibling's
        child, or re-split the union (paper §3.2)."""
        # nearest sibling entry O_NN (by distance between routing objects)
        d_sib = self._d_many(node.vecs[i], node.vecs)
        d_sib[i] = np.inf
        j = int(d_sib.argmin())
        sib = node.children[j]
        assert sib.is_leaf == child.is_leaf
        total = len(sib) + len(child)
        if total <= self.capacity:
            # merge child's entries into sibling
            for k in range(len(child)):
                pd = self._d(child.vecs[k], node.vecs[j])
                sib.add(child.vecs[k], child.radii[k], pd,
                        child.ids[k], child.children[k])
            node.remove(i)
            if sib.is_leaf:
                node.radii[j if j < i else j - 1] = float(sib.pdists.max())
            else:
                node.radii[j if j < i else j - 1] = float(
                    (sib.pdists + sib.radii).max())
        else:
            # re-split the union into two nodes
            vecs = np.vstack([sib.vecs, child.vecs])
            radii = np.concatenate([sib.radii, child.radii])
            ids = sib.ids + child.ids
            children = sib.children + child.children
            (n1, v1, r1), (n2, v2, r2) = self._split(
                vecs, radii, ids, children, sib.is_leaf)
            # remove higher index first to keep the other valid
            for k in sorted((i, j), reverse=True):
                node.remove(k)
            pd1 = self._d(v1, parent_vec) if parent_vec is not None else 0.0
            pd2 = self._d(v2, parent_vec) if parent_vec is not None else 0.0
            node.add(v1, r1, pd1, None, n1)
            node.add(v2, r2, pd2, None, n2)
