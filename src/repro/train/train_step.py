"""Training step builder: loss -> grad -> (optional int8 compressed
all-reduce) -> AdamW, with remat-by-period and GSPMD shardings attached.

``make_train_step(cfg, mesh, ...)`` returns (step_fn, shardings) where
step_fn(params, opt_state, batch) -> (params, opt_state, metrics) is ready to
``jax.jit(..., in_shardings=..., out_shardings=...)``, lower and compile —
the dry-run and the real trainer share this exact builder.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist import sharding as shd
from repro.dist.compression import compressed_mean_hook, init_ef_state
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update, \
    init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    opt: AdamWConfig = AdamWConfig()
    remat: bool = True
    moe_aux_weight: float = 0.01
    z_loss_weight: float = 1e-3
    grad_compression: str = "none"     # none | int8
    error_feedback: bool = False       # persistent EF state for int8 grads
    attn_impl: str | None = None       # None -> models.attention.ATTN_IMPL
    seq_parallel: bool = False         # Megatron SP on the residual stream


def loss_and_aux(params, cfg: ArchConfig, batch, settings: TrainSettings):
    logits, aux = M.forward(params, cfg, batch, remat=settings.remat,
                            attn_impl=settings.attn_impl)
    labels = batch["labels"]
    mask = jnp.ones(labels.shape, jnp.float32)
    loss = M.loss_fn(logits, labels, mask)
    total = loss
    if cfg.n_experts:
        total = total + settings.moe_aux_weight * aux["lb_loss"] \
            + settings.z_loss_weight * aux["z_loss"]
    return total, {"loss": loss, **{k: jnp.asarray(v) for k, v in aux.items()}}


def make_train_step(cfg: ArchConfig, mesh, inputs_spec: dict,
                    settings: TrainSettings = TrainSettings()):
    """Returns (step_fn, Shardings) for this arch on this mesh.

    With ``settings.error_feedback`` (and int8 compression), the step
    carries *persistent EF state*: ``step_fn(params, opt_state, ef, batch)
    -> (params, opt_state, ef, metrics)`` — the int8 quantisation residual
    is folded into the next step's gradient instead of being dropped, so
    long-run compressed training tracks uncompressed within one
    quantisation step per update (the ROADMAP EF-wiring item; parity smoke
    test in tests/test_error_feedback.py).  Initialise with
    ``init_ef_state(params)``; the returned shardings dict gains an
    ``"ef"`` entry (same specs as params, residuals live where their
    gradients do).  Without the flag the signature is unchanged."""
    use_ef = settings.error_feedback and settings.grad_compression == "int8"

    def _grads_and_metrics(params, batch):
        shd.set_sequence_parallel(settings.seq_parallel)
        (total, metrics), grads = jax.value_and_grad(
            loss_and_aux, has_aux=True)(params, cfg, batch, settings)
        return total, metrics, grads

    def step_fn(params, opt_state: AdamWState, batch):
        total, metrics, grads = _grads_and_metrics(params, batch)
        if settings.grad_compression == "int8":
            grads = compressed_mean_hook(grads)
        params, opt_state, opt_metrics = adamw_update(
            settings.opt, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics,
                                   "total_loss": total}

    def step_fn_ef(params, opt_state: AdamWState, ef, batch):
        total, metrics, grads = _grads_and_metrics(params, batch)
        grads, ef = compressed_mean_hook(grads, ef=ef)
        params, opt_state, opt_metrics = adamw_update(
            settings.opt, params, grads, opt_state)
        return params, opt_state, ef, {**metrics, **opt_metrics,
                                       "total_loss": total}

    # shardings
    pspecs = shd.param_pspecs(cfg, M.param_specs(cfg), mesh)
    param_sh = shd.to_named(pspecs, mesh)
    opt_specs = AdamWState(
        step=P(),
        mu=jax.tree_util.tree_map(
            lambda s, l: shd.opt_state_pspec(s, l.shape, mesh),
            pspecs, M.param_specs(cfg), is_leaf=lambda x: isinstance(x, P)),
        nu=jax.tree_util.tree_map(
            lambda s, l: shd.opt_state_pspec(s, l.shape, mesh),
            pspecs, M.param_specs(cfg), is_leaf=lambda x: isinstance(x, P)))
    opt_sh = shd.to_named(opt_specs, mesh)
    in_specs = shd.input_pspecs(cfg, "train", inputs_spec, mesh)
    batch_sh = shd.to_named(in_specs, mesh)
    metrics_sh = NamedSharding(mesh, P())

    shardings = dict(params=param_sh, opt=opt_sh, batch=batch_sh,
                     metrics=metrics_sh, pspecs=pspecs)
    if use_ef:
        # residuals are grad-shaped: shard them exactly like the params
        shardings["ef"] = param_sh
        return step_fn_ef, shardings
    return step_fn, shardings


def init_all(cfg: ArchConfig, rng, *, error_feedback: bool = False):
    params = M.init_params(cfg, rng)
    if error_feedback:
        return params, init_opt_state(params), init_ef_state(params)
    return params, init_opt_state(params)
