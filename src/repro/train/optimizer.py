"""AdamW optimizer in pure JAX (pytree states) + LR schedules.

State layout intentionally mirrors the param tree (two moment trees + step),
so the ZeRO-1 sharding rule in dist/sharding.py applies leaf-wise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms, biases, 1-d params."""
    s = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return not any(k in s for k in ("norm", "bias", "/b", "b_if", "A_log"))


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu: upd(path, p, g, mu, nu),
        params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
