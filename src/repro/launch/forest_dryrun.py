import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the distributed SM-forest query step on the production mesh —
the paper-representative §Perf cell.

Builds a real forest (host-side bulk build, one SM-tree shard per 'model'
rank), lowers the shard_map'd ``forest_knn`` fan-out for a serving batch and
records the roofline terms exactly like the LM cells.

    python -m repro.launch.forest_dryrun [--capacity 32] [--frontier 64]
        [--n 262144] [--batch 256] [--k 8] [--tag base]
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed import build_forest, forest_knn  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline import analysis as RA  # noqa: E402
from repro.roofline.hlo_analysis import analyse_hlo  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "perf")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=262_144)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=32)
    ap.add_argument("--frontier", type=int, default=64)
    ap.add_argument("--metric", default="l2")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--batch-axis", default=None,
                    help="shard queries over this mesh axis (2D serving)")
    args = ap.parse_args(argv)

    mesh = make_production_mesh()          # 16x16 single pod
    n_chips = 256
    rng = np.random.default_rng(0)
    X = rng.random((args.n, args.dim), np.float32)

    t0 = time.time()
    forest, _ = build_forest(X, mesh, capacity=args.capacity,
                             metric=args.metric)
    build_s = time.time() - t0

    q_sds = jax.ShapeDtypeStruct((args.batch, args.dim), jnp.float32)

    def step(forest, q):
        return forest_knn(forest, mesh, q, k=args.k,
                          max_frontier=args.frontier,
                          batch_axis=args.batch_axis)

    with mesh:
        t0 = time.time()
        lowered = jax.jit(step).lower(forest, q_sds)
        compiled = lowered.compile()
        compile_s = time.time() - t0
    txt = compiled.as_text()
    hlo = analyse_hlo(txt)

    # 'useful' yardstick: the distance evaluations a perfectly pruned search
    # must do — frontier * capacity * levels per query, at 2*dim flops each
    n_nodes = int(np.asarray(forest.n_nodes).max())
    height = int(np.asarray(forest.height).max())
    useful_flops = args.batch * height * args.frontier * args.capacity \
        * 2 * args.dim / n_chips
    coll = {"per_op_bytes": hlo["collectives"],
            "counts": hlo["collective_counts"],
            "total_bytes": hlo["collective_bytes"]}
    roof = RA.analyse({"flops": hlo["flops"], "bytes accessed": hlo["bytes"]},
                      coll, n_chips=n_chips,
                      model_flops_global=useful_flops * n_chips).to_dict()
    rec = dict(kind="forest_knn", tag=args.tag, n=args.n, dim=args.dim,
               batch=args.batch, k=args.k, capacity=args.capacity,
               frontier=args.frontier, build_s=round(build_s, 1),
               compile_s=round(compile_s, 1), n_nodes_per_shard=n_nodes,
               height=height, roofline=roof,
               hlo_analysis={k: v for k, v in hlo.items()
                             if k != "while_trips"})
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"forest_knn__{args.tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = roof
    print(f"[forest] tag={args.tag} cap={args.capacity} F={args.frontier}: "
          f"compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
          f"collective {r['collective_s']:.4f}s bottleneck={r['bottleneck']} "
          f"(compile {compile_s:.0f}s, build {build_s:.0f}s)")
    return rec


if __name__ == "__main__":
    main()
