"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any backend initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips, TPU v5e) or 2x16x16 two-pod mesh.

    Axis roles: 'pod' — data-parallel across pods (DCN-linked in a real
    fleet; gradient all-reduce hierarchy reduces intra-pod first);
    'data' — data parallel / ZeRO / FSDP axis; 'model' — tensor parallel.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over host CPU devices (tests / examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The composite data-parallel axes of a mesh (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
