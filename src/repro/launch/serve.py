"""Batched serving driver: prefill + cached greedy decode, optional kNN-LM
mixing from an SM-tree datastore.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --batch 4 \
        --prompt-len 32 --steps 16 [--knn --lam 0.3]

On hardware the same builders serve the full configs on the production mesh
(serve/serve_step.py is what the decode_32k / long_500k dry-run cells lower).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.all_archs import smoke_config
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--knn", action="store_true",
                    help="mix with an SM-tree kNN-LM datastore")
    ap.add_argument("--lam", type=float, default=0.3)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                    global_batch=args.batch)
    prompt = jnp.asarray(synth_batch(dc, 0, with_labels=False)["tokens"])

    store = None
    if args.knn:
        from repro.serve.knnlm import KnnLmConfig, KnnLmDatastore
        rng = np.random.default_rng(0)
        keys = rng.standard_normal((2048, cfg.d_model)).astype(np.float32)
        vals = rng.integers(0, cfg.vocab_size, 2048).astype(np.int32)
        store = KnnLmDatastore(KnnLmConfig(lam=args.lam, metric="l2"),
                               cfg.d_model)
        store.build(keys, vals)

    cache = M.init_cache(cfg, args.batch, args.prompt_len + args.steps + 1)
    step_fn = jax.jit(M.decode_step, static_argnums=1)

    t0 = time.time()
    for pos in range(args.prompt_len):
        logits, cache = step_fn(params, cfg, prompt[:, pos], cache,
                                jnp.int32(pos))
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for step in range(args.steps):
        pos = args.prompt_len + step
        logits, cache = step_fn(params, cfg, tok, cache, jnp.int32(pos))
        if store is not None:
            from repro.serve.knnlm import mix_logits
            h = params["embed"][tok].astype(jnp.float32)
            logits = mix_logits(logits, store.knn_logits(
                h, logits.shape[-1]), args.lam)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    decode_s = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"[serve] batch {args.batch}: prefill {prefill_s:.2f}s, "
          f"decode {args.steps} steps in {decode_s:.2f}s "
          f"({decode_s / args.steps * 1e3:.1f} ms/step"
          f"{', kNN-LM mixed' if store else ''})")
    print("[serve] sample:", toks[0][:12])
    return toks


if __name__ == "__main__":
    main()
