"""Batched serving driver: prefill + cached greedy decode, optional kNN-LM
mixing from an SM-tree datastore.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --batch 4 \
        --prompt-len 32 --steps 16 [--knn --lam 0.3] [--mesh host]

``--mesh host`` runs the GSPMD-sharded decode step (serve/serve_step.py
builders + dist/sharding policy) over all host devices — the same code path
the decode_32k / long_500k dry-run cells lower for the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.all_archs import smoke_config
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import model as M


def _dump_obs(args) -> None:
    """With ``--obs``: print the final metrics snapshot as one parseable
    ``[obs] {...}`` line (and write it to ``--obs-out`` when given) so CI
    smoke jobs can assert on coverage without scraping the summary."""
    if not getattr(args, "obs", False):
        return
    import json

    from repro.obs.export import metrics_snapshot
    snap = metrics_snapshot()
    body = json.dumps(snap, sort_keys=True, default=repr)
    if getattr(args, "obs_out", None):
        with open(args.obs_out, "w") as f:
            f.write(body + "\n")
    print(f"[obs] {body}", flush=True)


def _build_store(args, cfg, mesh=None):
    """Synthetic kNN-LM datastore (keys near the embedding scale); with a
    mesh the tree pages replicate and query cohorts shard over 'data'."""
    from repro.serve.knnlm import KnnLmConfig, KnnLmDatastore
    rng = np.random.default_rng(0)
    keys = rng.standard_normal((2048, cfg.d_model)).astype(np.float32)
    vals = rng.integers(0, cfg.vocab_size, 2048).astype(np.int32)
    store = KnnLmDatastore(KnnLmConfig(lam=args.lam, metric="l2"),
                           cfg.d_model, mesh=mesh)
    store.build(keys, vals)
    replicas = getattr(args, "replicas", 0)
    if getattr(args, "knn_mutate", False) or getattr(args, "frontend", False):
        wal_dir = None
        if replicas:
            # replication is log shipping: the stream needs a real WAL
            import tempfile
            args._repl_root = tempfile.mkdtemp(prefix="serve-repl-")
            wal_dir = f"{args._repl_root}/wal"
        shards = getattr(args, "knn_shards", 0)
        kw = {}
        if shards and shards > 1:
            # sharded store: maintenance (offered by the front-end
            # scheduler after each mutation batch) repairs delete skew in
            # the configured mode — incremental migration steps by
            # default, stop-the-world rebuilds as the baseline
            kw = {"shards": shards,
                  "rebalance_mode": getattr(args, "rebalance_mode",
                                            "incremental"),
                  "max_skew": 1.3, "min_objects": 256}
        store.enable_stream(wal_dir=wal_dir, **kw)  # batched add/evict
    if getattr(args, "frontend", False):
        # async serving front-end: retrieval coalesces into epoch-pinned
        # cohorts, mutations ride the scheduler between epoch publishes —
        # this replaces the old alternating query/mutate decode loop
        store.enable_frontend(cohort_width=args.cohort_width or args.batch,
                              slo_ms=args.slo_ms)
        if replicas:
            # socket-fed read replicas + replica-aware router in front of
            # the front-end (stream/transport.py, serve/router.py)
            store.enable_replication(f"{args._repl_root}/mirrors",
                                     n_replicas=replicas)
    return store


def _finish_frontend(store) -> str:
    """Drain the scheduler (all submitted mutations applied) and format
    the serving counters for the run summary."""
    if store is None or store.frontend is None:
        return ""
    store.frontend.drain()
    s = store.frontend.stats.snapshot()
    repl = ""
    if store.router is not None:
        # let the followers drain the tail the drain() above appended,
        # then report how far behind they ended
        seq = store.stream.wal.next_seq - 1
        for rep in store.replicas:
            try:
                rep.catch_up(seq, timeout=10.0)
            except TimeoutError:
                pass                      # lag reported honestly below
        r = store.router.snapshot()
        repl = (f", {len(store.replicas)} replicas "
                f"(max lag {r['max_replica_lag']} records)")
        store.close_replication()
    store.close_frontend()
    return (f", frontend: {s['n_cohorts']} cohorts "
            f"(fill {s['mean_cohort_fill']}, "
            f"{s['n_mutation_batches']} mutation batches, "
            f"p50 {s['p50_ms']}ms p99 {s['p99_ms']}ms){repl}")


class _WindowMutator:
    """Sliding-window live mutation under serving: every decode step adds
    the step's (hidden-state, next-token) pairs to the datastore and evicts
    the same number of oldest entries — the evict-while-serving workload
    the paper's O(h) Delete makes possible, batched through the stream
    pipeline (one WAL-able apply per step instead of per entry)."""

    def __init__(self, store):
        self.store = store
        self.evict_cursor = 0
        self.n_ops = 0

    def step(self, h, toks):
        h = np.asarray(h, np.float32)
        toks = np.asarray(toks, np.int32)
        self.store.add_batch(h, toks)
        b = len(toks)
        self.store.evict_batch(np.arange(self.evict_cursor,
                                         self.evict_cursor + b))
        self.evict_cursor += b
        self.n_ops += 2 * b


def serve_sharded(args, cfg):
    """GSPMD-sharded greedy decode on a {data, model} mesh over all host
    devices, using the exact serve_step builders the dry-run lowers.  With
    ``--knn`` the SM-tree datastore rides along: the query cohort shards
    over 'data' (dist.sharding.query_pspecs) and retrieval runs the fused
    frontier fast path against replicated tree pages."""
    from repro.configs.base import ShapeSpec
    from repro.dist import sharding as shd
    from repro.serve.serve_step import make_decode_step, make_knnlm_mixer

    n_dev = len(jax.devices())
    nm = 2 if n_dev % 2 == 0 else 1
    mesh = jax.make_mesh((n_dev // nm, nm), ("data", "model"))
    total = args.prompt_len + args.steps + 1
    shape = ShapeSpec("serve", total, args.batch, "decode")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                    global_batch=args.batch)
    prompt = jnp.asarray(synth_batch(dc, 0, with_labels=False)["tokens"])

    with shd.use_mesh(mesh):
        fn, sh = make_decode_step(cfg, mesh, shape)
        jitted = jax.jit(fn,
                         in_shardings=(sh["params"], sh["token"],
                                       sh["cache"], sh["pos"]),
                         out_shardings=(sh["token"], sh["logits"],
                                        sh["cache"]),
                         donate_argnums=(2,))
        params = jax.device_put(M.init_params(cfg, jax.random.PRNGKey(0)),
                                sh["params"])
        cache = jax.device_put(M.init_cache(cfg, args.batch, total),
                               sh["cache"])
        mix_fn = None
        mutator = None
        store = None
        if args.knn:
            store = _build_store(args, cfg, mesh=mesh)
            mix_fn, _ = make_knnlm_mixer(cfg, mesh, shape, store,
                                         lam=args.lam)
            if args.knn_mutate:
                mutator = _WindowMutator(store)
        t0 = time.time()
        for pos in range(args.prompt_len):
            tok, logits, cache = jitted(params, prompt[:, pos], cache,
                                        jnp.int32(pos))
        prefill_s = time.time() - t0
        out = [tok]
        t0 = time.time()
        for step in range(args.steps):
            fed = tok   # the step's input token (matches single-device path)
            tok, logits, cache = jitted(params, fed, cache,
                                        jnp.int32(args.prompt_len + step))
            if mix_fn is not None:
                h = params["embed"][fed].astype(jnp.float32)
                tok = jnp.argmax(mix_fn(logits, h), -1).astype(jnp.int32)
                if mutator is not None:
                    mutator.step(h, tok)
            out.append(tok)
        jax.block_until_ready(tok)
        decode_s = time.time() - t0
        fe = _finish_frontend(store)
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    mut = (f", {mutator.n_ops} live mutations "
           f"({mutator.n_ops / decode_s:.0f} ops/s)" if mutator else "")
    print(f"[serve] mesh {dict(mesh.shape)} batch {args.batch}: "
          f"prefill {prefill_s:.2f}s, decode {args.steps} steps in "
          f"{decode_s:.2f}s ({decode_s / args.steps * 1e3:.1f} ms/step"
          f"{', kNN-LM mixed' if mix_fn else ''}{mut}{fe})")
    print("[serve] sample:", toks[0][:12])
    _dump_obs(args)
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--knn", action="store_true",
                    help="mix with an SM-tree kNN-LM datastore")
    ap.add_argument("--knn-mutate", action="store_true",
                    help="with --knn: live sliding-window add/evict of "
                         "datastore entries each decode step (batched "
                         "through the repro.stream pipeline)")
    ap.add_argument("--frontend", action="store_true",
                    help="with --knn: route retrieval through the async "
                         "serving front-end (admission queue -> epoch-"
                         "pinned cohorts; mutations ride the scheduler "
                         "between epoch publishes)")
    ap.add_argument("--slo-ms", type=float, default=5.0,
                    help="front-end admission SLO: a partial cohort "
                         "dispatches once its oldest request is this old")
    ap.add_argument("--cohort-width", type=int, default=0,
                    help="front-end cohort width (0: use --batch); one "
                         "jitted kNN geometry per width")
    ap.add_argument("--replicas", type=int, default=0,
                    help="with --frontend: ship the WAL over a socket to "
                         "N read replicas and route queries through the "
                         "replica-aware router (stream/transport.py)")
    ap.add_argument("--knn-shards", type=int, default=0,
                    help="with --knn-mutate/--frontend: shard the "
                         "datastore into a streaming forest of N SM-trees "
                         "(host-side; per-shard descent + top-k merge) so "
                         "background rebalancing exercises under serving")
    ap.add_argument("--rebalance-mode", default="incremental",
                    choices=["stop_world", "incremental"],
                    help="with --knn-shards: skew repair strategy — "
                         "'incremental' drains skew one bounded, WAL-"
                         "replayable migration step per mutation batch "
                         "behind the epoch mechanism; 'stop_world' keeps "
                         "the one-shot rebuild baseline (also the replay "
                         "path for old WALs)")
    ap.add_argument("--obs", action="store_true",
                    help="enable the observability plane (repro.obs): "
                         "metrics registry, trace spans, flight recorder; "
                         "prints a final '[obs] {...}' JSON snapshot line")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="with --obs: also write the final snapshot JSON "
                         "to PATH (for CI assertions)")
    ap.add_argument("--lam", type=float, default=0.3)
    ap.add_argument("--mesh", default="single", choices=["single", "host"],
                    help="'host': sharded decode over all host devices")
    args = ap.parse_args(argv)
    if args.prompt_len < 1:
        ap.error("--prompt-len must be >= 1 (decode needs a seed token)")
    if args.replicas and not args.frontend:
        ap.error("--replicas requires --frontend (the router fronts the "
                 "admission queue)")
    if args.knn_shards > 1:
        if not (args.knn_mutate or args.frontend):
            ap.error("--knn-shards requires --knn-mutate or --frontend "
                     "(the forest lives in the stream pipeline)")
        if args.replicas:
            ap.error("--knn-shards does not compose with --replicas "
                     "(socket replication follows single-tree engines)")
        if args.mesh == "host":
            ap.error("--knn-shards is the host-side forest; it does not "
                     "compose with --mesh host")
    if args.obs:
        from repro import obs
        obs.enable()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "host":
        if len(jax.devices()) >= 2:
            return serve_sharded(args, cfg)
        print("[serve] --mesh host requested but only 1 device visible; "
              "falling back to the UNSHARDED single-device path "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
              "to shard on CPU)", flush=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                    global_batch=args.batch)
    prompt = jnp.asarray(synth_batch(dc, 0, with_labels=False)["tokens"])

    store = _build_store(args, cfg) if args.knn else None
    mutator = (_WindowMutator(store)
               if store is not None and args.knn_mutate else None)

    cache = M.init_cache(cfg, args.batch, args.prompt_len + args.steps + 1)
    step_fn = jax.jit(M.decode_step, static_argnums=1)

    t0 = time.time()
    for pos in range(args.prompt_len):
        logits, cache = step_fn(params, cfg, prompt[:, pos], cache,
                                jnp.int32(pos))
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for step in range(args.steps):
        pos = args.prompt_len + step
        logits, cache = step_fn(params, cfg, tok, cache, jnp.int32(pos))
        if store is not None:
            from repro.serve.knnlm import mix_logits
            h = params["embed"][tok].astype(jnp.float32)
            logits = mix_logits(logits, store.knn_logits(
                h, logits.shape[-1]), args.lam)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        if store is not None and mutator is not None:
            mutator.step(h, tok)
        out.append(tok)
    jax.block_until_ready(tok)   # async dispatch: sync before timing
    decode_s = time.time() - t0
    fe = _finish_frontend(store)
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    mut = (f", {mutator.n_ops} live mutations "
           f"({mutator.n_ops / decode_s:.0f} ops/s)" if mutator else "")
    print(f"[serve] batch {args.batch}: prefill {prefill_s:.2f}s, "
          f"decode {args.steps} steps in {decode_s:.2f}s "
          f"({decode_s / args.steps * 1e3:.1f} ms/step"
          f"{', kNN-LM mixed' if store else ''}{mut}{fe})")
    print("[serve] sample:", toks[0][:12])
    _dump_obs(args)
    return toks


if __name__ == "__main__":
    main()
