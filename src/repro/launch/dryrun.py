import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh and record memory/cost/collective analyses for §Roofline.

The two lines above MUST precede every other import (jax locks the device
count at first backend init).  Do not move them.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
    python -m repro.launch.dryrun --list

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import SHAPES, get_config, list_archs, \
    shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.roofline import analysis as RA  # noqa: E402
from repro.roofline.hlo_analysis import analyse_hlo  # noqa: E402


def static_bytes_per_device(tree_sds, pspecs, mesh) -> int:
    """Exact per-device bytes of a sharded pytree (params/opt/cache)."""
    total = 0
    for sds, spec in zip(jax.tree.leaves(tree_sds),
                         jax.tree.leaves(pspecs,
                                         is_leaf=lambda x: isinstance(
                                             x, jax.sharding.PartitionSpec))):
        n = int(np.prod(sds.shape)) if sds.shape else 1
        denom = 1
        for part in spec:
            if part is None:
                continue
            for ax in (part if isinstance(part, tuple) else (part,)):
                denom *= mesh.shape[ax]
        total += -(-n // denom) * jnp.dtype(sds.dtype).itemsize
    return total

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _bf16(cfg):
    """Production numerics + TP-friendliness padding for the 16-way mesh."""
    return dataclasses.replace(cfg, param_dtype="bfloat16",
                               compute_dtype="bfloat16",
                               head_pad=16, vocab_pad_to=256)


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ["argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes", "host_argument_size_in_bytes",
                "peak_memory_in_bytes"]
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": str(e)}


def _require_dist():
    """Import the distributed stack with a diagnosable failure mode: dry-run
    cells need it, but a missing/broken install should surface as one clear
    per-cell error record, not an ImportError at entrypoint import time."""
    try:
        from repro.dist import sharding as shd
        return shd
    except ImportError as e:
        raise RuntimeError(
            "repro.dist unavailable — dry-run cells need the sharding/"
            "checkpoint stack; run tier-1 smoke paths instead on minimal "
            f"hosts ({e})") from e


def build_cell(arch: str, shape_name: str, mesh, *, cfg_extra=None,
               ts_extra=None):
    """Returns (lower_fn, static_mem dict) for a cell.  ``cfg_extra`` /
    ``ts_extra``: config / TrainSettings overrides for §Perf variants."""
    cfg = _bf16(get_config(arch))
    if cfg_extra:
        cfg = dataclasses.replace(cfg, **cfg_extra)
    shape = SHAPES[shape_name]
    shd = _require_dist()
    params_sds = M.param_specs(cfg)
    static = {}

    if shape.kind == "train":
        from repro.train.train_step import TrainSettings, make_train_step
        inputs = M.input_specs(cfg, shape)
        step_fn, sh = make_train_step(cfg, mesh, inputs,
                                      TrainSettings(attn_impl="xla",
                                                    **(ts_extra or {})))
        from repro.train.optimizer import AdamWState
        opt_sds = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                            params_sds),
            nu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                            params_sds))
        pspecs = sh["pspecs"]
        static["params_bytes_dev"] = static_bytes_per_device(
            params_sds, pspecs, mesh)
        ospec = jax.tree.map(lambda s: s.spec, sh["opt"].mu,
                             is_leaf=lambda x: hasattr(x, "spec"))
        static["opt_bytes_dev"] = 2 * static_bytes_per_device(
            opt_sds.mu, ospec, mesh)
        jitted = jax.jit(step_fn,
                         in_shardings=(sh["params"], sh["opt"], sh["batch"]),
                         out_shardings=(sh["params"], sh["opt"], sh["metrics"]),
                         donate_argnums=(0, 1))
        return (lambda: jitted.lower(params_sds, opt_sds, inputs)), static

    if shape.kind == "prefill":
        from repro.serve.serve_step import ServeSettings, make_prefill_step
        fn, sh = make_prefill_step(cfg, mesh, shape,
                                   ServeSettings(attn_impl="xla"))
        inputs = M.input_specs(cfg, shape)
        static["params_bytes_dev"] = static_bytes_per_device(
            params_sds, sh["pspecs"], mesh)
        jitted = jax.jit(fn, in_shardings=(sh["params"], sh["batch"]),
                         out_shardings=sh["logits"])
        return (lambda: jitted.lower(params_sds, inputs)), static

    # decode
    from repro.serve.serve_step import ServeSettings, make_decode_step
    seq_shard = shape.name == "long_500k"
    fn, sh = make_decode_step(cfg, mesh, shape,
                              ServeSettings(seq_shard_cache=seq_shard))
    cache_sds = M.cache_specs(cfg, shape)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    static["params_bytes_dev"] = static_bytes_per_device(
        params_sds, sh["pspecs"], mesh)
    cspec = jax.tree.map(lambda s: s.spec, sh["cache"],
                         is_leaf=lambda x: hasattr(x, "spec"))
    static["cache_bytes_dev"] = static_bytes_per_device(
        cache_sds, cspec, mesh)
    jitted = jax.jit(fn,
                     in_shardings=(sh["params"], sh["token"], sh["cache"],
                                   sh["pos"]),
                     out_shardings=(sh["token"], sh["logits"], sh["cache"]),
                     donate_argnums=(2,))
    return (lambda: jitted.lower(params_sds, tok_sds, cache_sds, pos_sds)), \
        static


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             save: bool = True, verbose: bool = True, cfg_extra=None,
             ts_extra=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "n_chips": n_chips, "tag": tag,
                    "mesh_shape": dict(mesh.shape)}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
    else:
        try:
            t0 = time.time()
            with mesh:
                thunk, static = build_cell(arch, shape_name, mesh,
                                           cfg_extra=cfg_extra,
                                           ts_extra=ts_extra)
                lowered = thunk()
                t_lower = time.time() - t0
                t0 = time.time()
                compiled = lowered.compile()
                t_compile = time.time() - t0
            mem = _mem_analysis(compiled)
            cost = _cost_analysis(compiled)
            txt = compiled.as_text()
            hlo = analyse_hlo(txt)      # trip-count-corrected per-chip totals
            mf = RA.model_flops(cfg, shape)
            coll = {"per_op_bytes": hlo["collectives"],
                    "counts": hlo["collective_counts"],
                    "total_bytes": hlo["collective_bytes"]}
            roof = RA.analyse({"flops": hlo["flops"],
                               "bytes accessed": hlo["bytes"]},
                              coll, n_chips=n_chips,
                              model_flops_global=mf).to_dict()
            record.update(status="ok", lower_s=round(t_lower, 1),
                          compile_s=round(t_compile, 1),
                          memory_analysis=mem, cost_analysis_raw=cost,
                          hlo_analysis={k: v for k, v in hlo.items()
                                        if k != "while_trips"},
                          while_trips=hlo["while_trips"],
                          static_memory=static,
                          collectives=coll, roofline=roof,
                          params=M.exact_param_count(cfg),
                          active_params=cfg.active_param_count,
                          hlo_bytes=len(txt))
        except Exception as e:
            record["status"] = "error"
            record["error"] = f"{type(e).__name__}: {e}"
            record["traceback"] = traceback.format_exc()[-4000:]
    if verbose:
        s = record["status"]
        extra = ""
        if s == "ok":
            r = record["roofline"]
            extra = (f" bottleneck={r['bottleneck']} "
                     f"frac={r['roofline_frac']:.3f} "
                     f"compile={record['compile_s']}s")
        elif s == "error":
            extra = " " + record["error"][:160]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}"
              f"{' #' + tag if tag else ''}: {s}{extra}", flush=True)
    if save:
        out_dir = ART_DIR if not tag else os.path.join(
            ART_DIR, "..", "perf")
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_kind}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a in list_archs():
            for s in SHAPES:
                print(a, s)
        return

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(
                    ART_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                rec = run_cell(arch, shape_name, mesh_kind)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
