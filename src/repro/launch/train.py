"""Production training driver: sharded train step + checkpoint/restart.

    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 200 \\
        --ckpt-dir /tmp/ckpt [--resume] [--fail-at 120]

Fault-tolerance contract exercised here (and in tests/test_checkpoint.py):
deterministic restart — because the data pipeline is stateless in the step
index and the checkpoint carries (params, opt state, step), a run killed at
any step and resumed produces the same trajectory as an uninterrupted run.
``--fail-at`` injects a hard failure to demonstrate it.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.all_archs import smoke_config
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.dist import sharding as shd
from repro.dist.checkpoint import CheckpointManager, latest_step
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainSettings, init_all, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a crash at this step (restart demo)")
    ap.add_argument("--mesh", default="host", choices=["host", "single"])
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    if args.mesh == "host" and n_dev >= 2:
        nm = 2 if n_dev % 2 == 0 else 1
        mesh = jax.make_mesh((n_dev // nm, nm), ("data", "model"))
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))

    dc = DataConfig(seed=args.data_seed, vocab_size=cfg.vocab_size,
                    seq_len=args.seq_len, global_batch=args.global_batch)
    batch0 = synth_batch(dc, 0)
    inputs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch0.items()}
    settings = TrainSettings(opt=AdamWConfig(
        lr=args.lr, warmup_steps=max(5, args.steps // 20),
        total_steps=args.steps))

    with shd.use_mesh(mesh):
        step_fn, sh = make_train_step(cfg, mesh, inputs, settings)
        jitted = jax.jit(step_fn,
                         in_shardings=(sh["params"], sh["opt"], sh["batch"]),
                         out_shardings=(sh["params"], sh["opt"], sh["metrics"]),
                         donate_argnums=(0, 1))

        params, opt = init_all(cfg, jax.random.PRNGKey(0))
        start = 0
        mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
        if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            out, manifest = mgr.restore_latest(
                {"params": params, "opt": opt._asdict()},
                shardings={"params": sh["params"]})
            params = out["params"]
            from repro.train.optimizer import AdamWState
            opt = AdamWState(**out["opt"])
            start = manifest["step"]
            print(f"[train] resumed from step {start}")
        params = jax.device_put(params, sh["params"])
        opt = jax.device_put(opt, sh["opt"])

        if start >= args.steps:
            print(f"[train] nothing to do: resumed at step {start} >= "
                  f"--steps {args.steps}")
            return None

        t0 = time.time()
        for step in range(start, args.steps):
            if step == args.fail_at:
                raise SystemExit(f"[train] injected failure at step {step}")
            batch = jax.device_put(synth_batch(dc, step), sh["batch"])
            params, opt, metrics = jitted(params, opt, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({(time.time()-t0)/max(step-start,1):.2f}s/step)",
                      flush=True)
            if mgr and step and step % args.ckpt_every == 0:
                # step+1 = next step to run: resume must NOT replay this one
                mgr.save(step + 1, {"params": params, "opt": opt._asdict()})
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt._asdict()})
            mgr.wait()
    print(f"[train] done: {args.steps - start} steps, final loss "
          f"{float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
