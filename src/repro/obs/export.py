"""Exposition: `/metrics`-style JSON snapshots, local and over the wire.

The snapshot is one flat JSON object — ``{"metrics": {name: value, …},
"recorder": {…}, "enabled": bool, "t_wall": …}`` — the shape both the
``launch/serve.py --obs`` dump and the ship-server ``{"kind":
"metrics"}`` wire reply use, so one parser serves files, stdout lines,
and sockets.

``fetch_metrics`` speaks the ship-server's length-framed protocol (the
same socket that serves WAL pulls), so replication deployments get
metrics exposition on a port they already have open.
"""

from __future__ import annotations

import json
import time

__all__ = ["metrics_snapshot", "render_json", "fetch_metrics",
           "missing_rows"]


def metrics_snapshot() -> dict:
    from repro import obs
    return {
        "enabled": obs.enabled(),
        "t_wall": time.time(),
        "metrics": obs.REGISTRY.snapshot(),
        "recorder": obs.RECORDER.stats(),
    }


def render_json(indent: int | None = None) -> str:
    return json.dumps(metrics_snapshot(), indent=indent, sort_keys=True,
                      default=repr)


def fetch_metrics(address, timeout_s: float = 5.0) -> dict:
    """Pull a metrics snapshot from a running ``WalShipServer``.

    ``address`` is the ``(host, port)`` the server listens on.  Returns
    the parsed snapshot dict."""
    import socket

    # lazy import: obs must stay importable without the stream package
    from repro.stream import transport as _t

    with socket.create_connection(address, timeout=timeout_s) as conn:
        conn.settimeout(timeout_s)
        _t._send_msg(conn, {"kind": "metrics"})
        header, payload = _t._recv_msg(conn)
        if header.get("kind") != "metrics":
            raise RuntimeError(f"unexpected reply kind {header.get('kind')!r}")
        return json.loads(payload.decode("utf-8"))


def missing_rows(snapshot: dict, prefixes) -> list[str]:
    """Which of ``prefixes`` have no metric row in ``snapshot``?  Used by
    the obs-smoke CI assertion ('snapshot covers frontend/router/WAL/
    replica/descent')."""
    metrics = snapshot.get("metrics", {})
    out = []
    for p in prefixes:
        if not any(name.startswith(p) for name in metrics):
            out.append(p)
    return out
