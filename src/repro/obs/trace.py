"""Structured trace spans with parent/child links and wall timings.

One ``trace_id`` threads a query ticket's life (admit → cohort assembly →
epoch pin → device compute → slice/reply) or a mutation batch's life
(WAL append → cohort cut → apply → split/merge → publish) across threads
and layers.  Span context propagates two ways:

* **explicitly** — tickets carry a ``SpanCtx`` so the dispatcher thread
  can parent cohort work on the submitting caller's trace, and
* **implicitly** — a thread-local "current span" lets deep callees
  (``StreamingEngine.apply`` internals, WAL append) attach to whatever
  span the calling thread has open, with zero plumbing.

Cohorts batch many tickets into one device dispatch, which is fan-*in*,
not fan-out: the cohort span is parented on one member ticket and
carries ``links`` — the trace_ids of every other member — so each
ticket's trace still reaches the shared device-compute span.

Disabled path: :func:`span` returns a shared no-op context manager and
:func:`start_span` returns a shared ``_NullSpan``; neither allocates,
takes a time reading, or touches the recorder.

Head sampling: span creation is the dominant obs cost on the serving
hot path (a cohort of 64 tickets is 64 root spans), so high-rate roots
opt in with ``sampled=True`` — only 1 in ``GATE.sample_every`` of those
calls creates a real span, the rest get ``NULL_SPAN``.  The decision is
made once at the root: children of a traced parent are always real, and
callers skip child creation when the root came back ``NULL_SPAN``.
Low-rate roots (mutation batches, replica replay, lease transitions)
never pass ``sampled`` and are always traced.
"""

from __future__ import annotations

import itertools
import os
import threading
import time

__all__ = [
    "Span",
    "SpanCtx",
    "new_trace_id",
    "start_span",
    "span",
    "current_ctx",
    "assemble_trace",
    "trace_connected",
]

_tls = threading.local()


class _Gate:
    __slots__ = ("on", "sink", "sample_every")

    def __init__(self):
        self.on = False
        self.sink = None          # callable(Span) — set by obs/__init__
        self.sample_every = 8     # head-sampling rate for sampled=True roots


GATE = _Gate()
_sample_n = itertools.count()


# ids are a random per-process prefix + an atomic counter, not per-id
# os.urandom: a ticket span costs two ids, and at serving rates the
# urandom syscalls alone were a measurable slice of the cohort budget.
# (next() on itertools.count is atomic under the GIL.)
_ID_PREFIX = os.urandom(4).hex()
_ids = itertools.count()


def new_trace_id() -> str:
    return f"{_ID_PREFIX}{next(_ids) & 0xFFFFFFFF:08x}"


def _new_span_id() -> str:
    return f"{next(_ids) & 0xFFFFFFFF:08x}"


class SpanCtx:
    """Immutable (trace_id, span_id) pair that travels on tickets."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: str, span_id: str):
        self.trace_id = trace_id
        self.span_id = span_id

    def __repr__(self):
        return f"SpanCtx({self.trace_id}/{self.span_id})"


class Span:
    __slots__ = ("trace_id", "span_id", "parent_id", "name",
                 "t_start", "t_end", "attrs", "links", "_done")

    def __init__(self, name: str, trace_id: str, parent_id: str | None,
                 links=(), attrs: dict | None = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = _new_span_id()
        self.parent_id = parent_id
        self.links = tuple(links)
        self.attrs = dict(attrs) if attrs else {}
        self.t_start = time.monotonic()
        self.t_end = None
        self._done = False

    @property
    def ctx(self) -> SpanCtx:
        return SpanCtx(self.trace_id, self.span_id)

    @property
    def duration_s(self) -> float:
        end = self.t_end if self.t_end is not None else time.monotonic()
        return end - self.t_start

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self, **attrs) -> None:
        if self._done:
            return
        self._done = True
        if attrs:
            self.attrs.update(attrs)
        self.t_end = time.monotonic()
        sink = GATE.sink
        if sink is not None:
            sink(self)

    def to_dict(self) -> dict:
        return {
            "kind": "span",
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "duration_s": (self.t_end - self.t_start)
                          if self.t_end is not None else None,
            "links": list(self.links),
            "attrs": self.attrs,
        }


class _NullSpan:
    """Shared do-nothing span for the disabled path (and as a reusable
    no-op context manager).  Stateless, hence safe to share/re-enter."""

    __slots__ = ()
    trace_id = None
    span_id = None
    parent_id = None
    name = "null"
    links = ()
    attrs: dict = {}
    ctx = None
    duration_s = 0.0

    def set(self, **attrs):
        pass

    def end(self, **attrs):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


def current_ctx() -> SpanCtx | None:
    """Ctx of the span the calling thread currently has open, if any."""
    cur = getattr(_tls, "current", None)
    return cur.ctx if cur is not None else None


def sample_root() -> bool:
    """One head-sampling decision, taken without building a span: True
    when a ``sampled=True`` root created right now would be real.  Lets
    per-ticket hot paths skip the ``start_span`` call (and its kwargs
    plumbing) entirely for the unsampled majority."""
    if not GATE.on:
        return False
    se = GATE.sample_every
    return se <= 1 or next(_sample_n) % se == 0


def start_span(name: str, *, parent: SpanCtx | None = None,
               trace_id: str | None = None, links=(), sampled: bool = False,
               **attrs):
    """Open a span (caller must ``end()`` it).  Parent resolution:
    explicit ``parent`` ctx > thread-local current span > new root.

    ``sampled=True`` marks a high-rate root: when the span *would* start
    a new trace (no parent, no explicit trace_id), only 1 in
    ``GATE.sample_every`` calls creates a real span; the rest return
    ``NULL_SPAN``.  Ignored when a parent is present — the root already
    made the decision."""
    if not GATE.on:
        return NULL_SPAN
    if parent is None:
        parent = current_ctx()
    if parent is None and sampled and trace_id is None:
        se = GATE.sample_every
        if se > 1 and next(_sample_n) % se:
            return NULL_SPAN
    if parent is not None:
        tid = trace_id if trace_id is not None else parent.trace_id
        pid = parent.span_id
    else:
        tid = trace_id if trace_id is not None else new_trace_id()
        pid = None
    return Span(name, tid, pid, links=links, attrs=attrs)


class _ActiveSpan:
    """Context manager installing a span as the thread-local current."""

    __slots__ = ("_span", "_prev")

    def __init__(self, s: Span):
        self._span = s
        self._prev = None

    def __enter__(self) -> Span:
        self._prev = getattr(_tls, "current", None)
        _tls.current = self._span
        return self._span

    def __exit__(self, exc_type, exc, tb):
        _tls.current = self._prev
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        self._span.end()
        return False


def span(name: str, *, parent: SpanCtx | None = None,
         trace_id: str | None = None, links=(), **attrs):
    """``with obs.span("wal.append", n=b):`` — opens a span, makes it the
    thread-local current (so nested spans parent on it), ends it on exit.
    Returns the shared no-op manager when tracing is off."""
    if not GATE.on:
        return NULL_SPAN
    return _ActiveSpan(start_span(name, parent=parent, trace_id=trace_id,
                                  links=links, **attrs))


# ---------------------------------------------------------------- analysis

def assemble_trace(records, trace_id: str) -> list[dict]:
    """Pick the span dicts belonging to ``trace_id`` out of a recorder
    dump/snapshot.  A span belongs if its trace_id matches *or* it links
    to the trace (cohort fan-in)."""
    out = []
    for r in records:
        if r.get("kind") != "span":
            continue
        if r.get("trace_id") == trace_id or trace_id in r.get("links", ()):
            out.append(r)
    return out


def trace_connected(records, trace_id: str) -> bool:
    """True when the trace's spans form one connected tree: exactly one
    root reachable from every span via parent edges (link-joined spans
    count as connected through the link)."""
    spans = assemble_trace(records, trace_id)
    if not spans:
        return False
    by_id = {s["span_id"]: s for s in spans}
    roots = 0
    for s in spans:
        pid = s.get("parent_id")
        if pid is None or pid not in by_id:
            # a span pulled in via links is attached through the link,
            # not a parent edge; only same-trace orphans count as roots
            if s.get("trace_id") == trace_id:
                roots += 1
    return roots == 1
