"""Bounded flight recorder: a ring of recent spans + state-transition
events, dumped to JSON when something goes wrong.

The ring holds the last ``capacity`` records (spans land here when they
``end()``; events land immediately), so a postmortem dump shows what the
process was doing in the seconds *before* the fault — the classic
flight-recorder contract.  Dump triggers are the replication-plane
faults: ``FencedOut`` (zombie leader writes after losing the lease),
``ShipStall`` (transport made no progress), ``DigestMismatch`` (replica
replay diverged), plus chaos-drill assertions.

Dumps go to ``$REPRO_OBS_DUMP_DIR`` (default: the system temp dir) as
``obs_dump_<reason>_<pid>_<n>.json``; ``last_dump_path`` points at the
most recent one so tests and operators can find it without globbing.
"""

from __future__ import annotations

import collections
import json
import os
import tempfile
import threading
import time

__all__ = ["FlightRecorder"]


class FlightRecorder:
    def __init__(self, capacity: int = 4096, *, gate=None):
        self._gate = gate
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._n_spans = 0
        self._n_events = 0
        self._n_dumps = 0
        self.last_dump_path: str | None = None

    @property
    def _on(self) -> bool:
        return self._gate is None or self._gate.on

    # ------------------------------------------------------------- record

    def record_span(self, span) -> None:
        # the span object lands in the ring as-is; to_dict() is deferred
        # to read/dump time (a span is immutable after end(), and the
        # dict build is hot-path cost the serving threads shouldn't pay).
        # No lock: deque.append is atomic under the GIL, and n_spans is
        # a diagnostic where a rare lost increment is acceptable — this
        # runs on every span end, the hottest recorder path.
        if not self._on:
            return
        self._ring.append(span)
        self._n_spans += 1

    def record_event(self, name: str, **attrs) -> None:
        """State transition: lease acquire/fence, degraded flip, shed,
        host escalation, …"""
        if not self._on:
            return
        d = {"kind": "event", "name": name, "t": time.monotonic(),
             "t_wall": time.time(), "attrs": attrs}
        with self._lock:
            self._ring.append(d)
            self._n_events += 1

    def record_fault(self, name: str, exc: BaseException | None = None,
                     **attrs) -> str | None:
        """Record a fault event and dump the ring.  Returns the dump path
        (None when disabled)."""
        if not self._on:
            return None
        if exc is not None:
            attrs = dict(attrs, exc_type=type(exc).__name__, exc=str(exc))
        self.record_event(name, **attrs)
        return self.dump(reason=name)

    # -------------------------------------------------------------- reads

    def records(self) -> list[dict]:
        with self._lock:
            raw = list(self._ring)
        return [r if isinstance(r, dict) else r.to_dict() for r in raw]

    def spans(self) -> list[dict]:
        return [r for r in self.records() if r.get("kind") == "span"]

    def events(self) -> list[dict]:
        return [r for r in self.records() if r.get("kind") == "event"]

    def stats(self) -> dict:
        with self._lock:
            return {"ring_len": len(self._ring), "n_spans": self._n_spans,
                    "n_events": self._n_events, "n_dumps": self._n_dumps}

    # -------------------------------------------------------------- dump

    def _dump_dir(self) -> str:
        return os.environ.get("REPRO_OBS_DUMP_DIR") or tempfile.gettempdir()

    def dump(self, reason: str = "manual", path: str | None = None,
             metrics: dict | None = None) -> str:
        """Write the ring (plus an optional metrics snapshot) as JSON."""
        with self._lock:
            raw = list(self._ring)
            self._n_dumps += 1
            n = self._n_dumps
        records = [r if isinstance(r, dict) else r.to_dict() for r in raw]
        if path is None:
            safe = "".join(c if c.isalnum() or c in "._-" else "_"
                           for c in reason)
            path = os.path.join(
                self._dump_dir(),
                f"obs_dump_{safe}_{os.getpid()}_{n}.json")
        doc = {
            "reason": reason,
            "t_wall": time.time(),
            "pid": os.getpid(),
            "n_records": len(records),
            "records": records,
        }
        if metrics is not None:
            doc["metrics"] = metrics
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, default=repr)
        os.replace(tmp, path)
        with self._lock:
            self.last_dump_path = path
        return path

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()
            self._n_spans = 0
            self._n_events = 0
            self._n_dumps = 0
            self.last_dump_path = None
