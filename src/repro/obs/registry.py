"""Lock-cheap metrics registry: counters, gauges, fixed-bucket histograms.

Design constraints (DESIGN.md §15):

* **Near-zero when disabled.**  Every instrument handed out by the global
  registry shares one module-level gate; the hot-path methods start with a
  single attribute check (``if not self._gate.on: return``) and touch
  nothing else.  No locks, no allocation, no time calls on the disabled
  path.
* **Bounded memory.**  Histograms are fixed bucket arrays (counts +
  count/sum/min/max) — never unbounded sample lists.  Percentiles come
  from a cumulative walk over the bucket table, so p50/p95/p99 are
  bucket-upper-bound estimates with relative error set by the bucket
  geometry (~2x steps by default).
* **Lock-cheap, not lock-free.**  Each instrument owns its own small
  ``threading.Lock``; contention is per-metric, and the critical sections
  are a handful of integer ops.  CPython's GIL already serialises the
  int increments — the locks exist so ``snapshot()`` reads are coherent
  and the code stays correct on free-threaded builds.

Instruments constructed *directly* (``Histogram("x", buckets)``) are
always-on — that is the migration path for ``FrontendStats``, whose
latency percentiles must keep working with observability off because the
bench gate reads them.  Instruments obtained through :func:`Registry
.counter` / ``gauge`` / ``histogram`` inherit the registry's gate.
"""

from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "LATENCY_BUCKETS_S",
    "DEFAULT_BUCKETS",
]

_INF = float("inf")

# ~2x geometric ladder from 100 us to ~100 s: right-sized for request
# latencies (sub-ms cohort waits up to multi-second chaos drills).
LATENCY_BUCKETS_S = tuple(1e-4 * (2.0 ** i) for i in range(21))

# General-purpose magnitude ladder for dimensionless observations.
DEFAULT_BUCKETS = tuple(10.0 ** e for e in range(-4, 8))


class _Gate:
    """Shared on/off switch.  One attribute read on the hot path."""

    __slots__ = ("on",)

    def __init__(self, on: bool = False):
        self.on = on


_ALWAYS_ON = _Gate(True)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "_gate", "_lock", "_value")

    def __init__(self, name: str, *, gate: _Gate | None = None):
        self.name = name
        self._gate = gate if gate is not None else _ALWAYS_ON
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if not self._gate.on:
            return
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_gate", "_lock", "_value")

    def __init__(self, name: str, *, gate: _Gate | None = None):
        self.name = name
        self._gate = gate if gate is not None else _ALWAYS_ON
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._gate.on:
            return
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def snapshot(self):
        return self._value


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max and percentile reads.

    ``buckets`` is an ascending tuple of upper bounds; an implicit +inf
    bucket catches the overflow.  ``observe`` is O(log n_buckets) via
    binary search; memory is O(n_buckets) forever.
    """

    __slots__ = ("name", "buckets", "_gate", "_lock", "_counts",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, buckets=LATENCY_BUCKETS_S, *,
                 gate: _Gate | None = None):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("buckets must be a non-empty ascending sequence")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self._gate = gate if gate is not None else _ALWAYS_ON
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)  # +1: the +inf bucket
        self._count = 0
        self._sum = 0.0
        self._min = _INF
        self._max = -_INF

    def _bucket_index(self, v: float) -> int:
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v: float) -> None:
        if not self._gate.on:
            return
        v = float(v)
        if math.isnan(v):
            return
        i = self._bucket_index(v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, vs) -> None:
        for v in vs:
            self.observe(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, pct: float) -> float:
        """Upper-bound estimate of the ``pct``-th percentile.

        Returns the upper bound of the bucket where the cumulative count
        crosses ``pct`` — exact max for the overflow bucket.
        """
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            target = max(1, math.ceil(total * pct / 100.0))
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= target:
                    if i < len(self.buckets):
                        # clamp to the observed max: a single sample in a
                        # wide bucket should not report the bucket ceiling
                        return min(self.buckets[i], self._max)
                    return self._max
            return self._max  # pragma: no cover

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.buckets) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = _INF
            self._max = -_INF

    def full_snapshot(self):
        base = {
            "count": self._count,
            "sum": self._sum,
            "min": self._min if self._count else 0.0,
            "max": self._max if self._count else 0.0,
        }
        base["p50"] = self.percentile(50)
        base["p95"] = self.percentile(95)
        base["p99"] = self.percentile(99)
        return base


class Registry:
    """Named instrument table.  ``counter``/``gauge``/``histogram`` are
    get-or-create, so any module can say
    ``obs.counter("wal.appends_total")`` and share the process-wide
    instrument without plumbing handles around."""

    def __init__(self, *, gate: _Gate | None = None):
        self._gate = gate if gate is not None else _Gate(True)
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    @property
    def enabled(self) -> bool:
        return self._gate.on

    def enable(self) -> None:
        self._gate.on = True

    def disable(self) -> None:
        self._gate.on = False

    def _get_or_create(self, name, kind, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = factory()
                self._instruments[name] = inst
            elif not isinstance(inst, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {kind.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get_or_create(
            name, Counter, lambda: Counter(name, gate=self._gate))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(
            name, Gauge, lambda: Gauge(name, gate=self._gate))

    def histogram(self, name: str, buckets=LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_create(
            name, Histogram, lambda: Histogram(name, buckets, gate=self._gate))

    def register(self, inst) -> None:
        """Adopt an externally-constructed instrument (typically an
        always-on one like ``FrontendStats.latency_hist``) so snapshots
        include it — instead of double-observing every sample into a
        second registry-gated copy.  Last registration wins, so after a
        ``clear()`` (or a newer front-end claiming the name) the active
        instrument is the one exported."""
        with self._lock:
            self._instruments[inst.name] = inst

    def get(self, name: str):
        return self._instruments.get(name)

    def names(self):
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """Flat ``{name: value}`` dict; histograms expand to
        ``name.count`` / ``name.sum`` / ``name.p50`` / … rows."""
        with self._lock:
            items = list(self._instruments.items())
        out: dict[str, float] = {}
        for name, inst in sorted(items):
            if isinstance(inst, Histogram):
                for k, v in inst.full_snapshot().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = inst.snapshot()
        return out

    def reset(self) -> None:
        with self._lock:
            items = list(self._instruments.values())
        for inst in items:
            inst.reset()

    def clear(self) -> None:
        with self._lock:
            self._instruments.clear()
