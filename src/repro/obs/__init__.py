"""Process-wide observability plane (DESIGN.md §15).

Four pieces behind one switch:

* :mod:`repro.obs.registry` — lock-cheap counters / gauges /
  fixed-bucket histograms (p50/p95/p99, bounded memory).
* :mod:`repro.obs.trace` — structured spans threading one ``trace_id``
  through a query ticket or mutation batch across threads and layers.
* :mod:`repro.obs.recorder` — bounded flight-recorder ring of recent
  spans + state-transition events, dumped to JSON on ``FencedOut`` /
  ``ShipStall`` / ``DigestMismatch`` / chaos assertions.
* :mod:`repro.obs.export` — `/metrics`-style JSON snapshot, served over
  the ship-server socket and by ``launch/serve.py --obs``.

Usage::

    from repro import obs

    obs.enable()
    obs.counter("wal.appends_total").inc(b)
    with obs.span("mutation.apply", n=b):
        ...
    obs.record_event("router.leader_down", misses=3)
    obs.record_fault("wal.fenced_out", exc)        # event + JSON dump
    snap = obs.export.metrics_snapshot()

**Disabled-path contract**: everything above is a single shared-flag
check when ``obs`` is off — no locks, no allocation, no clock reads, no
ring appends.  The serving hot paths keep this contract by hoisting the
check (``if obs.enabled(): …``) around any work needed to *build*
metric values (device fetches, percentile math).
"""

from __future__ import annotations

import itertools

import numpy as np

from . import export, recorder, registry, trace
from .recorder import FlightRecorder
from .registry import (DEFAULT_BUCKETS, LATENCY_BUCKETS_S, Counter, Gauge,
                       Histogram, Registry, _Gate)
from .trace import (NULL_SPAN, Span, SpanCtx, assemble_trace, current_ctx,
                    new_trace_id, sample_root, span, start_span,
                    trace_connected)

__all__ = [
    "REGISTRY", "RECORDER",
    "enabled", "enable", "disable", "reset",
    "counter", "gauge", "histogram",
    "span", "start_span", "current_ctx", "new_trace_id", "sample_root",
    "record_event", "record_fault",
    "observe_query_result", "want_level_stats", "LEVEL_STATS_EVERY",
    "set_trace_sampling", "TRACE_SAMPLE_EVERY",
    "Counter", "Gauge", "Histogram", "Registry", "FlightRecorder",
    "Span", "SpanCtx", "NULL_SPAN",
    "assemble_trace", "trace_connected",
    "LATENCY_BUCKETS_S", "DEFAULT_BUCKETS",
    "export", "recorder", "registry", "trace",
]

# One gate shared by the registry, the tracer, and the recorder: a single
# bool attribute flip turns the whole plane on or off.
_GATE = _Gate(False)
REGISTRY = Registry(gate=_GATE)
RECORDER = FlightRecorder(gate=_GATE)
trace.GATE.on = False
trace.GATE.sink = RECORDER.record_span


def enabled() -> bool:
    return _GATE.on


def enable() -> None:
    _GATE.on = True
    trace.GATE.on = True


def disable() -> None:
    _GATE.on = False
    trace.GATE.on = False


def reset() -> None:
    """Clear all instruments, spans, and the recorder ring, and re-phase
    the descent-counter sample so the next dispatch accounts (tests,
    and short ``--obs`` runs that must populate the descent rows)."""
    global _level_stats_n
    REGISTRY.clear()
    RECORDER.reset()
    _level_stats_n = itertools.count()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, buckets=LATENCY_BUCKETS_S) -> Histogram:
    return REGISTRY.histogram(name, buckets)


# High-rate trace roots (query tickets) are head-sampled: 1 in
# TRACE_SAMPLE_EVERY sampled=True roots gets a real span, so a 64-wide
# cohort carries ~8 ticket spans instead of 64.  Children of a traced
# root are always real; low-rate roots (mutations, replay) never sample.
TRACE_SAMPLE_EVERY = 8


def set_trace_sampling(every: int) -> None:
    """Set the head-sampling rate for ``sampled=True`` root spans: 1
    traces every root, N traces 1 in N.  Tests pin this to 1 so every
    ticket's trace is complete."""
    trace.GATE.sample_every = max(1, int(every))


def record_event(name: str, **attrs) -> None:
    RECORDER.record_event(name, **attrs)


def record_fault(name: str, exc: BaseException | None = None, **attrs):
    """Fault event + flight-recorder JSON dump (with a metrics snapshot
    attached).  No-op returning None when disabled."""
    if not _GATE.on:
        return None
    if exc is not None:
        attrs = dict(attrs, exc_type=type(exc).__name__, exc=str(exc))
    RECORDER.record_event(name, **attrs)
    return RECORDER.dump(reason=name, metrics=REGISTRY.snapshot())


# ------------------------------------------------- paper-level counters

# The paper-level descent counters are *sampled*: 1 in LEVEL_STATS_EVERY
# dispatches runs the level-stats descent variant (per-level pruned-by-
# bound reductions — a few percent per dispatch) and accounts queries /
# dist-evals / nodes / pruned; the other 15/16 run the default kernel
# and skip accounting entirely, including the device fetches for the
# reduction arrays.  Per-query averages (dist_evals_total /
# queries_total) stay unbiased because numerator and denominator are
# sampled together.  next() on itertools.count is atomic under the GIL.
LEVEL_STATS_EVERY = 16
_level_stats_n = itertools.count()


def want_level_stats() -> bool:
    """Should this dispatch run the level-stats variant and account the
    paper counters?  False when disabled; a 1/LEVEL_STATS_EVERY sample
    when enabled (the first dispatch after :func:`reset` always
    samples, so short runs still populate the descent rows)."""
    if not _GATE.on:
        return False
    return next(_level_stats_n) % LEVEL_STATS_EVERY == 0


def observe_query_result(res, pruned=None, *, prefix: str = "descent") -> None:
    """Accumulate the descent's per-dispatch reductions into paper-level
    counters: metric (distance) evaluations, nodes visited, and — when
    the kernel was asked for level stats — pruned-by-bound and
    pruned-by-parent per level.

    ``pruned`` is what ``smtree.knn(..., level_stats=True)`` returned:
    a ``(by_bound, by_parent)`` pair of ``[levels, b]`` stacks (a bare
    array is accepted as by-bound only, for older recorded shapes).
    ``by_parent`` feeds ``{prefix}.pruned_by_parent_total`` — entries the
    parent-distance pre-filter dropped before any metric eval, the
    quantity DESIGN.md §17 moves; note ``dist_evals_total`` already
    excludes them (it counts evaluations performed).

    Callers pass a ``QueryResult`` whose fields they are already
    materialising to the host (the serving paths call ``np.asarray`` on
    dists/ids regardless), so this adds host-side integer sums, not
    device syncs.  Always check ``obs.enabled()`` before computing
    ``pruned`` — the level-stats kernel variant is a separate jit cache
    entry that should only ever compile with obs on."""
    if not _GATE.on:
        return
    b = int(np.asarray(res.dists).shape[0])
    dist_evals = int(np.sum(np.asarray(res.dist_evals)))
    nodes = int(np.sum(np.asarray(res.page_hits)))
    overflow = int(np.sum(np.asarray(res.overflow)))
    REGISTRY.counter(f"{prefix}.queries_total").inc(b)
    REGISTRY.counter(f"{prefix}.dist_evals_total").inc(dist_evals)
    REGISTRY.counter(f"{prefix}.nodes_visited_total").inc(nodes)
    if overflow:
        REGISTRY.counter(f"{prefix}.frontier_overflow_total").inc(overflow)
    if pruned is None:
        return
    by_bound, by_parent = (pruned if isinstance(pruned, tuple)
                           else (pruned, None))
    for stack, kind in ((by_bound, "pruned_by_bound"),
                        (by_parent, "pruned_by_parent")):
        if stack is None:
            continue
        p = np.asarray(stack)           # [levels, b]
        REGISTRY.counter(f"{prefix}.{kind}_total").inc(int(p.sum()))
        for lvl in range(p.shape[0]):
            REGISTRY.counter(
                f"{prefix}.{kind}_level{lvl:02d}_total"
            ).inc(int(p[lvl].sum()))
