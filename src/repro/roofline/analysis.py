"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (seconds, per training/serving step, per chip — the SPMD-partitioned
module IS the per-chip program):

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = sum over collective ops of bytes_moved_per_chip / ICI_bw

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(counted per chip; bytes_moved applies the standard ring multipliers:
all-gather/reduce-scatter/all-to-all (n-1)/n, all-reduce 2(n-1)/n,
collective-permute 1).

MODEL_FLOPS uses the 6·N·D training rule (2·N·D inference) with N = active
params (MoE) — the useful-compute yardstick that exposes remat/duplication
waste in HLO_FLOPs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w.\-]*\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Sum per-chip bytes moved by collectives in a partitioned HLO module."""
    per_op: dict[str, float] = {}
    count: dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, shape_str, op = m.groups()
        n = _group_size(line)
        if n <= 1:
            continue
        result_bytes = _shape_bytes(shape_str)
        if op == "all-gather":
            moved = result_bytes * (n - 1) / n
        elif op == "all-reduce":
            moved = result_bytes * 2 * (n - 1) / n
        elif op == "reduce-scatter":
            moved = result_bytes * (n - 1)          # result is 1/n of operand
        elif op == "all-to-all":
            moved = result_bytes * (n - 1) / n
        else:  # collective-permute
            moved = result_bytes
        per_op[op] = per_op.get(op, 0.0) + moved
        count[op] = count.get(op, 0) + 1
        total += moved
    return {"per_op_bytes": per_op, "counts": count, "total_bytes": total}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    step_s: float           # max of the three terms (overlap-ideal)
    roofline_frac: float    # model_flops_time / step_s  (the score)

    def to_dict(self):
        return dataclasses.asdict(self)


def analyse(cost: dict, collectives: dict, *, n_chips: int,
            model_flops_global: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cbytes = float(collectives["total_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf_chip = model_flops_global / n_chips
    useful = mf_chip / flops if flops else 0.0
    ideal_s = mf_chip / PEAK_FLOPS
    frac = ideal_s / step_s if step_s else 0.0
    return Roofline(compute_s, memory_s, coll_s, flops, bytes_acc, cbytes,
                    mf_chip, useful, bottleneck, step_s, frac)


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference, D = global tokens
    processed by the step (decode: batch tokens)."""
    n = cfg.active_param_count
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq
