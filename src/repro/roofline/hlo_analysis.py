"""Corrected per-chip FLOPs / HBM-bytes / collective-bytes from compiled HLO.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for
scan-over-layers models that understates compute by the layer count.  This
module re-derives the totals by parsing the (post-SPMD-partitioning) HLO
text, walking the call graph from ENTRY, and multiplying while-loop bodies by
their trip counts (recovered from the `constant(N)` bound in the loop
condition — exact for scan-lowered loops).

Accounting model (per partitioned module = per chip):
  * FLOPs: 2 * result_elements * contraction_size for every dot; descends
    into fusions/calls/while bodies.
  * HBM bytes: result + operand bytes of every op in a computation (fusion
    internals excluded — their intermediates stay in registers/VMEM) —
    a buffer-traffic proxy consistent with post-fusion materialisation.
  * Collective bytes: ring multipliers per op type on the result size
    ((n-1)/n for AG/A2A, 2(n-1)/n for AR, (n-1) for RS relative to its
    per-shard result, 1 for permute), n = replica-group size.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OPLINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLREF = re.compile(r"(?:body|to_apply|calls)=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = byts = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    invariant_bytes: float = 0.0   # loop-invariant operand traffic: charged
    #                                ONCE per while execution, not per trip
    #                                (weights stay VMEM/register-resident
    #                                across scan iterations)
    coll: dict = field(default_factory=dict)       # op -> bytes
    coll_counts: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)      # (callee, kind)
    whiles: list = field(default_factory=list)     # (body, cond)
    max_const: int = 1                              # for trip-count recovery
    defs: dict = field(default_factory=dict)        # op name -> shape str
    gte_idx: dict = field(default_factory=dict)     # op name -> carry index
    view_of: dict = field(default_factory=dict)     # view op -> source name
    root_ops: list = field(default_factory=list)    # ROOT tuple operands
    op_operands: dict = field(default_factory=dict) # op -> (opcode, [refs])
    param_names: dict = field(default_factory=dict) # param index -> op name
    root_name: str = ""                              # ROOT op name


def parse_module(txt: str) -> tuple[dict[str, Comp], str]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    entry = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", s)
            cur = comps.setdefault(m.group(1), Comp(m.group(1)))
            entry = m.group(1)
            continue
        if s.startswith("%") and s.endswith("{") and "(" in s and "->" in s:
            m = re.match(r"%([\w.\-]+)", s)
            cur = comps.setdefault(m.group(1), Comp(m.group(1)))
            continue
        if cur is None:
            continue
        if s == "}":
            continue
        mc = _CONSTANT.search(s)
        if mc:
            cur.max_const = max(cur.max_const, int(mc.group(1)))
        m = _OPLINE.match(line)
        if not m:
            continue
        name, result, opcode, rest = m.groups()
        cur.defs[name] = result
        _, rbytes = _shape_elems_bytes(result)
        refs = re.findall(r"%([\w.\-]+)", rest)
        cur.op_operands[name] = (opcode, refs, rbytes)
        if opcode == "get-tuple-element":
            mi = re.search(r"index=(\d+)", line)
            if mi:
                cur.gte_idx[name] = int(mi.group(1))
        if opcode == "parameter":
            mi = re.search(r"parameter\((\d+)\)", line)
            if mi:
                cur.param_names[int(mi.group(1))] = name
        if opcode in ("bitcast", "reshape", "copy", "transpose", "convert") \
                and refs:
            cur.view_of[name] = refs[0]
        if opcode == "fusion":
            mc2 = _CALLREF.search(line)
            if mc2:
                cur.op_operands[name] = (opcode,
                                         [r for r in refs
                                          if r != mc2.group(1)], rbytes)
                cur.defs[name + "//callee"] = mc2.group(1)
        if s.startswith("ROOT"):
            cur.root_name = name
            if opcode == "tuple":
                cur.root_ops = refs

        if opcode == "dot":
            # operands: first two %refs in rest
            refs = re.findall(r"%([\w.\-]+)", rest)
            lhs_shape = cur.defs.get(refs[0], "") if refs else ""
            cdims = _CONTRACT.search(line)
            contraction = 1
            if lhs_shape and cdims:
                dims = _first_shape_dims(lhs_shape)
                for ci in cdims.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contraction *= dims[int(ci)]
            relems, _ = _shape_elems_bytes(result)
            cur.flops += 2.0 * relems * contraction

        if opcode in COLLECTIVES or any(opcode.startswith(c + "-") or
                                        opcode == c for c in COLLECTIVES):
            base = next(c for c in COLLECTIVES
                        if opcode == c or opcode.startswith(c))
            mg = _GROUPS.search(line)
            if mg:
                n = len(mg.group(1).split(","))
            else:
                mg2 = _GROUPS_IOTA.search(line)
                n = int(mg2.group(2)) if mg2 else 2
            if n > 1:
                if base == "all-gather":
                    moved = rbytes * (n - 1) / n
                elif base == "all-reduce":
                    moved = rbytes * 2 * (n - 1) / n
                elif base == "reduce-scatter":
                    moved = rbytes * (n - 1)
                elif base == "all-to-all":
                    moved = rbytes * (n - 1) / n
                else:
                    moved = rbytes
                cur.coll[base] = cur.coll.get(base, 0.0) + moved
                cur.coll_counts[base] = cur.coll_counts.get(base, 0) + 1

        if opcode == "while":
            mb = _CALLREF.search(line)
            mcond = _COND.search(line)
            if mb and mcond:
                cur.whiles.append((mb.group(1), mcond.group(1)))
        elif opcode in ("fusion", "call", "custom-call", "reduce",
                        "reduce-window", "scatter", "sort", "map",
                        "all-reduce", "reduce-scatter", "select-and-scatter"):
            for ref in _CALLREF.findall(line):
                cur.calls.append((ref, opcode))
        mbr = _BRANCHES.search(line)
        if mbr:
            branches = re.findall(r"%([\w.\-]+)", mbr.group(1))
            if branches:
                cur.calls.append((branches[0], "conditional"))
    for c in comps.values():
        _finalise_traffic(c, comps)
    return comps, entry


_SLICY = {"dynamic-slice", "slice", "gather", "get-tuple-element",
          "bitcast", "reshape", "convert", "broadcast"}


def _slice_only_charge(callee: Comp, param_idx: int) -> float | None:
    """If the fusion callee consumes parameter ``param_idx`` only through
    slice-like ops (including as the in-place TARGET of a
    dynamic-update-slice), return the bytes actually touched; else None
    (full operand is streamed)."""
    pname = callee.param_names.get(param_idx)
    if pname is None:
        return None
    frontier = {pname}
    total = 0.0
    for _ in range(4):                    # follow short view chains
        nxt = set()
        for opname, (opc, refs, rb) in callee.op_operands.items():
            hit = frontier & set(refs)
            if not hit:
                continue
            if opc in ("dynamic-slice", "slice", "gather"):
                total += 1.0 * rb
            elif opc == "dynamic-update-slice":
                if refs and refs[0] in frontier:
                    # param is the aliased target: touches only the window
                    upd = callee.defs.get(refs[1]) if len(refs) > 1 else None
                    total += (_shape_elems_bytes(upd)[1] if upd else rb)
                else:                      # param is the update itself
                    shp = callee.defs.get(next(iter(hit)))
                    total += _shape_elems_bytes(shp)[1] if shp else rb
            elif opc in ("bitcast", "reshape", "convert", "copy",
                         "transpose", "get-tuple-element"):
                nxt.add(opname)
            else:
                return None               # directly consumed: full read
        if not nxt:
            break
        frontier = nxt
    return total


def _fusion_write_charge(callee: Comp, rbytes: float) -> float:
    """Write-side bytes of a fusion: if the root is (a view of) a
    dynamic-update-slice, only the update window is written (the target is
    aliased in place on TPU)."""
    root = callee.root_name
    for _ in range(4):
        if root in callee.view_of:
            root = callee.view_of[root]
        else:
            break
    opc, refs, _rb = callee.op_operands.get(root, (None, [], 0.0))
    if opc == "dynamic-update-slice" and len(refs) > 1:
        upd = callee.defs.get(refs[1])
        if upd:
            return 2.0 * _shape_elems_bytes(upd)[1]
    return rbytes


def _finalise_traffic(c: Comp, comps: dict):
    """Per-opcode HBM traffic, splitting loop-invariant operand reads into
    ``invariant_bytes`` (charged once per while execution: XLA keeps
    loop-invariant buffers resident across scan iterations — e.g. an sLSTM's
    recurrent weights across a 32k-step scan).

    Invariance detection: a carry position is invariant when the body's ROOT
    tuple passes the parameter's GTE through unchanged (modulo views).
    """
    inv_idx = set()
    for i, op in enumerate(c.root_ops):
        src = op
        seen = set()
        while src in c.view_of and src not in seen:
            seen.add(src)
            src = c.view_of[src]
        if c.gte_idx.get(src) == i:
            inv_idx.add(i)
    inv_ops = {n for n, i in c.gte_idx.items() if i in inv_idx}
    changed = True
    while changed:
        changed = False
        for v, srcname in c.view_of.items():
            if srcname in inv_ops and v not in inv_ops:
                inv_ops.add(v)
                changed = True

    ZERO = {"get-tuple-element", "tuple", "bitcast", "reshape",
            "parameter", "constant", "while", "conditional", "call",
            "after-all", "partition-id", "replica-id", "iota",
            "custom-call", "optimization-barrier", "rng-bit-generator"}
    charged_inv: set[str] = set()
    for name, (opcode, refs, rbytes) in c.op_operands.items():
        if opcode in ZERO:
            continue
        if opcode in ("dynamic-slice", "slice", "gather", "broadcast",
                      "copy", "transpose", "convert", "pad"):
            c.bytes += 2.0 * rbytes          # read slice/src + write result
            continue
        if opcode == "dynamic-update-slice":
            upd = c.defs.get(refs[1]) if len(refs) > 1 else None
            c.bytes += 2.0 * (_shape_elems_bytes(upd)[1] if upd else rbytes)
            continue
        if any(opcode == x or opcode.startswith(x + "-")
               for x in COLLECTIVES):
            c.bytes += 2.0 * rbytes          # HBM side of the collective
            continue
        callee = comps.get(c.defs.get(name + "//callee", ""))
        traffic = rbytes if callee is None else \
            _fusion_write_charge(callee, rbytes)
        for i, ref in enumerate(refs[:8]):
            shp = c.defs.get(ref)
            if not shp:
                continue
            b = _shape_elems_bytes(shp)[1]
            if callee is not None:
                # fusion: a parameter consumed only through slices reads
                # just the slices (e.g. per-step dynamic-slice of stacked
                # scan residuals), not the whole buffer
                sliced = _slice_only_charge(callee, i)
                if sliced is not None:
                    b = min(b, sliced)
            if ref in inv_ops:
                if ref not in charged_inv:
                    c.invariant_bytes += b
                    charged_inv.add(ref)
            else:
                traffic += b
        c.bytes += traffic


def analyse_hlo(txt: str) -> dict:
    comps, entry = parse_module(txt)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0, "while_trips": {}}

    memo: dict[str, tuple] = {}
    trips: dict[str, int] = {}

    def total(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, {}, {})
        fl, by = c.flops, c.bytes
        coll = dict(c.coll)
        cnts = dict(c.coll_counts)
        for callee, kind in c.calls:
            f2, b2, co2, cn2 = total(callee, depth + 1)
            fl += f2
            # fusion internals: flops yes, bytes no (registers/VMEM)
            if kind not in ("fusion",):
                by += b2
            for k, v in co2.items():
                coll[k] = coll.get(k, 0.0) + v
            for k, v in cn2.items():
                cnts[k] = cnts.get(k, 0) + v
        for body, cond in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            trips[body] = trip
            f2, b2, co2, cn2 = total(body, depth + 1)
            fl += f2 * trip
            # loop-invariant reads: once per while execution, not per trip
            by += b2 * trip + comps[body].invariant_bytes
            for k, v in co2.items():
                coll[k] = coll.get(k, 0.0) + v * trip
            for k, v in cn2.items():
                cnts[k] = cnts.get(k, 0) + v * trip
        memo[name] = (fl, by, coll, cnts)
        return memo[name]

    fl, by, coll, cnts = total(entry)
    return {"flops": fl, "bytes": by, "collectives": coll,
            "collective_counts": cnts,
            "collective_bytes": float(sum(coll.values())),
            "while_trips": trips}


def top_contributors(txt: str, n: int = 8) -> list[dict]:
    """Debug: rank computations by (multiplicity-weighted) bytes and
    collective traffic to localise hotspots."""
    comps, entry = parse_module(txt)
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        c = comps.get(name)
        if c is None:
            continue
        m = mult[name]
        for callee, _ in c.calls:
            mult[callee] = mult.get(callee, 0.0) + m
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
        for body, cond in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            mult[body] = mult.get(body, 0.0) + m * trip
            if body not in seen:
                seen.add(body)
                order.append(body)
    rows = []
    for name, m in mult.items():
        c = comps.get(name)
        if c is None:
            continue
        rows.append({"comp": name[:60], "mult": m,
                     "bytes": c.bytes * m,
                     "coll": sum(c.coll.values()) * m,
                     "flops": c.flops * m})
    rows.sort(key=lambda r: -(r["bytes"] + r["coll"]))
    return rows[:n]
