"""repro.stream — the online-mutation subsystem (DESIGN.md §10).

Turns the SM-tree's O(h) insert/delete fast paths into a serving-grade
write pipeline:

  * ``batcher``   — conflict-free mutation cohorts applied by one jitted
    ``lax.scan`` per cohort; overflow/underflow rows resolve through the
    on-device split/merge passes (host escalation is the cold assert-path).
  * ``wal``       — append-only write-ahead log (segment rotation, strict
    JSON manifest); every acknowledged batch is replayable.
  * ``epoch``     — epoch-based snapshot handoff: readers pin immutable
    tree versions while the writer advances.
  * ``rebalance`` — skew detection + repair after heavy delete streams:
    one-shot stop-the-world shard rebuilds (the baseline) or deterministic
    ``MigrationPlan`` schedules executed one bounded, WAL-replayable,
    epoch-gated step per mutation batch (DESIGN.md §16).
  * ``pipeline``  — ``StreamingEngine`` / ``StreamingForest`` orchestrators
    with snapshot + WAL-tail restore (bitwise-deterministic).
  * ``replica``   — WAL-shipping read replicas: followers that tail the
    leader's segments (torn-tail-tolerant ``tail_wal`` cursor), replay
    through the same pipeline, publish bitwise-identical epochs, and
    verify it via digest exchange.
  * ``transport`` — the socket shipping layer: a ``WalShipServer`` serves
    the leader's segments, a ``WalShipClient`` mirrors them byte-identically
    on the follower host (idempotent redelivery, backoff + jitter
    reconnects), ``ShippedReplica`` composes client + replica.
  * ``lease``     — lease-based leader election with monotonic fencing
    tokens; ``promote`` fails a caught-up follower over into leadership
    (drain -> digest verify -> re-open the mirror as the new WAL, fenced).
  * ``faults``    — seeded deterministic fault injection (drop / dup /
    reorder / torn / delay / heartbeat starvation) for the chaos suite.
"""
from repro.stream.batcher import MutationBatcher, cut_cohorts  # noqa: F401
from repro.stream.epoch import EpochManager  # noqa: F401
from repro.stream.faults import (FaultInjector, FaultPlan,  # noqa: F401
                                 NO_FAULTS)
from repro.stream.lease import (FenceGuard, Lease, LeaseLost,  # noqa: F401
                                LeaseStore, Promotion, promote)
from repro.stream.pipeline import StreamingEngine, StreamingForest  # noqa: F401
from repro.stream.rebalance import (GeometryMismatch,  # noqa: F401
                                    MigrationPlan, MigrationStep,
                                    check_geometry, collect_stats,
                                    needs_rebalance, plan_migration,
                                    rebalance_shards)
from repro.stream.replica import (DigestMismatch, Replica,  # noqa: F401
                                  ledger_digest, tree_digest)
from repro.stream.transport import (ShippedReplica, ShipStall,  # noqa: F401
                                    TransportError, WalShipClient,
                                    WalShipServer)
from repro.stream.wal import (FencedOut, WalCursor, WalTailStall,  # noqa: F401
                              WriteAheadLog, iter_wal, tail_wal)
