"""Epoch-based snapshot handoff between one writer and many readers.

JAX arrays are immutable, so every published tree version is already a
consistent snapshot — what the epoch layer adds is the *protocol*: readers
(``forest_knn`` cohorts, the kNN-LM serving mixer) pin the epoch they are
querying so the version they hold is never retired out from under a
long-running descent, while the writer keeps advancing the next epoch
through the batcher.  Handoff is O(1) (a dict insert); no copy, no lock on
the data plane (DESIGN.md §10).

    mgr = EpochManager(tree0)
    with mgr.reading() as t:      # reader pins the current version
        ...query t...             # immutable, whatever the writer does
    mgr.publish(new_tree)         # writer hands off the next epoch

(``acquire``/``release`` remain for readers whose pin outlives a single
scope; ``reading()`` is the recommended form — it cannot leak a pin on an
exception mid-descent.)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

from repro import obs

__all__ = ["EpochManager"]


class EpochManager:
    """Versioned publish/acquire/release bookkeeping for immutable trees.

    ``keep`` bounds how many *unpinned* superseded versions stay resident
    (0 = only the latest); pinned versions always survive until their last
    reader releases them."""

    def __init__(self, tree: Any, *, epoch: int = 0, keep: int = 0):
        self.keep = int(keep)
        self._lock = threading.Lock()
        self._versions: dict[int, Any] = {epoch: tree}
        self._refs: dict[int, int] = {epoch: 0}
        self._meta: dict[int, Any] = {}
        self._latest = epoch

    # -- reader side -------------------------------------------------------
    @property
    def epoch(self) -> int:
        # locked: an unlocked read can observe _latest mid-publish on
        # another thread (torn against _versions/_refs bookkeeping)
        with self._lock:
            return self._latest

    def current(self) -> tuple[int, Any]:
        """Borrow the latest version without pinning.  Only safe for
        readers that provably finish before the writer's next publish can
        retire it — a long descent over a borrowed tree races
        ``_retire_locked``.  Serving paths should use ``reading()``."""
        with self._lock:
            return self._latest, self._versions[self._latest]

    def acquire(self) -> tuple[int, Any]:
        """Pin and return the latest (epoch, tree)."""
        with self._lock:
            e = self._latest
            self._refs[e] += 1
            return e, self._versions[e]

    @contextlib.contextmanager
    def reading(self, *, with_epoch: bool = False):
        """Context manager over acquire/release: pins the latest version
        for the duration of the block and releases it even on error.

            with mgr.reading() as tree:
                ...query tree...

        ``with_epoch=True`` yields ``(epoch, tree)`` instead — serving
        paths that report which snapshot answered a request (the front-end
        tags every cohort, the replica digest exchange names the epoch it
        verified) need the number without giving up the context-manager
        pin discipline."""
        e, tree = self.acquire()
        try:
            yield (e, tree) if with_epoch else tree
        finally:
            self.release(e)

    def refs(self, epoch: int) -> int:
        """Current pin count for ``epoch`` (diagnostics/tests)."""
        with self._lock:
            return self._refs.get(epoch, 0)

    def release(self, epoch: int) -> None:
        with self._lock:
            if epoch not in self._refs:
                raise KeyError(f"epoch {epoch} was never published")
            if self._refs[epoch] <= 0:
                raise ValueError(f"epoch {epoch} release without acquire")
            self._refs[epoch] -= 1
            self._retire_locked()

    def meta(self, epoch: int) -> Any:
        """Writer-attached provenance for a resident ``epoch`` (``None``
        when the publish carried none, or the version was retired).  The
        streaming forest tags migration-step publishes so diagnostics can
        tell a maintenance epoch from a mutation epoch."""
        with self._lock:
            return self._meta.get(epoch)

    # -- writer side -------------------------------------------------------
    def publish(self, tree: Any, *, meta: Any = None) -> int:
        """Install ``tree`` as the next epoch; returns its number.
        ``meta`` attaches optional provenance retrievable via ``meta()``
        while the version stays resident."""
        with self._lock:
            self._latest += 1
            self._versions[self._latest] = tree
            self._refs[self._latest] = 0
            if meta is not None:
                self._meta[self._latest] = meta
            self._retire_locked()
            latest, resident = self._latest, len(self._versions)
        if obs.enabled():
            obs.counter("epoch.publishes_total").inc()
            obs.gauge("epoch.latest").set(float(latest))
            obs.gauge("epoch.resident").set(float(resident))
        return latest

    # -- retirement --------------------------------------------------------
    def _retire_locked(self) -> None:
        stale = sorted(e for e in self._versions
                       if e != self._latest and self._refs[e] == 0)
        for e in stale[:max(0, len(stale) - self.keep)]:
            del self._versions[e]
            del self._refs[e]
            self._meta.pop(e, None)

    @property
    def resident(self) -> list[int]:
        """Epoch numbers currently held (diagnostics)."""
        with self._lock:
            return sorted(self._versions)
