"""Mutation batch executor: conflict-free cohorts + one fused device scan.

The write path mirrors what PR 2 did for reads: where the query cohort
amortised descent over a batch of queries, the mutation batcher amortises
*dispatch* over a batch of edits.  A mixed insert/delete log is cut into
**conflict-free cohorts** — maximal runs in which no object id repeats —
and each cohort is applied by ``core.smtree.apply_mutations``: one jitted
``lax.scan`` over the (donation-friendly) ``TreeArrays``, one device
round-trip per cohort instead of one per mutation.

Rows the jitted fast paths cannot absorb (leaf overflow on insert, min-fill
underflow on delete) are **escalated** to the host control plane
(``core.engine._HostView`` — the same split/merge code the one-at-a-time
engine uses) after their cohort's scan, still in log order.  Because a
cohort never contains two ops on the same id, the scan-then-escalate
reordering is invisible: ops within a cohort touch disjoint objects, so any
serialisation of {applied-in-scan} before {escalated} is equivalent to the
original log order, and — critically for the WAL contract — *replaying the
same batches through the same code yields bitwise-identical trees*.

Cohorts are padded to power-of-two lengths so the jit cache stays small
(one entry per bucket per tree geometry).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import smtree
from repro.core.smtree import (OP_DELETE, OP_INSERT, OP_NOP, ST_APPLIED,
                               ST_MERGE, ST_NOTFOUND, ST_OVERFLOW, ST_SPLIT,
                               ST_UNDERFLOW, TreeArrays)

__all__ = ["MutationBatcher", "BatchResult", "cut_cohorts", "pad_to_bucket",
           "check_oids", "escalate_rows",
           "OP_INSERT", "OP_DELETE", "OP_NOP"]


@dataclasses.dataclass
class BatchResult:
    statuses: np.ndarray      # [B] int32 — final per-row outcome (ST_*)
    n_fast: int               # rows absorbed by the jitted scan
    n_escalated: int          # rows resolved by the host control plane
    n_cohorts: int
    n_split: int = 0          # rows resolved by the on-device split pass
    n_merge: int = 0          # rows resolved by the on-device merge pass


def check_oids(oids: np.ndarray) -> None:
    """Boundary validation for mutation logs: negative object ids are
    reserved (the batcher pads cohorts with the oid = -1 NOP sentinel, and
    the jitted paths treat negatives as never-matching), so they must be
    rejected before a batch is WAL-framed or applied."""
    oids = np.asarray(oids)
    if len(oids) and int(oids.min()) < 0:
        raise ValueError(
            "negative object ids are reserved (NOP pad sentinel); got "
            f"min oid {int(oids.min())}")


def cut_cohorts(oids: np.ndarray) -> list[tuple[int, int]]:
    """Cut a log into maximal conflict-free [start, end) runs.

    A new cohort starts exactly when the incoming row's oid already appears
    in the current one, so within a cohort every id is unique and ops
    commute across the scan/escalation boundary."""
    cuts: list[tuple[int, int]] = []
    start = 0
    seen: set[int] = set()
    for i, oid in enumerate(oids):
        o = int(oid)
        if o in seen:
            cuts.append((start, i))
            start = i
            seen = set()
        seen.add(o)
    if len(oids) or not cuts:
        cuts.append((start, len(oids)))
    return cuts


def pad_to_bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clamped to [1, cap] — bounds jit cache size."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


def escalate_rows(tree: TreeArrays, statuses: np.ndarray, ops, xs,
                  oids) -> TreeArrays:
    """Host control plane for the rows the device could not absorb.

    Overflow rows (multi-level / root splits, exhausted free ring) are
    processed before underflow rows, each group in log order.  The ordering
    is load-bearing for the device-split transparency property: the on-device
    split pass handles a log-order *prefix* of a cohort's overflow rows, so
    running the overflow remainder first keeps the total split order
    identical whether device splits are on or off — within a conflict-free
    cohort the two groups touch disjoint objects, so the reorder is
    semantically invisible.  Mutates ``statuses`` in place; returns the
    updated tree."""
    rows = [i for i, st in enumerate(statuses) if st == ST_OVERFLOW]
    rows += [i for i, st in enumerate(statuses) if st == ST_UNDERFLOW]
    if not rows:
        return tree
    from repro.core.engine import _HostView
    hv = _HostView(tree)
    for i in rows:
        if ops[i] == OP_INSERT:
            hv.insert_with_split(np.asarray(xs[i], np.float32),
                                 int(oids[i]))
            statuses[i] = ST_APPLIED
        else:
            ok = hv.delete_with_merge(np.asarray(xs[i], np.float32),
                                      int(oids[i]))
            statuses[i] = ST_APPLIED if ok else ST_NOTFOUND
    return hv.to_tree()


class MutationBatcher:
    """Applies mutation logs to one ``TreeArrays`` (single tree / one forest
    shard).  Owns the tree between calls; read it back via ``.tree``.

    ``donate=True`` donates the carried tree's buffers to each scan (saves
    one tree of memory on accelerators) — only safe when no other reference
    to the tree is live, which epoch publication violates: a pinned epoch
    (stream/epoch.py) holds the same arrays the next batch would consume.
    The stream pipeline therefore leaves donation off.

    ``device_splits=False`` disables the on-device split pass (every
    overflow escalates to the host, the PR-3 behaviour) and
    ``device_merges=False`` the on-device merge pass (every underflow
    escalates, the PR-4 behaviour) — kept as benchmark baselines and the
    bitwise-transparency test references."""

    def __init__(self, tree: TreeArrays, *, max_batch: int = 4096,
                 donate: bool = False, device_splits: bool = True,
                 device_merges: bool = True):
        self.tree = tree
        self.max_batch = int(max_batch)
        self.donate = donate
        self.device_splits = device_splits
        self.device_merges = device_merges

    # -- host escalation ---------------------------------------------------
    def _escalate(self, statuses: np.ndarray, ops, xs, oids) -> np.ndarray:
        self.tree = escalate_rows(self.tree, statuses, ops, xs, oids)
        return statuses

    # -- public API --------------------------------------------------------
    def apply(self, ops, xs, oids) -> BatchResult:
        """Apply a mutation log in order.  ops [B] (OP_*), xs [B, dim],
        oids [B] (non-negative).  Returns per-row statuses; the updated
        tree is ``self.tree``."""
        ops = np.asarray(ops, np.int32)
        xs = np.asarray(xs, np.float32)
        oids = np.asarray(oids, np.int32)
        assert ops.shape == oids.shape == xs.shape[:1], \
            (ops.shape, oids.shape, xs.shape)
        check_oids(oids)
        statuses = np.zeros(len(ops), np.int32)
        n_fast = n_esc = n_split = n_merge = 0
        cohorts = cut_cohorts(oids)
        for start, end in cohorts:
            for cs in range(start, end, self.max_batch):
                ce = min(cs + self.max_batch, end)
                st = self._apply_cohort(ops[cs:ce], xs[cs:ce], oids[cs:ce])
                n_esc += int(np.isin(st, (ST_OVERFLOW, ST_UNDERFLOW)).sum())
                n_fast += int((st == ST_APPLIED).sum())
                n_split += int((st == ST_SPLIT).sum())
                n_merge += int((st == ST_MERGE).sum())
                st[np.isin(st, (ST_SPLIT, ST_MERGE))] = ST_APPLIED
                statuses[cs:ce] = self._escalate(st, ops[cs:ce], xs[cs:ce],
                                                 oids[cs:ce])
        return BatchResult(statuses, n_fast, n_esc, len(cohorts), n_split,
                           n_merge)

    def _apply_cohort(self, ops, xs, oids) -> np.ndarray:
        n = len(ops)
        bucket = pad_to_bucket(n, self.max_batch)
        if bucket != n:
            pad = bucket - n
            ops = np.concatenate([ops, np.full(pad, OP_NOP, np.int32)])
            oids = np.concatenate([oids, np.full(pad, -1, np.int32)])
            xs = np.concatenate([xs, np.zeros((pad, xs.shape[1]),
                                              np.float32)])
        tree, st = smtree.apply_mutations(self.tree, ops, xs, oids,
                                          donate=self.donate,
                                          splits=self.device_splits,
                                          merges=self.device_merges)
        st = np.array(jax.device_get(st[:n]))   # copy: escalation mutates
        self.tree = tree
        return st
