"""Lease-based leader election with monotonic fencing tokens.

One writer per WAL directory is the log's core invariant; this module is
how that invariant survives the writer dying.  A **lease** is a claim on
leadership with an expiry; whoever holds the unexpired lease is the
leader.  Every grant carries a **fencing token** that increases
monotonically across takeovers — the token, not the lease file's timing,
is what protects the log: the leader's ``WriteAheadLog`` runs a
:class:`FenceGuard` under its append lock, and the guard rejects the
append (``wal.FencedOut``) the moment a *higher* token exists.  A deposed
leader therefore cannot acknowledge — or even half-frame — a write after
its successor takes over, no matter how stale its own view of the clock
is.  (Expiry alone is never trusted for safety, only for liveness: an
expired-but-unclaimed lease keeps accepting appends, because loss is only
possible once a new claimant exists, and a new claimant always means a
higher token.)

The store is a single JSON file updated by compare-and-swap (an
``O_EXCL`` lockfile serializes writers across processes; tmp-then-rename
keeps readers crash-consistent) — deliberately the same durability idiom
as the WAL manifest.  Tests inject a manual clock so expiry is a
deterministic event, not a sleep.

Failover is :func:`promote`: a caught-up follower acquires the lease
(new, higher token), **drains** the shipped tail it already has (and, if
the dead leader's ship server still serves the directory, pulls the last
bytes — ``transport.WalShipServer`` reads straight off disk precisely so
a crashed leader's log remains drainable), optionally **verifies** the
digest exchange against the last acknowledged leader state, then re-opens
the mirror as its own authoritative ``WriteAheadLog`` with the new fence
attached and hands it to the follower engine.  From that point the
follower *is* the leader: ``apply(..., log=True)`` appends under the new
token, and the old leader's next append raises ``FencedOut``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

from repro import obs
from repro.stream.replica import Replica
from repro.stream.wal import FencedOut, WriteAheadLog

__all__ = ["Lease", "LeaseStore", "LeaseLost", "FenceGuard", "Promotion",
           "promote"]


class LeaseLost(RuntimeError):
    """A renew/release was attempted under a token that no longer holds
    the lease — the caller has been superseded and must stop leading."""


@dataclasses.dataclass(frozen=True)
class Lease:
    """One grant: ``token`` is the fencing token (monotonic across all
    grants ever made by this store, including after release)."""
    holder: str
    token: int
    expires_at: float

    def expired(self, now: float) -> bool:
        return now >= self.expires_at


class LeaseStore:
    """File-backed lease with CAS semantics.

    ``ttl_s`` is how long a grant lives without renewal; ``clock`` is
    injectable (default ``time.monotonic`` — leases are meaningful within
    one host's clock domain; cross-host deployments would use a real
    coordination service, which this store models with the same API).
    """

    def __init__(self, path: str, *, ttl_s: float = 1.0, clock=time.monotonic):
        self.path = path
        self.ttl_s = float(ttl_s)
        self.clock = clock
        self._lock = threading.Lock()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    # -- state -------------------------------------------------------------
    def read(self) -> Lease | None:
        """Current grant, or None if never granted / released."""
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (FileNotFoundError, ValueError):
            return None
        if doc.get("holder") is None:
            return None
        return Lease(holder=doc["holder"], token=int(doc["token"]),
                     expires_at=float(doc["expires_at"]))

    def _last_token(self) -> int:
        """Highest token ever granted (survives release: the record keeps
        ``token`` with ``holder: null`` so monotonicity cannot reset)."""
        try:
            with open(self.path) as f:
                return int(json.load(f).get("token", -1))
        except (FileNotFoundError, ValueError):
            return -1

    def _write(self, doc: dict) -> None:
        tmp = self.path + f".tmp-{os.getpid()}-{threading.get_ident()}"
        with open(tmp, "w") as f:
            json.dump(doc, f, sort_keys=True, allow_nan=False)
            f.write("\n")
        os.rename(tmp, self.path)

    def _cas(self, fn):
        """Run ``fn()`` (read-modify-write) under the cross-process
        lockfile; a contender holding it briefly makes us spin."""
        lockfile = self.path + ".lock"
        with self._lock:
            deadline = time.monotonic() + 5.0
            while True:
                try:
                    fd = os.open(lockfile, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                    break
                except FileExistsError:
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"lease lockfile {lockfile} wedged — stale "
                            "lock from a killed process?")
                    time.sleep(0.001)
            try:
                return fn()
            finally:
                os.close(fd)
                os.unlink(lockfile)

    # -- grants ------------------------------------------------------------
    def try_acquire(self, holder: str) -> Lease | None:
        """Claim or renew: succeeds if the lease is free, expired, or
        already ours (renewal keeps the token — same leadership term).
        A takeover mints ``last_token + 1``.  Returns None when someone
        else holds it unexpired."""
        def cas():
            now = self.clock()
            cur = self.read()
            if cur is not None and cur.holder != holder \
                    and not cur.expired(now):
                return None
            if cur is not None and cur.holder == holder \
                    and not cur.expired(now):
                token = cur.token          # renewal: same live term
            else:
                # free, expired, or even our *own* expired grant: a new
                # term — an expired token may have been beaten by a claim
                # this holder never observed, so it must never be reused
                token = self._last_token() + 1
            lease = Lease(holder=holder, token=token,
                          expires_at=now + self.ttl_s)
            self._write({"holder": lease.holder, "token": lease.token,
                         "expires_at": lease.expires_at})
            return lease
        lease = self._cas(cas)
        if lease is not None:
            obs.record_event("lease.acquired", holder=holder,
                             token=lease.token)
        return lease

    def renew(self, holder: str, token: int) -> Lease:
        """Extend our own unexpired-or-not grant; raises ``LeaseLost`` if
        a different holder/token has taken over (renewing an expired but
        untaken lease succeeds — no successor exists to conflict with)."""
        def cas():
            cur = self.read()
            if cur is None or cur.holder != holder or cur.token != token:
                raise LeaseLost(
                    f"{holder!r} token {token} superseded by "
                    f"{(cur.holder, cur.token) if cur else None}")
            lease = Lease(holder=holder, token=token,
                          expires_at=self.clock() + self.ttl_s)
            self._write({"holder": lease.holder, "token": lease.token,
                         "expires_at": lease.expires_at})
            return lease
        return self._cas(cas)

    def release(self, holder: str, token: int) -> None:
        """Step down voluntarily; keeps the token watermark on disk."""
        def cas():
            cur = self.read()
            if cur is None or cur.holder != holder or cur.token != token:
                raise LeaseLost(
                    f"{holder!r} token {token} cannot release — now "
                    f"{(cur.holder, cur.token) if cur else None}")
            self._write({"holder": None, "token": token})
        self._cas(cas)


class FenceGuard:
    """Zero-arg callable for ``WriteAheadLog(fence=...)``: raises
    ``FencedOut`` when this writer's token is no longer the store's.

    Runs on every append (under the WAL's append lock), so the decision
    uses the store's *current* record — no cached window a stale leader
    could slip an acknowledged write through.  The check is pure token
    comparison, not expiry: see the module docstring."""

    def __init__(self, store: LeaseStore, holder: str, token: int):
        self.store = store
        self.holder = holder
        self.token = token

    def __call__(self) -> None:
        cur = self.store.read()
        if cur is None or cur.token != self.token \
                or cur.holder != self.holder:
            obs.record_event(
                "lease.fenced", holder=self.holder, token=self.token,
                current=(cur.holder, cur.token) if cur else None)
            raise FencedOut(
                f"append fenced: {self.holder!r} holds token {self.token} "
                f"but lease is {(cur.holder, cur.token) if cur else None}")


@dataclasses.dataclass
class Promotion:
    """Result of :func:`promote`: the grant, the re-opened authoritative
    WAL (fence attached), and where replay ended."""
    lease: Lease
    wal: WriteAheadLog
    applied_seq: int
    digest: str


def promote(replica, store: LeaseStore, holder: str, *,
            target: tuple[int, str] | None = None,
            drain_timeout: float = 30.0, wal_kw: dict | None = None
            ) -> Promotion:
    """Fail a follower over into leadership.

    ``replica`` is a ``Replica`` or ``transport.ShippedReplica``; its WAL
    directory (the mirror, for a shipped one) becomes the authoritative
    log.  ``target`` is the last known acknowledged leader state — a
    ``(seq, digest)`` pair from ``ledger_digest`` — when available: the
    drain then *must* reach that seq and reproduce that digest
    (``DigestMismatch``/``TimeoutError`` otherwise), which is the
    zero-acknowledged-write-loss check.  Without a target the drain
    applies whatever tail is reachable and stops when dry (crash-
    consistent: everything acknowledged *and shipped* survives).

    Steps, in order — each gate must pass before the next:

    1. acquire the lease (new, higher fencing token); refuse to promote
       while the old leader's grant is live,
    2. drain the shipped tail through the normal replay path,
    3. verify the digest exchange against ``target`` if given,
    4. re-open the WAL directory with the new fence and attach it to the
       follower engine (``apply(..., log=True)`` now appends here).
    """
    obs.record_event("lease.promote_start", holder=holder)
    lease = store.try_acquire(holder)
    if lease is None:
        cur = store.read()
        raise LeaseLost(
            f"cannot promote {holder!r}: lease held by "
            f"{(cur.holder, cur.token) if cur else None} and not expired")

    plain = replica.replica if hasattr(replica, "replica") else replica
    if not isinstance(plain, Replica):
        raise TypeError(f"promote() wants a Replica/ShippedReplica, "
                        f"got {type(replica).__name__}")

    if target is not None:
        seq, digest = target
        # verify() drains through seq then compares digests; for a
        # shipped replica the pump below keeps pulling bytes too
        if hasattr(replica, "catch_up"):
            replica.catch_up(seq, timeout=drain_timeout)
        replica.verify(seq, digest, timeout=drain_timeout)
        applied, got = plain.digest()
    else:
        # drain until dry: poll until a full round moves nothing
        deadline = time.monotonic() + drain_timeout
        while True:
            try:
                n = replica.poll()
            except ConnectionError:
                n = 0           # ship source gone — mirror is all there is
            if n == 0:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(f"promotion drain of {holder!r} did not "
                                   f"go dry within {drain_timeout}s")
        applied, got = plain.digest()

    guard = FenceGuard(store, holder, lease.token)
    wal = WriteAheadLog(plain.wal_dir, fence=guard, **(wal_kw or {}))
    if wal.next_seq == 0:
        # empty mirror (promoted straight off a snapshot, no tail ever
        # shipped): seq numbering must continue from the snapshot's
        # high-water mark, not restart — replicas dedupe by seq
        wal.next_seq = applied + 1
    elif wal.next_seq != applied + 1:
        # mirror holds frames past what replay applied (a bounded-poll
        # budget left tail unapplied, or scan/apply drifted) — leading
        # from here would assign seqs the follower state never saw
        wal.close()
        raise RuntimeError(
            f"promotion of {holder!r} inconsistent: WAL next_seq "
            f"{wal.next_seq} vs applied seq {applied}")
    plain.follower.wal = wal
    obs.record_event("lease.promote_done", holder=holder,
                     token=lease.token, applied_seq=applied)
    return Promotion(lease=lease, wal=wal, applied_seq=applied, digest=got)
