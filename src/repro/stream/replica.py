"""WAL-shipping read replicas: horizontal read fan-out for the SM-tree.

The WAL is framed, crc'd, and replay-deterministic (DESIGN.md §10), which
is everything a follower needs: a replica restores the leader's snapshot,
then *tails* the WAL directory (shipped segments on a shared/replicated
mount), replaying every batch through the identical ``apply_mutations``
pipeline and every rebalance record with its recorded seed.  Because the
whole mutation path is bitwise-deterministic — cohort cuts, device
split/merge passes, headroom growth points — the follower publishes
epochs whose ``TreeArrays`` are **bitwise identical** to the leader's at
the same WAL sequence number.  That is verified, not assumed: the digest
exchange hashes every array of the pinned epoch on both sides.

    leader:   seq, digest = ledger_digest(eng)          # after any batch
    follower: rep.poll(); rep.verify(seq, digest)       # raises on drift

Resume is torn-tail tolerant (``stream.wal.tail_wal``): a frame the
leader is mid-append on — or that the shipping layer has only partially
delivered — parks the cursor at the last complete frame; the next poll
picks it up once whole.  Restarting a follower from the *same* snapshot
replays the same tail to the same state, so replicas are disposable.

Replicas serve reads only (their engines have no WAL of their own, and
``apply`` is never called with ``log=True``); writes belong to the
leader.  For mesh serving, hand the follower's shards to
``core.distributed.place_forest`` and run ``forest_knn`` against them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time

import numpy as np

from repro import obs
from repro.stream.pipeline import StreamingEngine, StreamingForest
from repro.stream.wal import KIND_BATCH, WalCursor, tail_wal

__all__ = ["tree_digest", "ledger_digest", "DigestMismatch", "Replica"]


def tree_digest(tree_or_trees) -> str:
    """SHA-256 over every array (and the geometry meta) of a pinned
    epoch — one tree or a tuple/list of forest shards.  Bitwise: two
    trees digest equal iff every leaf is byte-identical."""
    trees = (tree_or_trees if isinstance(tree_or_trees, (tuple, list))
             else (tree_or_trees,))
    h = hashlib.sha256()
    for t in trees:
        h.update(repr((t.capacity, t.dim, t.metric, t.max_nodes,
                       t.min_fill)).encode())
        for name in ("vecs", "radius", "pdist", "child", "oid", "valid",
                     "count", "is_leaf", "alive", "parent", "pslot", "root",
                     "n_nodes", "height", "free_list", "free_head"):
            a = np.asarray(getattr(t, name))
            h.update(name.encode())
            h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def ledger_digest(engine) -> tuple[int, str]:
    """Leader-side half of the digest exchange: (wal_seq, digest) of the
    currently *published* epoch.  Call between batches (an epoch-publish
    boundary); a follower that has applied through ``wal_seq`` must
    produce the same digest."""
    if engine.wal is None:
        raise ValueError("leader has no WAL — nothing to ship")
    seq = engine.wal.next_seq - 1
    with engine.epochs.reading() as pinned:
        return seq, tree_digest(pinned)


class DigestMismatch(AssertionError):
    """Follower state diverged from the leader's digest — replication bug
    or nondeterministic replay; never expected in production."""


class Replica:
    """A follower that tails a WAL directory and publishes epochs.

    ``follower`` is a ``StreamingEngine`` or ``StreamingForest`` holding
    the snapshot state (constructed with ``wal=None`` — the replica never
    appends), typically via :meth:`from_snapshot`.  ``start_seq`` is the
    WAL high-water mark baked into that snapshot (records at or below it
    are skipped).  Construction params that shape replay (``max_batch``,
    ``device_splits``/``device_merges``, ``headroom_frac``) must match the
    leader's, or replay is still *correct* but not bitwise — the digest
    exchange exists to catch exactly that.

    ``max_records_per_poll`` bounds one poll's replay work so a
    far-behind follower drains its backlog in slices instead of stalling
    its serving thread for the whole tail (reads keep landing on the
    epochs published between slices).  ``max_stall_polls`` arms the WAL
    tail's corruption diagnostic (``wal.WalTailStall``): N consecutive
    parked polls with undecodable bytes pending raises instead of
    spinning silently forever.  ``lag`` = leader's acknowledged seq minus
    applied seq — the router's staleness bound; the leader side comes
    from transport end markers (``note_leader_seq``) or, absent those,
    the highest record seq this replica has scanned.
    """

    def __init__(self, follower, wal_dir: str, *, start_seq: int = -1,
                 max_records_per_poll: int | None = None,
                 max_stall_polls: int | None = None):
        if getattr(follower, "wal", None) is not None:
            raise ValueError("replica follower must not own a WAL "
                             "(it tails the leader's)")
        self.follower = follower
        self.wal_dir = wal_dir
        self.cursor = WalCursor(seq=start_seq)
        self.max_records_per_poll = max_records_per_poll
        self.max_stall_polls = max_stall_polls
        self.leader_seq = start_seq
        self._lock = threading.Lock()     # poll() is single-flight
        self._running = False
        self._thread: threading.Thread | None = None

    # -- construction ------------------------------------------------------
    @classmethod
    def from_snapshot(cls, ckpt_dir: str, wal_dir: str, **kw) -> "Replica":
        """Restore the leader's last snapshot (no replay — the tail is
        applied incrementally by ``poll``)."""
        from repro.dist.checkpoint import read_manifest
        extra = read_manifest(ckpt_dir)["extra"]
        maker = (StreamingEngine if extra["kind"] == "smtree"
                 else StreamingForest)
        follower = maker.restore(ckpt_dir, wal=None, **kw)
        return cls(follower, wal_dir, start_seq=int(extra["wal_seq"]))

    # -- state -------------------------------------------------------------
    @property
    def applied_seq(self) -> int:
        return self.cursor.seq

    @property
    def lag(self) -> int:
        """Records the leader has acknowledged that this follower has not
        yet applied (>= 0).  Exact when the transport feeds
        ``note_leader_seq``; otherwise a lower bound from the records this
        replica has itself scanned."""
        return max(0, self.leader_seq - self.cursor.seq)

    def note_leader_seq(self, seq: int) -> None:
        """Record the leader's acknowledged high-water mark (monotonic) —
        the socket transport calls this with every end marker's
        ``leader_seq``."""
        with self._lock:
            self.leader_seq = max(self.leader_seq, int(seq))

    @property
    def epochs(self):
        return self.follower.epochs

    def digest(self) -> tuple[int, str]:
        """(applied_seq, digest) of the follower's published epoch."""
        with self._lock:
            with self.follower.epochs.reading() as pinned:
                return self.cursor.seq, tree_digest(pinned)

    # -- replication -------------------------------------------------------
    def poll(self) -> int:
        """Tail once: apply the next slice of complete records (all of
        them, or at most ``max_records_per_poll``); returns how many."""
        with self._lock:
            records, cur = tail_wal(self.wal_dir, self.cursor,
                                    max_records=self.max_records_per_poll,
                                    max_stalls=self.max_stall_polls)
            n = 0
            # one replay span per non-empty poll: the mutation trace's
            # replica leg (records carry the leader-assigned seqs)
            rspan = (obs.start_span("replica.replay",
                                    first_seq=records[0].seq,
                                    n_records=len(records))
                     if records and obs.enabled() else obs.NULL_SPAN)
            for rec in records:
                if rec.kind == KIND_BATCH:
                    self.follower.apply(rec.ops.astype(np.int32), rec.xs,
                                        rec.oids, log=False)
                else:
                    # control records (rebalance / migration plan /
                    # migration step) replay through the follower's own
                    # state machine so incremental migrations interleave
                    # bitwise-identically with the batch records
                    self.follower.apply_control(rec.kind, rec.params or {})
                # advance seq per record, not per poll: a crash mid-poll
                # resumes after the last *applied* record (offset is
                # per-poll, but the seq filter makes the re-scan skip)
                self.cursor.seq = rec.seq
                n += 1
            rspan.end(last_seq=self.cursor.seq)
            # byte position + stall count from the scan, seq from the last
            # *applied* record (they differ only if apply raised mid-poll —
            # the next poll re-scans from the old offset, seq filter skips)
            self.cursor = dataclasses.replace(cur, seq=self.cursor.seq)
            self.leader_seq = max(self.leader_seq, self.cursor.seq)
            if n and obs.enabled():
                obs.counter("replica.records_applied_total").inc(n)
                obs.gauge("replica.lag").set(float(self.lag))
                obs.gauge("replica.applied_seq").set(float(self.cursor.seq))
            return n

    def run_until(self, seq: int, *, timeout: float = 30.0,
                  interval: float = 0.005) -> None:
        """Poll until the follower has applied through ``seq``."""
        deadline = time.monotonic() + timeout
        while self.cursor.seq < seq:
            if self.poll() == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"replica stuck at seq {self.cursor.seq}, "
                        f"want {seq}")
                time.sleep(interval)

    def verify(self, seq: int, digest: str, *, timeout: float = 30.0) -> None:
        """Digest exchange, follower side: catch up through ``seq`` and
        compare digests; raises :class:`DigestMismatch` on divergence."""
        self.run_until(seq, timeout=timeout)
        got_seq, got = self.digest()
        if got_seq != seq or got != digest:
            exc = DigestMismatch(
                f"replica diverged at seq {got_seq} (want {seq}): "
                f"digest {got[:16]}… != leader {digest[:16]}…")
            obs.record_fault("replica.digest_mismatch", exc,
                             applied_seq=got_seq, want_seq=seq)
            raise exc
        if obs.enabled():
            obs.counter("replica.digest_verifies_total").inc()

    # -- background tailing ------------------------------------------------
    def start(self, *, interval: float = 0.01) -> "Replica":
        """Tail continuously on a daemon thread until ``stop()``."""
        if self._running:
            return self
        self._running = True

        def loop():
            while self._running:
                if self.poll() == 0:
                    time.sleep(interval)

        self._thread = threading.Thread(target=loop, name="replica-tail",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "Replica":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
