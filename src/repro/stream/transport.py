"""WAL segment shipping over a socket: the replica transport.

PR 6's replicas tail a *shared filesystem*; this module removes that
assumption.  A ``WalShipServer`` sits next to the leader's WAL directory
and serves its bytes; a ``WalShipClient`` maintains a **mirror** WAL
directory on the follower host and pulls whatever it is missing.  The
mirror is byte-identical to the leader's log, so everything downstream —
``tail_wal``'s torn-tail-tolerant cursor, ``Replica``'s seq-deduped
replay, digest exchange, snapshot fast-forward, and (on failover,
``stream.lease``) re-opening the mirror as the *new authoritative WAL* —
reuses the existing machinery unchanged.

Wire protocol (little-endian, one length-framed message at a time):

    u32   header length H
    H     strict-JSON header {"kind": ..., "len": n, "crc": crc32(body)}
    n     body bytes

  * client -> server  ``pull``  {segment, offset}: resume point, exactly a
    ``WalCursor``'s byte position (the client recomputes it from its own
    mirror via the same ``_scan_segment`` recovery scan the WAL uses).
  * server -> client  ``chunk`` {segment, offset, len, crc} + raw segment
    bytes; then ``end`` {active_segment, leader_seq, sealed} closing the
    round.

Delivery is **idempotent by construction**: a duplicated chunk lands at an
offset the mirror already covers and is ignored; a dropped or reordered
chunk breaks the append-at-size invariant and is ignored too, after which
the next pull round resyncs from the mirror's scanned valid length.  A
*torn* chunk (shipping layer delivered fewer bytes than the record frame
claims — injected via ``stream.faults``) is caught exactly like a crash
mid-append: the record-level crc scan parks before it and the resync
truncates it away.  Consecutive no-progress rounds are counted so a
permanently corrupt source raises a diagnostic instead of spinning.

Connection management is explicitly failure-shaped: per-connection
timeouts on both ends, and the client's background pump reconnects with
exponential backoff + seeded jitter.  Kill-and-restart of either endpoint
is supported by the real ``stop()``/``start()`` paths (the server rebinds
its port; the client resyncs from its mirror).
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time
import zlib

from repro import obs
from repro.stream.faults import FaultInjector
from repro.stream.replica import Replica
from repro.stream.wal import (WriteAheadLog, _MANIFEST, _scan_dir,
                              _scan_segment, _segment_index, _segment_name)

__all__ = ["TransportError", "ShipStall", "WalShipServer", "WalShipClient",
           "ShippedReplica"]

_LEN = struct.Struct("<I")
_MAX_HEADER = 1 << 20          # sanity bound on a wire header
CHUNK_BYTES = 1 << 16


class TransportError(ConnectionError):
    """Connection-level shipping failure (timeout, EOF, bad frame) — the
    retryable class: the client's pump backs off and reconnects."""


class ShipStall(RuntimeError):
    """The shipped stream stopped making progress for too many rounds
    while the leader kept advancing — a permanently corrupt mirror or a
    wedged source, not a transient fault.  Diagnostic, not retryable."""


# -- wire framing ----------------------------------------------------------

def _send_msg(sock: socket.socket, header: dict, body: bytes = b"") -> None:
    header = dict(header)
    header["len"] = len(body)
    header["crc"] = zlib.crc32(body)
    hb = json.dumps(header, sort_keys=True, allow_nan=False).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb + body)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            part = sock.recv(n - len(buf))
        except (socket.timeout, OSError) as e:
            raise TransportError(f"recv failed: {e}") from e
        if not part:
            raise TransportError("connection closed mid-message")
        buf.extend(part)
    return bytes(buf)


def _recv_msg(sock: socket.socket) -> tuple[dict, bytes]:
    (hlen,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if hlen > _MAX_HEADER:
        raise TransportError(f"oversized wire header ({hlen} bytes)")
    try:
        header = json.loads(_recv_exact(sock, hlen))
    except ValueError as e:
        raise TransportError(f"unparseable wire header: {e}") from e
    body = _recv_exact(sock, int(header.get("len", 0)))
    if zlib.crc32(body) != header.get("crc"):
        raise TransportError("wire body crc mismatch")
    return header, body


# -- server (leader side) --------------------------------------------------

class WalShipServer:
    """Serves a WAL directory's bytes to pulling followers.

    ``wal``(a ``WriteAheadLog``) or ``leader_seq_fn`` supplies the
    leader's acknowledged high-water mark for the ``end`` marker —
    followers feed it to ``Replica.note_leader_seq`` so ``lag`` is exact
    rather than observed.  ``fault`` (a ``stream.faults.FaultInjector``)
    is applied to each response's message list — drop/dup/reorder/torn —
    so tests exercise the client's resync machinery deterministically."""

    def __init__(self, wal_dir: str, *, host: str = "127.0.0.1",
                 port: int = 0, wal: WriteAheadLog | None = None,
                 leader_seq_fn=None, fault: FaultInjector | None = None,
                 timeout_s: float = 5.0, chunk_bytes: int = CHUNK_BYTES,
                 max_chunks: int = 64):
        self.wal_dir = wal_dir
        self.host = host
        self._want_port = port
        self.port: int | None = None
        self.wal = wal
        self.leader_seq_fn = leader_seq_fn
        self.fault = fault
        self.timeout_s = timeout_s
        self.chunk_bytes = int(chunk_bytes)
        self.max_chunks = int(max_chunks)
        self._listener: socket.socket | None = None
        self._threads: list[threading.Thread] = []
        self._running = False
        self._lock = threading.Lock()

    # -- lifecycle ---------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        if self.port is None:
            raise RuntimeError("server not started")
        return (self.host, self.port)

    def start(self) -> "WalShipServer":
        with self._lock:
            if self._running:
                return self
            ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            # restart-after-kill rebinds the port a prior incarnation chose
            ls.bind((self.host, self.port if self.port is not None
                     else self._want_port))
            ls.listen(16)
            ls.settimeout(0.2)        # accept loop polls _running
            self.port = ls.getsockname()[1]
            self._listener = ls
            self._running = True
        t = threading.Thread(target=self._accept_loop, name="walship-accept",
                             daemon=True)
        t.start()
        self._threads = [t]
        return self

    def stop(self) -> None:
        with self._lock:
            self._running = False
            ls, self._listener = self._listener, None
        if ls is not None:
            ls.close()
        for t in self._threads:
            t.join(timeout=10)
        self._threads = []

    def __enter__(self) -> "WalShipServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- serving -----------------------------------------------------------
    def _accept_loop(self) -> None:
        while self._running:
            ls = self._listener
            if ls is None:
                return
            try:
                conn, _ = ls.accept()
            except socket.timeout:
                continue
            except OSError:
                return                      # listener closed under us
            conn.settimeout(self.timeout_s)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="walship-conn", daemon=True).start()

    def _leader_seq(self) -> int:
        if self.wal is not None:
            return self.wal.next_seq - 1
        if self.leader_seq_fn is not None:
            return int(self.leader_seq_fn())
        return -1

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            with conn:
                while self._running:
                    try:
                        header, _ = _recv_msg(conn)
                    except TransportError:
                        return              # client went away / timed out
                    if header.get("kind") == "metrics":
                        # `/metrics` over the socket the deployment
                        # already has open: reply with the process-wide
                        # JSON snapshot and keep the connection usable
                        from repro.obs.export import metrics_snapshot
                        body = json.dumps(
                            metrics_snapshot(), default=repr).encode("utf-8")
                        _send_msg(conn, {"kind": "metrics"}, body)
                        continue
                    if header.get("kind") != "pull":
                        return              # protocol violation: hang up
                    msgs = self._build_response(int(header["segment"]),
                                                int(header["offset"]))
                    if self.fault is not None:
                        msgs = self._inject(msgs)
                        self.fault.maybe_delay()
                    for h, body in msgs:
                        _send_msg(conn, h, body)
        except OSError:
            return                          # connection dropped mid-send

    def _inject(self, msgs: list) -> list:
        """Fault-inject the data chunks (never the end marker — dropping
        the round terminator models nothing the byte protocol allows, the
        connection would just desync; killing the *connection* is the
        injector's delay/drop-at-chunk level plus the kill/restart API)."""
        chunks = [m for m in msgs if m[0]["kind"] == "chunk"]
        tail = [m for m in msgs if m[0]["kind"] != "chunk"]
        chunks = self.fault.filter(chunks)
        chunks = [(h, self.fault.torn(b)) for h, b in chunks]
        return chunks + tail

    def _build_response(self, segment: int, offset: int) -> list:
        """Chunk messages covering bytes past (segment, offset), oldest
        first, then the ``end`` marker.  Reads straight off the directory
        so it serves equally with the leader process alive (in-process
        WAL handle) or dead (failover drain: a promoted follower can
        finish pulling the tail of a crashed leader's directory)."""
        msgs: list[tuple[dict, bytes]] = []
        names = _scan_dir(self.wal_dir)
        active = _segment_index(names[-1]) if names else 0
        budget = self.max_chunks
        for name in names:
            idx = _segment_index(name)
            if idx < segment or budget <= 0:
                continue
            path = os.path.join(self.wal_dir, name)
            try:
                size = os.path.getsize(path)
            except OSError:
                continue
            pos = offset if idx == segment else 0
            while pos < size and budget > 0:
                n = min(self.chunk_bytes, size - pos)
                with open(path, "rb") as f:
                    f.seek(pos)
                    body = f.read(n)
                if not body:
                    break
                msgs.append(({"kind": "chunk", "segment": idx,
                              "offset": pos}, body))
                pos += len(body)
                budget -= 1
        msgs.append(({"kind": "end", "active_segment": active,
                      "leader_seq": self._leader_seq()}, b""))
        return msgs


# -- client (follower side) ------------------------------------------------

class WalShipClient:
    """Pulls a leader's WAL into a local mirror directory.

    The mirror obeys one invariant the downstream ``tail_wal`` positional-
    sealing rule depends on: **only the newest mirror segment may be
    incomplete**.  Chunks are accepted only when they append exactly at
    the mirror's current size; advancing to the next segment requires the
    current one to parse completely (``_scan_segment`` — the same scan
    WAL recovery runs).  Anything else — duplicate, gap, reordering,
    torn delivery — is dropped and repaired by the next round's resync,
    which recomputes the resume point from the mirror's scanned valid
    length and truncates torn bytes, exactly like crash recovery."""

    def __init__(self, address: tuple[str, int], mirror_dir: str, *,
                 timeout_s: float = 5.0, backoff_base_s: float = 0.02,
                 backoff_max_s: float = 2.0, seed: int = 0,
                 max_stall_rounds: int = 200):
        self.address = (address[0], int(address[1]))
        self.mirror_dir = mirror_dir
        os.makedirs(mirror_dir, exist_ok=True)
        self.timeout_s = timeout_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.max_stall_rounds = int(max_stall_rounds)
        import random
        self._jitter = random.Random(seed)
        self._sock: socket.socket | None = None
        self.leader_seq = -1          # from the last end marker
        self.active_segment = 0
        self.n_rounds = 0
        self.n_reconnects = 0
        self.n_rejected_chunks = 0
        self._stall_rounds = 0
        self._seg = 0                 # mirror append position
        self._size = 0
        self._sealed: set[int] = set()
        self._resync()
        self._running = False
        self._thread: threading.Thread | None = None

    # -- mirror bookkeeping ------------------------------------------------
    def _path(self, idx: int) -> str:
        return os.path.join(self.mirror_dir, _segment_name(idx))

    def _resync(self) -> None:
        """Recompute the append position from the mirror itself: scan the
        newest segment with the WAL's own recovery scan and truncate any
        torn tail (a killed receiver, or a torn injected chunk) so the
        next accepted chunk appends after the last *complete* frame."""
        names = _scan_dir(self.mirror_dir)
        if not names:
            self._seg, self._size = 0, 0
            return
        self._sealed = {_segment_index(n) for n in names[:-1]}
        idx = _segment_index(names[-1])
        path = self._path(idx)
        _, valid = _scan_segment(path, sealed=False)
        if valid < os.path.getsize(path):
            with open(path, "r+b") as f:
                f.truncate(valid)
        self._seg, self._size = idx, valid

    def _segment_complete(self) -> bool:
        """Whole current segment parses as frames (safe to seal).  A
        missing or record-less segment is *not* complete: advancing past
        it would leave a hole in the mirror (a dropped/reordered first
        chunk of a new segment must not skip the one before it)."""
        path = self._path(self._seg)
        if not os.path.exists(path):
            return False
        records, valid = _scan_segment(path, sealed=False)
        return (bool(records)
                and valid == os.path.getsize(path) == self._size)

    def _seal_current(self) -> None:
        """Mark the current mirror segment sealed: record it in the mirror
        manifest (entries recomputed locally — the mirror's bytes are the
        leader's bytes, so the entries match) and advance."""
        idx = self._seg
        if idx in self._sealed:
            return
        records, _ = _scan_segment(self._path(idx), sealed=True)
        entry = WriteAheadLog._manifest_entry(_segment_name(idx), records)
        self._sealed.add(idx)
        doc = {"version": 1, "next_seq": (records[-1].seq + 1 if records
                                          else 0)}
        entries = []
        mpath = os.path.join(self.mirror_dir, _MANIFEST)
        if os.path.exists(mpath):
            with open(mpath) as f:
                entries = json.load(f)["segments"]
        if entry["name"] not in {e["name"] for e in entries}:
            entries.append(entry)
        doc["segments"] = sorted(entries, key=lambda e: e["name"])
        tmp = mpath + f".tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
            f.write("\n")
        os.rename(tmp, mpath)

    # -- one pull round ----------------------------------------------------
    def _connect(self) -> socket.socket:
        if self._sock is not None:
            return self._sock
        try:
            s = socket.create_connection(self.address,
                                         timeout=self.timeout_s)
        except OSError as e:
            raise TransportError(f"connect to {self.address} failed: "
                                 f"{e}") from e
        s.settimeout(self.timeout_s)
        self._sock = s
        return s

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def poll(self) -> int:
        """One pull round: request from the mirror's resume point, apply
        every acceptable chunk, process the end marker.  Returns bytes
        appended to the mirror.  Raises ``TransportError`` on connection
        trouble (caller backs off and retries — the background pump does
        this automatically) and ``ShipStall`` after ``max_stall_rounds``
        consecutive no-progress rounds while the leader is known to be
        ahead."""
        sock = self._connect()
        try:
            _send_msg(sock, {"kind": "pull", "segment": self._seg,
                             "offset": self._size})
            appended = 0
            while True:
                header, body = _recv_msg(sock)
                kind = header.get("kind")
                if kind == "chunk":
                    appended += self._accept(int(header["segment"]),
                                             int(header["offset"]), body)
                elif kind == "end":
                    self.active_segment = int(header["active_segment"])
                    self.leader_seq = max(self.leader_seq,
                                          int(header["leader_seq"]))
                    if (self.active_segment > self._seg
                            and self._segment_complete()):
                        # rotation observed with no follow-on chunk yet:
                        # seal so tail_wal's manifest fast-forward works
                        self._seal_current()
                    break
                else:
                    raise TransportError(f"unknown wire message {kind!r}")
        except TransportError:
            self.close()
            self._resync()       # a torn receive may sit in the mirror
            raise
        self.n_rounds += 1
        if obs.enabled():
            obs.counter("transport.rounds_total").inc()
            obs.counter("transport.bytes_shipped_total").inc(appended)
        if appended == 0:
            self._resync()       # repair before deciding we are stuck
            behind = self.leader_seq >= 0 and self._behind()
            self._stall_rounds = self._stall_rounds + 1 if behind else 0
            if self._stall_rounds >= self.max_stall_rounds:
                exc = ShipStall(
                    f"mirror stuck at segment {self._seg} offset "
                    f"{self._size} for {self._stall_rounds} rounds while "
                    f"leader is at seq {self.leader_seq} — corrupt "
                    "source or mirror")
                obs.record_fault("transport.ship_stall", exc,
                                 segment=self._seg, offset=self._size,
                                 rounds=self._stall_rounds,
                                 leader_seq=self.leader_seq)
                raise exc
        else:
            self._stall_rounds = 0
        return appended

    def _behind(self) -> bool:
        """Mirror's newest complete record is behind the leader's ack."""
        records, _ = _scan_segment(self._path(self._seg), sealed=False) \
            if os.path.exists(self._path(self._seg)) else ([], 0)
        last = records[-1].seq if records else -1
        return last < self.leader_seq

    def _accept(self, seg: int, off: int, body: bytes) -> int:
        """Append-at-size or reject (idempotent redelivery: duplicates and
        out-of-order chunks are dropped, resync repairs)."""
        if seg == self._seg and off == self._size:
            pass                              # in-order append
        elif seg == self._seg + 1 and off == 0 and self._segment_complete():
            self._seal_current()
            self._seg, self._size = seg, 0
        else:
            self.n_rejected_chunks += 1
            if obs.enabled():
                obs.counter("transport.rejected_chunks_total").inc()
            return 0
        with open(self._path(self._seg), "ab") as f:
            f.write(body)
        self._size += len(body)
        return len(body)

    # -- background pump ---------------------------------------------------
    def start(self, *, interval: float = 0.01) -> "WalShipClient":
        """Pull continuously on a daemon thread; reconnects with
        exponential backoff + jitter on transport errors."""
        if self._running:
            return self
        self._running = True

        def pump():
            failures = 0
            while self._running:
                try:
                    n = self.poll()
                    failures = 0
                    if n == 0:
                        time.sleep(interval)
                except TransportError:
                    failures += 1
                    delay = min(self.backoff_max_s,
                                self.backoff_base_s * (2 ** (failures - 1)))
                    # full jitter: desynchronizes a fleet of reconnecting
                    # followers hammering a restarted leader
                    time.sleep(delay * (0.5 + 0.5 * self._jitter.random()))
                    self.n_reconnects += 1
                    if obs.enabled():
                        obs.counter("transport.reconnects_total").inc()
                except ShipStall:
                    self._running = False
                    raise

        self._thread = threading.Thread(target=pump, name="walship-pump",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        self.close()

    def __enter__(self) -> "WalShipClient":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# -- composition: socket-fed replica ---------------------------------------

class ShippedReplica:
    """A read replica fed over the socket transport: ``WalShipClient``
    pumping a local mirror + ``Replica`` tailing that mirror.  The two
    halves stay independently testable; this class only sequences them
    (ship bytes, note the leader's ack high-water, replay) and carries
    the leader-reported seq into ``Replica.lag`` for the router's
    staleness bound."""

    def __init__(self, follower, address: tuple[str, int], mirror_dir: str,
                 *, start_seq: int = -1, seed: int = 0,
                 timeout_s: float = 5.0, max_records_per_poll: int | None = None,
                 max_stall_polls: int | None = 500):
        self.client = WalShipClient(address, mirror_dir, seed=seed,
                                    timeout_s=timeout_s)
        self.replica = Replica(follower, mirror_dir, start_seq=start_seq,
                               max_records_per_poll=max_records_per_poll,
                               max_stall_polls=max_stall_polls)
        self._running = False
        self._thread: threading.Thread | None = None

    # -- delegation --------------------------------------------------------
    @property
    def follower(self):
        return self.replica.follower

    @property
    def epochs(self):
        return self.replica.epochs

    @property
    def applied_seq(self) -> int:
        return self.replica.applied_seq

    @property
    def lag(self) -> int:
        return self.replica.lag

    def digest(self):
        return self.replica.digest()

    def verify(self, seq: int, digest: str, *, timeout: float = 30.0):
        return self.replica.verify(seq, digest, timeout=timeout)

    # -- pumping -----------------------------------------------------------
    def poll(self) -> int:
        """Ship once, then replay once; returns records applied."""
        self.client.poll()
        self.replica.note_leader_seq(self.client.leader_seq)
        return self.replica.poll()

    def catch_up(self, seq: int, *, timeout: float = 30.0,
                 interval: float = 0.002) -> None:
        """Pump until the follower has applied through ``seq`` (transport
        errors back off and retry inside the window)."""
        deadline = time.monotonic() + timeout
        failures = 0
        while self.replica.cursor.seq < seq:
            try:
                n = self.poll()
                failures = 0
            except TransportError:
                n, failures = 0, failures + 1
                time.sleep(min(0.2, 0.01 * (2 ** min(failures, 4))))
            if n == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"shipped replica stuck at seq "
                        f"{self.replica.cursor.seq}, want {seq}")
                time.sleep(interval)

    def start(self, *, interval: float = 0.005) -> "ShippedReplica":
        if self._running:
            return self
        self._running = True

        def pump():
            failures = 0
            while self._running:
                try:
                    n = self.poll()
                    failures = 0
                except TransportError:
                    n, failures = 0, failures + 1
                    delay = min(self.client.backoff_max_s,
                                self.client.backoff_base_s
                                * (2 ** (failures - 1)))
                    time.sleep(delay
                               * (0.5 + 0.5 * self.client._jitter.random()))
                    self.client.n_reconnects += 1
                    if obs.enabled():
                        obs.counter("transport.reconnects_total").inc()
                if n == 0:
                    time.sleep(interval)

        self._thread = threading.Thread(target=pump, name="shipped-replica",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        self.client.close()

    def __enter__(self) -> "ShippedReplica":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
