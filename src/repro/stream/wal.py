"""Append-only write-ahead log for SM-tree mutation streams.

Every mutation batch the stream batcher applies is first framed into the
active segment file, so any tree state is reproducible as *last snapshot +
WAL tail replay* (repro.stream.pipeline) — the same bitwise-deterministic
kill/resume contract the training checkpoints carry (DESIGN.md §7/§10).

Layout (one directory per log):

    <dir>/manifest.json           strict JSON: sealed segments + next_seq
    <dir>/segment_00000000.wal    framed records, append-only
    <dir>/segment_00000001.wal    ...

Record framing (little-endian):

    u32   header length H
    H     bytes of strict-JSON header
          {"kind": "batch"|"rebalance", "seq": n, ...payload geometry...}
    P     payload bytes (ops int8 · oids int32 · xs f32, in that order;
          empty for control records), crc32 recorded in the header

The manifest is rewritten atomically (tmp-then-rename) when a segment
seals; the active segment is recovered by scanning on open.  A torn tail
record in the *active* segment (crash mid-append) terminates replay
cleanly — exactly the batch that never acknowledged — while corruption in
a sealed segment raises.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
import zlib
from typing import Any, Iterator

import numpy as np

from repro import obs

_MANIFEST = "manifest.json"
_SEG_PREFIX = "segment_"
_SEG_SUFFIX = ".wal"
_LEN = struct.Struct("<I")

KIND_BATCH = "batch"
KIND_REBALANCE = "rebalance"
# Incremental-rebalancing control records (DESIGN.md §16).  A plan record
# carries the full deterministic migration schedule; each step record marks
# exactly where in the mutation order one bounded move executed.  Both are
# params-only control frames, so the framing/crc machinery below needs no
# special case for them.
KIND_MIGRATION_PLAN = "migration_plan"
KIND_MIGRATION_STEP = "migration_step"


class FencedOut(RuntimeError):
    """A deposed leader tried to append: its fencing token is stale (a
    newer leader holds the lease — stream/lease.py).  The append never
    reached the log, so the write was never acknowledged and is cleanly
    the *client's* to retry against the new leader."""


class WalTailStall(RuntimeError):
    """A follower's tail poll has made no progress for ``max_stalls``
    consecutive polls while unread bytes sit past its cursor — a truly
    corrupt segment (planted mid-segment corruption, a mis-shipped
    chunk), not the benign torn tail of a leader mid-append, which the
    next completed append always clears."""


@dataclasses.dataclass
class WalRecord:
    kind: str
    seq: int
    ops: np.ndarray | None = None      # [n] int8  (batch records)
    oids: np.ndarray | None = None     # [n] int32
    xs: np.ndarray | None = None       # [n, dim] f32
    params: dict | None = None         # control records (rebalance)


def _segment_name(index: int) -> str:
    return f"{_SEG_PREFIX}{index:08d}{_SEG_SUFFIX}"


def _segment_index(name: str) -> int:
    return int(name[len(_SEG_PREFIX):-len(_SEG_SUFFIX)])


def _encode(record: WalRecord) -> bytes:
    header: dict[str, Any] = {"kind": record.kind, "seq": record.seq}
    payload = b""
    if record.kind == KIND_BATCH:
        ops = np.ascontiguousarray(record.ops, np.int8)
        oids = np.ascontiguousarray(record.oids, np.int32)
        xs = np.ascontiguousarray(record.xs, np.float32)
        assert ops.shape == oids.shape == xs.shape[:1], \
            (ops.shape, oids.shape, xs.shape)
        payload = ops.tobytes() + oids.tobytes() + xs.tobytes()
        header["n"] = int(ops.shape[0])
        header["dim"] = int(xs.shape[1])
    else:
        header["params"] = record.params or {}
    header["crc"] = zlib.crc32(payload)
    hb = json.dumps(header, sort_keys=True, allow_nan=False).encode()
    return _LEN.pack(len(hb)) + hb + payload


def _decode_header(header: dict) -> tuple[int, WalRecord | None]:
    """(payload length, partially-built record)."""
    if header["kind"] == KIND_BATCH:
        n, dim = int(header["n"]), int(header["dim"])
        return n * (1 + 4 + 4 * dim), WalRecord(KIND_BATCH, int(header["seq"]))
    return 0, WalRecord(header["kind"], int(header["seq"]),
                        params=header.get("params", {}))


def _scan_segment(path: str, *, sealed: bool, start: int = 0,
                  max_records: int | None = None):
    """(records, valid_byte_length) of one segment, scanning from byte
    ``start`` (which must sit on a frame boundary — e.g. a prior scan's
    returned length).  A truncated/corrupt tail frame is tolerated (scan
    stops, its bytes excluded from valid_byte_length) only when ``sealed``
    is False.  ``max_records`` stops the scan cleanly after that many
    records, with the returned length on the frame boundary — a bounded
    follower poll resumes exactly there."""
    with open(path, "rb") as f:
        data = f.read()
    off, total = start, len(data)
    records: list[WalRecord] = []

    def torn(msg: str):
        if sealed:
            raise ValueError(f"corrupt sealed WAL segment {path}: {msg}")

    while off < total:
        if max_records is not None and len(records) >= max_records:
            break
        if off + _LEN.size > total:
            torn("truncated length prefix")
            break
        (hlen,) = _LEN.unpack_from(data, off)
        if off + _LEN.size + hlen > total:
            torn("truncated header")
            break
        try:
            header = json.loads(data[off + _LEN.size:off + _LEN.size + hlen])
            plen, rec = _decode_header(header)
        except (ValueError, KeyError):
            torn("unparseable header")
            break
        body_off = off + _LEN.size + hlen
        if body_off + plen > total:
            torn("truncated payload")
            break
        payload = data[body_off:body_off + plen]
        if zlib.crc32(payload) != header.get("crc"):
            torn("payload crc mismatch")
            break
        if rec.kind == KIND_BATCH:
            n, dim = int(header["n"]), int(header["dim"])
            rec.ops = np.frombuffer(payload, np.int8, n, 0).copy()
            rec.oids = np.frombuffer(payload, np.int32, n, n).copy()
            rec.xs = np.frombuffer(payload, np.float32, n * dim,
                                   n * 5).reshape(n, dim).copy()
        records.append(rec)
        off = body_off + plen
    return records, off


def _read_segment(path: str, *, sealed: bool) -> Iterator[WalRecord]:
    yield from _scan_segment(path, sealed=sealed)[0]


def _scan_dir(directory: str) -> list[str]:
    if not os.path.isdir(directory):
        return []
    return sorted(n for n in os.listdir(directory)
                  if n.startswith(_SEG_PREFIX) and n.endswith(_SEG_SUFFIX))


def iter_wal(directory: str, after_seq: int = -1) -> Iterator[WalRecord]:
    """Replay records with seq > ``after_seq`` in order, read-only.

    Safe to call while another process/handle appends: sealed segments are
    immutable and the active segment tolerates a torn tail."""
    names = _scan_dir(directory)
    sealed_names = set()
    mpath = os.path.join(directory, _MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            sealed_names = {s["name"] for s in json.load(f)["segments"]}
    for i, name in enumerate(names):
        sealed = name in sealed_names or i < len(names) - 1
        for rec in _read_segment(os.path.join(directory, name),
                                 sealed=sealed):
            if rec.seq > after_seq:
                yield rec


@dataclasses.dataclass
class WalCursor:
    """Resumable position of a WAL follower (stream/replica.py).

    ``segment``/``offset`` name the next unread byte; ``seq`` is the last
    record applied (records at or below it are skipped on overlap, so a
    cursor restored from a snapshot's ``wal_seq`` with segment/offset 0
    fast-forwards correctly).  The offset always lands on a frame
    boundary: a torn tail frame in the active segment leaves the cursor
    *before* it, and the next poll re-reads from there — once the leader's
    append completes, the same bytes parse and the record flows through.

    ``stalls`` counts consecutive polls that made no progress while
    unparseable bytes sat past the offset — the health signal that
    separates a benign mid-append torn tail (cleared by the very next
    completed append) from a truly corrupt segment (grows forever;
    ``tail_wal(max_stalls=N)`` turns it into a ``WalTailStall``).
    """
    seq: int = -1
    segment: int = 0
    offset: int = 0
    stalls: int = 0


def tail_wal(directory: str, cursor: WalCursor, *,
             max_records: int | None = None,
             max_stalls: int | None = None
             ) -> tuple[list[WalRecord], WalCursor]:
    """One follower poll: complete records past ``cursor``, plus the
    advanced cursor.  Safe to call while the leader appends — sealed
    segments are immutable, and the active segment's torn tail (a frame
    mid-append, or mid-shipment on a lagging mount) terminates the poll
    cleanly at the last complete frame.  Sealed segments wholly below the
    cursor's seq are skipped without reading their frames.

    ``max_records`` bounds how many records one poll scans (a far-behind
    follower drains its backlog across many bounded polls instead of
    stalling its serving thread for all of it); the cursor lands on the
    frame boundary after the last scanned record.  ``max_stalls`` raises
    ``WalTailStall`` once that many consecutive polls parked on the same
    offset with undecodable bytes beyond it — park-forever is the right
    behaviour for a leader mid-append, and the wrong one for a corrupt
    segment; the counter tells them apart."""
    names = _scan_dir(directory)
    cur = dataclasses.replace(cursor)
    out: list[WalRecord] = []
    sealed_meta: dict[str, dict] = {}
    mpath = os.path.join(directory, _MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            sealed_meta = {s["name"]: s for s in json.load(f)["segments"]}
    budget = max_records
    pending_bytes = 0
    for i, name in enumerate(names):
        idx = _segment_index(name)
        if idx < cur.segment:
            continue
        if budget is not None and budget <= 0:
            break
        path = os.path.join(directory, name)
        sealed = name in sealed_meta or i < len(names) - 1
        start = cur.offset if idx == cur.segment else 0
        entry = sealed_meta.get(name)
        if (sealed and start == 0 and entry is not None
                and entry.get("last_seq") is not None
                and entry["last_seq"] <= cur.seq):
            # snapshot fast-forward: this whole segment predates the cursor
            cur.segment, cur.offset = idx, os.path.getsize(path)
            continue
        records, end = _scan_segment(path, sealed=sealed, start=start,
                                     max_records=budget)
        if budget is not None:
            budget -= len(records)
        for rec in records:
            if rec.seq > cur.seq:
                out.append(rec)
                cur.seq = rec.seq
        cur.segment, cur.offset = idx, end
        if not sealed:
            # bytes past the parse point: a torn tail (benign, mid-append)
            # or corruption (permanent) — the stall counter decides which
            if budget is None or budget > 0:
                try:
                    pending_bytes = max(0, os.path.getsize(path) - end)
                except OSError:
                    pending_bytes = 0
            break   # the active segment is always the last one scanned
    progressed = (bool(out)
                  or (cur.segment, cur.offset) != (cursor.segment,
                                                   cursor.offset))
    if progressed or pending_bytes == 0:
        cur.stalls = 0
    else:
        cur.stalls = cursor.stalls + 1
        if max_stalls is not None and cur.stalls >= max_stalls:
            exc = WalTailStall(
                f"WAL tail parked at segment {cur.segment} offset "
                f"{cur.offset} for {cur.stalls} consecutive polls with "
                f"{pending_bytes} undecodable bytes beyond it — corrupt "
                f"segment in {directory!r}? (a leader mid-append clears "
                "in one append's time)")
            obs.record_fault("wal.tail_stall", exc, segment=cur.segment,
                             offset=cur.offset, stalls=cur.stalls)
            raise exc
    return out, cur


class WriteAheadLog:
    """Appender with segment rotation; one writer *process* per directory
    (appends are thread-safe within it).

    ``sync=True`` fsyncs the segment after every append (durability across
    power loss; cost measured in benchmarks/bench_stream.py).

    ``group_commit=True`` (with ``sync``) coalesces concurrent appends
    into one fsync: each appender writes + flushes its frame under the
    write lock, then joins a commit round — the first thread through
    becomes the *leader* and fsyncs once for every frame written so far;
    followers that arrive while the leader is in ``fsync`` find their
    frame already covered and return without touching the disk.  The
    durability contract is unchanged (an acknowledged append is on stable
    storage before ``append_*`` returns); only the fsync *count* drops —
    from one per append to one per concurrent burst, which closes most of
    the ~14x gap between ``sync`` and buffered appends under multi-writer
    load (the ``wal_group_fsync_*`` rows in benchmarks/bench_stream.py).
    Single-threaded callers see plain per-append fsync behaviour.

    ``fence`` (settable post-construction too — failover attaches it at
    promotion, stream/lease.py) is a zero-arg callable run under the
    append lock before every frame write; it raises ``FencedOut`` when
    this writer's lease/fencing token is stale.  A fenced append touches
    neither the log nor ``next_seq``, so a deposed leader can never
    acknowledge — or half-frame — a write the new leader won't have."""

    def __init__(self, directory: str, *, segment_max_records: int = 1024,
                 sync: bool = False, group_commit: bool = False,
                 fence=None):
        self.directory = directory
        self.segment_max_records = int(segment_max_records)
        self.sync = sync
        self.fence = fence
        self.group_commit = bool(group_commit)
        os.makedirs(directory, exist_ok=True)
        self._file = None
        # _lock serializes frame writes + bookkeeping; _commit_lock elects
        # the group-commit leader.  Lock order: _commit_lock -> _lock.
        self._lock = threading.Lock()
        self._commit_lock = threading.Lock()
        self._appended = 0      # frames written + flushed (all segments)
        self._synced = 0        # frames covered by an fsync
        self._recover()

    # -- recovery / bookkeeping ------------------------------------------
    def _recover(self) -> None:
        names = _scan_dir(self.directory)
        self.next_seq = 0
        self._active_records = 0
        self._sealed: list[dict] = []   # manifest entries, kept incrementally
        self._dir_dirty = True          # directory entry not yet fsync'd
        if names:
            self._active_index = _segment_index(names[-1])
            for i, name in enumerate(names):
                path = os.path.join(self.directory, name)
                sealed = i < len(names) - 1
                records, valid_len = _scan_segment(path, sealed=sealed)
                for rec in records:
                    self.next_seq = max(self.next_seq, rec.seq + 1)
                if sealed:
                    self._sealed.append(self._manifest_entry(name, records))
                else:
                    self._active_records = len(records)
                    if valid_len < os.path.getsize(path):
                        # torn tail from a crash mid-append: truncate it so
                        # post-recovery appends land after the last complete
                        # record instead of behind unreadable garbage (which
                        # replay would silently stop at)
                        with open(path, "r+b") as f:
                            f.truncate(valid_len)
        else:
            self._active_index = 0

    @staticmethod
    def _manifest_entry(name: str, records: list[WalRecord]) -> dict:
        return {"name": name,
                "first_seq": records[0].seq if records else None,
                "last_seq": records[-1].seq if records else None,
                "records": len(records)}

    def _active_path(self) -> str:
        return os.path.join(self.directory, _segment_name(self._active_index))

    def _ensure_open(self):
        if self._file is None:
            self._file = open(self._active_path(), "ab")
            self._dir_dirty = True
        return self._file

    def _write_manifest(self) -> None:
        doc = {"version": 1, "segments": self._sealed,
               "next_seq": self.next_seq}
        tmp = os.path.join(self.directory, f".tmp-{_MANIFEST}.{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
            f.write("\n")
            if self.sync:
                f.flush()
                os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.directory, _MANIFEST))
        if self.sync:
            from repro.dist.checkpoint import fsync_directory
            fsync_directory(self.directory)

    def _rotate_if_full(self) -> None:
        # caller holds self._lock
        if self._active_records < self.segment_max_records:
            return
        if self._file is not None:
            if self.sync:
                # seal-time fsync: under group commit a frame flushed after
                # the last leader's snapshot may not be covered yet, and
                # its writer's own commit round would find the segment
                # already closed — every sealed segment must be durable
                self._file.flush()
                os.fsync(self._file.fileno())
                self._synced = self._appended
            self._file.close()
            self._file = None
        name = _segment_name(self._active_index)
        records, _ = _scan_segment(os.path.join(self.directory, name),
                                   sealed=True)
        self._sealed.append(self._manifest_entry(name, records))
        self._active_index += 1
        self._active_records = 0
        self._write_manifest()

    # -- appends ----------------------------------------------------------
    def _append(self, rec: WalRecord) -> int:
        with self._lock:
            if self.fence is not None:
                try:
                    self.fence()        # FencedOut before any byte lands
                except FencedOut as exc:
                    # flight-recorder dump: a deposed leader just tried to
                    # write — the postmortem wants the ring *now*
                    obs.record_fault("wal.fenced_out", exc,
                                     next_seq=self.next_seq,
                                     directory=self.directory)
                    raise
            rec.seq = self.next_seq     # seq assignment must be atomic
            f = self._ensure_open()     # with the frame write
            buf = _encode(rec)
            f.write(buf)
            f.flush()
            self.next_seq = rec.seq + 1
            self._active_records += 1
            self._appended += 1
            my = self._appended
            if obs.enabled():
                obs.counter("wal.appends_total").inc()
                obs.counter("wal.bytes_total").inc(len(buf))
                obs.gauge("wal.next_seq").set(self.next_seq)
            if self.sync and not self.group_commit:
                os.fsync(f.fileno())
                if obs.enabled():
                    obs.counter("wal.fsyncs_total").inc()
                self._synced = my
                if self._dir_dirty:
                    # a freshly created segment file's *directory entry*
                    # must be durable too, or power loss drops the whole
                    # segment even though its records were fsync'd (same
                    # rule as the checkpoint commit, DESIGN.md §9)
                    from repro.dist.checkpoint import fsync_directory
                    fsync_directory(self.directory)
                    self._dir_dirty = False
        if self.sync and self.group_commit:
            self._group_fsync(my)
        with self._lock:
            self._rotate_if_full()
        return rec.seq

    def _group_fsync(self, my: int) -> None:
        """Join a commit round covering frame number ``my``: returns only
        once that frame is on stable storage, fsyncing at most once.

        The fsync itself runs under the write lock: a concurrent append's
        trailing ``_rotate_if_full`` (which takes only ``_lock``) may
        close the segment, and an fsync on the raw fd outside the lock
        races that close (EBADF — or worse, a silently recycled fd).
        Group commit's win is the fsync *count* (followers covered by the
        leader's round return without touching the disk), not overlapping
        the disk wait with writes, so serialising the fsync against
        appends only queues the burst the leader's round already covers."""
        with self._commit_lock:
            if self._synced >= my:
                return      # a prior leader's fsync already covered us
            with self._lock:
                f = self._file
                if f is None:
                    # the segment sealed since our write; the seal-time
                    # fsync in _rotate_if_full covered it
                    return
                f.flush()   # concurrent writers' buffered frames too
                snapshot = self._appended
                os.fsync(f.fileno())
                if obs.enabled():
                    obs.counter("wal.fsyncs_total").inc()
                    obs.counter("wal.group_commit_rounds_total").inc()
                if self._dir_dirty:
                    # cleared only after the fsync succeeded — a failed
                    # fsync must not drop the directory-entry guarantee
                    from repro.dist.checkpoint import fsync_directory
                    fsync_directory(self.directory)
                    self._dir_dirty = False
                # monotonic, and under _lock like every other _synced
                # write: a concurrent seal-time fsync may already have
                # advanced it past this round's snapshot
                self._synced = max(self._synced, snapshot)

    def append_batch(self, ops, xs, oids) -> int:
        """Frame one mutation batch; returns its sequence number."""
        return self._append(WalRecord(
            KIND_BATCH, self.next_seq, ops=np.asarray(ops, np.int8),
            oids=np.asarray(oids, np.int32), xs=np.asarray(xs, np.float32)))

    def append_rebalance(self, params: dict) -> int:
        """Frame a rebalance decision so tail replay re-executes it at the
        exact same point in the mutation order."""
        return self._append(WalRecord(KIND_REBALANCE, self.next_seq,
                                      params=params))

    def append_migration_plan(self, params: dict) -> int:
        """Frame a full migration schedule (seed + per-step donor/receiver/
        oid ranges) at the point in the mutation order where the planner
        fired; replay re-installs the identical plan."""
        return self._append(WalRecord(KIND_MIGRATION_PLAN, self.next_seq,
                                      params=params))

    def append_migration_step(self, params: dict) -> int:
        """Frame one executed migration step so replay re-runs the bounded
        move at the exact same interleaving point — including resuming a
        partially-executed plan after a crash."""
        return self._append(WalRecord(KIND_MIGRATION_STEP, self.next_seq,
                                      params=params))

    def replay(self, after_seq: int = -1) -> Iterator[WalRecord]:
        return iter_wal(self.directory, after_seq)

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
