"""Serving-grade write pipelines: WAL ▸ batcher ▸ epochs ▸ snapshots.

Two orchestrators over the stream primitives:

  * ``StreamingEngine``  — one SM-tree (the kNN-LM datastore case): every
    mutation batch is framed into the WAL *before* it is applied (write-
    ahead), applied through the conflict-free-cohort batcher, and the
    resulting immutable tree version is published as the next epoch for
    concurrent readers.
  * ``StreamingForest``  — a sharded SM-forest: rows are routed to their
    owner shard (round-robin hash for new ids, ownership map — maintained
    across rebalances — for deletes), applied shard-at-a-time through the
    same batcher, with background ``maintenance()`` firing the rebalancer
    when delete skew builds up.

Both support ``snapshot()`` (atomic checkpoint carrying the tree geometry
and the WAL high-water mark) and ``restore()`` = snapshot + WAL tail
replay.  Replay routes every record back through the identical code paths
— batch records through the batcher, rebalance records through
``rebalance_shards`` with the recorded seed — so the restored state is
**bitwise identical** to the straight-line run (tests/test_stream_e2e.py).
"""
from __future__ import annotations

import numpy as np

from repro.core import smtree
from repro.core.smtree import OP_DELETE, OP_INSERT, TreeArrays, empty_tree
from repro.stream.batcher import BatchResult, MutationBatcher
from repro.stream.epoch import EpochManager
from repro.stream.rebalance import (collect_stats, live_objects,
                                    needs_rebalance, rebalance_shards)
from repro.stream.wal import KIND_BATCH, WriteAheadLog

__all__ = ["StreamingEngine", "StreamingForest"]


def _mutation_log(xs, oids, op: int):
    xs = np.asarray(xs, np.float32)
    oids = np.asarray(oids, np.int32)
    return np.full(len(oids), op, np.int32), xs, oids


class StreamingEngine:
    """WAL-backed batched mutation pipeline over a single SM-tree."""

    def __init__(self, tree: TreeArrays, *, wal: WriteAheadLog | None = None,
                 ckpt=None, max_batch: int = 4096, donate: bool = False):
        # donation would consume the buffers published as the previous
        # epoch out from under pinned readers — see MutationBatcher
        self.batcher = MutationBatcher(tree, max_batch=max_batch,
                                       donate=donate)
        self.wal = wal
        self.ckpt = ckpt          # dist.checkpoint.CheckpointManager
        self.epochs = EpochManager(tree)
        self._step = 0

    @property
    def tree(self) -> TreeArrays:
        return self.batcher.tree

    # -- mutations ---------------------------------------------------------
    def apply(self, ops, xs, oids, *, log: bool = True) -> BatchResult:
        """Apply one mutation batch; frames it into the WAL first so an
        acknowledged batch is always replayable."""
        if log and self.wal is not None:
            self.wal.append_batch(np.asarray(ops, np.int8), xs, oids)
        res = self.batcher.apply(ops, xs, oids)
        self.epochs.publish(self.tree)
        return res

    def insert_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_INSERT)
        return self.apply(ops, xs, oids, **kw)

    def delete_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_DELETE)
        return self.apply(ops, xs, oids, **kw)

    # -- snapshots ---------------------------------------------------------
    def _extra(self) -> dict:
        t = self.tree
        return {"kind": "smtree", "capacity": t.capacity, "dim": t.dim,
                "metric": t.metric, "max_nodes": t.max_nodes,
                "min_fill": t.min_fill,
                "wal_seq": (self.wal.next_seq - 1 if self.wal is not None
                            else -1)}

    def snapshot(self, step: int | None = None) -> int:
        """Checkpoint the current tree + WAL high-water mark."""
        if self.ckpt is None:
            raise ValueError("no CheckpointManager configured")
        step = self._step if step is None else step
        self.ckpt.save(step, {"tree": self.tree}, extra=self._extra())
        self._step = step + 1
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, *, wal: WriteAheadLog | None = None,
                ckpt=None, **kw) -> "StreamingEngine":
        """Last snapshot + WAL tail replay (bitwise-deterministic)."""
        from repro.dist.checkpoint import read_manifest, restore_checkpoint
        manifest = read_manifest(ckpt_dir)
        extra = manifest["extra"]
        template = _tree_template(extra)
        state, _ = restore_checkpoint(ckpt_dir, {"tree": template},
                                      step=manifest["step"])
        eng = cls(state["tree"], wal=wal, ckpt=ckpt, **kw)
        eng._step = manifest["step"] + 1
        if wal is not None:
            for rec in wal.replay(after_seq=extra["wal_seq"]):
                if rec.kind == KIND_BATCH:
                    eng.apply(rec.ops.astype(np.int32), rec.xs, rec.oids,
                              log=False)
        return eng


def _tree_template(extra: dict, max_nodes: int | None = None) -> TreeArrays:
    t = empty_tree(dim=extra["dim"], capacity=extra["capacity"],
                   max_nodes=max_nodes or extra["max_nodes"],
                   metric=extra["metric"],
                   min_fill_frac=extra["min_fill"] / extra["capacity"])
    return t


class StreamingForest:
    """WAL-backed batched mutation pipeline over a sharded SM-forest.

    Host-centric control plane: shards are held as per-shard TreeArrays and
    mutated shard-at-a-time (the mesh-resident stacked form for shard_map
    serving is materialised on demand via ``stacked()`` /
    ``core.distributed.forest_apply_mutations``)."""

    def __init__(self, trees: list[TreeArrays], *,
                 wal: WriteAheadLog | None = None, ckpt=None,
                 max_batch: int = 4096, max_skew: float = 1.5,
                 min_objects: int = 64):
        self.batchers = [MutationBatcher(t, max_batch=max_batch)
                         for t in trees]
        self.wal = wal
        self.ckpt = ckpt
        self.max_skew = max_skew
        self.min_objects = min_objects
        self.epochs = EpochManager(tuple(self.trees))
        self.owner: dict[int, int] = {}
        self._step = 0
        self.n_rebalances = 0
        self._rebuild_ownership()

    @property
    def trees(self) -> list[TreeArrays]:
        return [b.tree for b in self.batchers]

    @property
    def n_shards(self) -> int:
        return len(self.batchers)

    @property
    def n_objects(self) -> int:
        return sum(t.n_objects for t in self.trees)

    def _rebuild_ownership(self) -> None:
        self.owner = {}
        for s, t in enumerate(self.trees):
            _, oids = live_objects(t)
            for o in oids:
                self.owner[int(o)] = s

    # -- routing -----------------------------------------------------------
    def route(self, ops, oids) -> np.ndarray:
        """Owner shard per row.  Deletes follow the ownership map (objects
        migrate under rebalancing); new inserts hash round-robin
        (oid mod S, matching ``build_forest``'s initial partition).  The
        map is scanned in log order so same-batch insert→delete pairs
        route consistently."""
        S = self.n_shards
        pending = dict(self.owner)
        out = np.empty(len(oids), np.int32)
        for i, (op, oid) in enumerate(zip(ops, oids)):
            o = int(oid)
            s = pending.get(o, o % S)
            out[i] = s
            if op == OP_INSERT:
                pending[o] = s
            elif op == OP_DELETE:
                pending.pop(o, None)
        return out

    # -- mutations ---------------------------------------------------------
    def apply(self, ops, xs, oids, *, log: bool = True) -> BatchResult:
        ops = np.asarray(ops, np.int32)
        xs = np.asarray(xs, np.float32)
        oids = np.asarray(oids, np.int32)
        if log and self.wal is not None:
            self.wal.append_batch(ops.astype(np.int8), xs, oids)
        owner = self.route(ops, oids)
        statuses = np.zeros(len(ops), np.int32)
        n_fast = n_esc = n_coh = 0
        for s in range(self.n_shards):
            rows = np.nonzero(owner == s)[0]
            if not len(rows):
                continue
            r = self.batchers[s].apply(ops[rows], xs[rows], oids[rows])
            statuses[rows] = r.statuses
            n_fast += r.n_fast
            n_esc += r.n_escalated
            n_coh += r.n_cohorts
        applied = statuses == smtree.ST_APPLIED
        for i in np.nonzero(applied)[0]:
            if ops[i] == OP_INSERT:
                self.owner[int(oids[i])] = int(owner[i])
            else:
                self.owner.pop(int(oids[i]), None)
        self.epochs.publish(tuple(self.trees))
        return BatchResult(statuses, n_fast, n_esc, n_coh)

    def insert_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_INSERT)
        return self.apply(ops, xs, oids, **kw)

    def delete_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_DELETE)
        return self.apply(ops, xs, oids, **kw)

    # -- queries (host-side scatter-gather; mesh serving uses forest_knn) --
    def knn(self, queries, *, k: int = 8, max_frontier: int = 64):
        """Global kNN over the current epoch's shards: per-shard cohort
        descent + host top-k merge.  Returns (dists [b, k], ids [b, k])."""
        _, trees = self.epochs.current()
        ds, ids = [], []
        for t in trees:
            res = smtree.knn(t, queries, k=k, max_frontier=max_frontier)
            ds.append(np.asarray(res.dists))
            ids.append(np.asarray(res.ids))
        d = np.concatenate(ds, axis=1)
        i = np.concatenate(ids, axis=1)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        return np.take_along_axis(d, order, 1), np.take_along_axis(i, order, 1)

    # -- maintenance -------------------------------------------------------
    def maintenance(self, *, log: bool = True) -> bool:
        """Detect skew and rebalance; returns True when a rebuild fired."""
        stats = collect_stats(self.trees)
        if not needs_rebalance(stats, max_skew=self.max_skew,
                               min_objects=self.min_objects):
            return False
        seed = (self.wal.next_seq if self.wal is not None
                else self.n_rebalances)
        self._run_rebalance(int(seed), log=log)
        return True

    def _run_rebalance(self, seed: int, *, log: bool) -> None:
        if log and self.wal is not None:
            self.wal.append_rebalance({"seed": seed})
        trees, moved, _ = rebalance_shards(self.trees, seed=seed)
        for b, t in zip(self.batchers, trees):
            b.tree = t
        self.n_rebalances += 1
        self._rebuild_ownership()
        self.epochs.publish(tuple(self.trees))

    # -- snapshots ---------------------------------------------------------
    def stacked(self) -> TreeArrays:
        from repro.core.distributed import stack_trees
        return stack_trees(self.trees)

    def _extra(self) -> dict:
        proto = self.trees[0]
        return {"kind": "smforest", "n_shards": self.n_shards,
                "capacity": proto.capacity, "dim": proto.dim,
                "metric": proto.metric, "min_fill": proto.min_fill,
                "shard_max_nodes": [t.max_nodes for t in self.trees],
                "n_rebalances": self.n_rebalances,
                "wal_seq": (self.wal.next_seq - 1 if self.wal is not None
                            else -1)}

    def snapshot(self, step: int | None = None) -> int:
        if self.ckpt is None:
            raise ValueError("no CheckpointManager configured")
        step = self._step if step is None else step
        self.ckpt.save(step, {"forest": self.stacked()},
                       extra=self._extra())
        self._step = step + 1
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, *, wal: WriteAheadLog | None = None,
                ckpt=None, **kw) -> "StreamingForest":
        """Last snapshot + WAL tail replay (bitwise-deterministic: batch
        records re-run the batcher, rebalance records re-run the rebuild
        with the recorded seed)."""
        from repro.core.distributed import stack_trees, unstack_forest
        from repro.dist.checkpoint import read_manifest, restore_checkpoint
        manifest = read_manifest(ckpt_dir)
        extra = manifest["extra"]
        shard_nodes = extra["shard_max_nodes"]
        template = stack_trees([_tree_template(extra, max_nodes=m)
                                for m in shard_nodes])
        state, _ = restore_checkpoint(ckpt_dir, {"forest": template},
                                      step=manifest["step"])
        trees = unstack_forest(state["forest"], max_nodes=shard_nodes)
        forest = cls(trees, wal=wal, ckpt=ckpt, **kw)
        forest._step = manifest["step"] + 1
        forest.n_rebalances = extra.get("n_rebalances", 0)
        if wal is not None:
            for rec in wal.replay(after_seq=extra["wal_seq"]):
                if rec.kind == KIND_BATCH:
                    forest.apply(rec.ops.astype(np.int32), rec.xs, rec.oids,
                                 log=False)
                else:
                    forest._run_rebalance(int(rec.params["seed"]), log=False)
        return forest
