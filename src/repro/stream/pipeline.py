"""Serving-grade write pipelines: WAL ▸ batcher ▸ epochs ▸ snapshots.

Two orchestrators over the stream primitives:

  * ``StreamingEngine``  — one SM-tree (the kNN-LM datastore case): every
    mutation batch is framed into the WAL *before* it is applied (write-
    ahead), applied through the conflict-free-cohort batcher, and the
    resulting immutable tree version is published as the next epoch for
    concurrent readers.
  * ``StreamingForest``  — a sharded SM-forest: rows are routed to their
    owner shard (round-robin hash for new ids, ownership map — maintained
    across rebalances — for deletes), applied shard-at-a-time through the
    same batcher, with background ``maintenance()`` firing the rebalancer
    when delete skew builds up.

The forest's ``maintenance()`` runs in one of two rebalance modes:
``stop_world`` (the original one-shot ``rebalance_shards`` rebuild, kept
as the baseline and the replay path for old WALs) and ``incremental``
(a deterministic ``MigrationPlan`` executed one bounded step per call —
each step a delete-on-donor / insert-on-receiver cohort behind one epoch
publish, DESIGN.md §16).

Both support ``snapshot()`` (atomic checkpoint carrying the tree geometry
and the WAL high-water mark) and ``restore()`` = snapshot + WAL tail
replay.  Replay routes every record back through the identical code paths
— batch records through the batcher, control records (rebalance /
migration plan / migration step) through ``apply_control`` — so the
restored state is **bitwise identical** to the straight-line run, even
after a crash between migration steps (tests/test_stream_e2e.py,
tests/test_migration.py).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import obs
from repro.core import smtree
from repro.core.smtree import OP_DELETE, OP_INSERT, TreeArrays, empty_tree
from repro.stream.batcher import (BatchResult, MutationBatcher, check_oids,
                                  cut_cohorts, escalate_rows, pad_to_bucket)
from repro.stream.epoch import EpochManager
from repro.stream.rebalance import (MigrationPlan, collect_stats,
                                    live_objects, needs_rebalance,
                                    plan_migration, rebalance_shards)
from repro.stream.wal import (KIND_BATCH, KIND_MIGRATION_PLAN,
                              KIND_MIGRATION_STEP, KIND_REBALANCE,
                              WriteAheadLog)

__all__ = ["StreamingEngine", "StreamingForest"]


def _mutation_log(xs, oids, op: int):
    xs = np.asarray(xs, np.float32)
    oids = np.asarray(oids, np.int32)
    return np.full(len(oids), op, np.int32), xs, oids


def _pad_cohort(ops, xs, oids, owner, max_batch: int):
    """Pad a cohort slice to its power-of-two bucket with NOP rows (oid -1,
    owner 0 — inert on every shard) so the collective jit cache stays one
    entry per bucket size, exactly like the batcher's host path."""
    n = len(ops)
    bucket = pad_to_bucket(n, max_batch)
    if bucket == n:
        return ops, xs, oids, owner
    pad = bucket - n
    return (np.concatenate([ops, np.full(pad, smtree.OP_NOP, np.int32)]),
            np.concatenate([xs, np.zeros((pad, xs.shape[1]), np.float32)]),
            np.concatenate([oids, np.full(pad, -1, np.int32)]),
            np.concatenate([owner, np.zeros(pad, np.int32)]))


class StreamingEngine:
    """WAL-backed batched mutation pipeline over a single SM-tree.

    ``headroom_frac`` arms ahead-of-time free-ring growth: after each
    batch — an epoch-publish point, never mid-pass — the node table is
    doubled (``smtree.grow_tree``) whenever the free ring sits below
    ``max(MAX_HEIGHT + 1, headroom_frac * max_nodes)``, so ring
    exhaustion (the one split-path host escalation left) stops being a
    mid-batch event.  Growth is deterministic in the mutation sequence,
    which the WAL replay contract requires.  ``None`` disables it (the
    PR-4 behaviour: exhaustion escalates so the host can ``_grow``)."""

    def __init__(self, tree: TreeArrays, *, wal: WriteAheadLog | None = None,
                 ckpt=None, max_batch: int = 4096, donate: bool = False,
                 device_splits: bool = True, device_merges: bool = True,
                 headroom_frac: float | None = 1 / 16):
        # donation would consume the buffers published as the previous
        # epoch out from under pinned readers — see MutationBatcher
        self.batcher = MutationBatcher(tree, max_batch=max_batch,
                                       donate=donate,
                                       device_splits=device_splits,
                                       device_merges=device_merges)
        self.wal = wal
        self.ckpt = ckpt          # dist.checkpoint.CheckpointManager
        self.headroom_frac = headroom_frac
        self.n_grows = 0
        self.epochs = EpochManager(tree)
        self._step = 0

    @property
    def tree(self) -> TreeArrays:
        return self.batcher.tree

    # -- mutations ---------------------------------------------------------
    def apply(self, ops, xs, oids, *, log: bool = True) -> BatchResult:
        """Apply one mutation batch; frames it into the WAL first so an
        acknowledged batch is always replayable.  Negative oids are rejected
        here — before the WAL append — so a bad batch can neither collide
        with the batcher's pad sentinel nor poison replay."""
        check_oids(oids)
        if log and self.wal is not None:
            with obs.span("mutation.wal_append", n=len(ops)):
                self.wal.append_batch(np.asarray(ops, np.int8), xs, oids)
        with obs.span("mutation.apply", n=len(ops)):
            res = self.batcher.apply(ops, xs, oids)
        if (self.headroom_frac is not None
                and smtree.needs_headroom(self.tree,
                                          frac=self.headroom_frac)):
            self.batcher.tree = smtree.grow_tree(self.tree)
            self.n_grows += 1
            obs.record_event("stream.tree_grow", n_grows=self.n_grows)
        with obs.span("mutation.publish"):
            self.epochs.publish(self.tree)
        if obs.enabled():
            obs.counter("stream.batches_total").inc()
            obs.counter("stream.rows_total").inc(len(ops))
            obs.counter("stream.escalated_rows_total").inc(res.n_escalated)
            obs.counter("stream.device_splits_total").inc(res.n_split)
            obs.counter("stream.device_merges_total").inc(res.n_merge)
        return res

    def insert_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_INSERT)
        return self.apply(ops, xs, oids, **kw)

    def delete_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_DELETE)
        return self.apply(ops, xs, oids, **kw)

    # -- snapshots ---------------------------------------------------------
    def _extra(self) -> dict:
        t = self.tree
        return {"kind": "smtree", "capacity": t.capacity, "dim": t.dim,
                "metric": t.metric, "max_nodes": t.max_nodes,
                "min_fill": t.min_fill,
                "wal_seq": (self.wal.next_seq - 1 if self.wal is not None
                            else -1)}

    def snapshot(self, step: int | None = None) -> int:
        """Checkpoint the current tree + WAL high-water mark."""
        if self.ckpt is None:
            raise ValueError("no CheckpointManager configured")
        step = self._step if step is None else step
        self.ckpt.save(step, {"tree": self.tree}, extra=self._extra())
        self._step = step + 1
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, *, wal: WriteAheadLog | None = None,
                ckpt=None, **kw) -> "StreamingEngine":
        """Last snapshot + WAL tail replay (bitwise-deterministic)."""
        from repro.dist.checkpoint import read_manifest, restore_checkpoint
        manifest = read_manifest(ckpt_dir)
        extra = manifest["extra"]
        template = _tree_template(extra)
        state, _ = restore_checkpoint(ckpt_dir, {"tree": template},
                                      step=manifest["step"])
        eng = cls(state["tree"], wal=wal, ckpt=ckpt, **kw)
        eng._step = manifest["step"] + 1
        if wal is not None:
            for rec in wal.replay(after_seq=extra["wal_seq"]):
                if rec.kind == KIND_BATCH:
                    eng.apply(rec.ops.astype(np.int32), rec.xs, rec.oids,
                              log=False)
        return eng


def _tree_template(extra: dict, max_nodes: int | None = None) -> TreeArrays:
    t = empty_tree(dim=extra["dim"], capacity=extra["capacity"],
                   max_nodes=max_nodes or extra["max_nodes"],
                   metric=extra["metric"],
                   min_fill_frac=extra["min_fill"] / extra["capacity"])
    return t


class StreamingForest:
    """WAL-backed batched mutation pipeline over a sharded SM-forest.

    Two control-plane modes:

      * host-centric (``mesh=None``): shards are held as per-shard
        TreeArrays and mutated shard-at-a-time through per-shard batchers —
        each shard's cohorts still run the fused device scan + split pass.
      * mesh-resident (``mesh=`` a Mesh whose ``axis`` has one device per
        shard): the stacked forest lives on the mesh and every WAL batch is
        applied as cut-cohorts → one ``forest_apply_mutations`` collective →
        one ``forest_apply_splits`` collective over the compacted overflow
        rows → psum'd statuses.  Tree pages never leave HBM; the host sees
        only the per-row status vectors.  Residual escalations (multi-level
        or root splits, merges) unstack the affected shards to the host
        control plane — the rare path.

    Both modes produce bitwise-identical shards for conflict-free batches
    (tests/test_device_split.py): the collective is the same masked scan +
    split pass the batcher runs, and host escalation uses the same code in
    the same (overflow-first) order."""

    def __init__(self, trees: list[TreeArrays], *,
                 wal: WriteAheadLog | None = None, ckpt=None,
                 max_batch: int = 4096, max_skew: float = 1.5,
                 min_objects: int = 64, mesh=None, axis: str = "model",
                 device_splits: bool = True, device_merges: bool = True,
                 headroom_frac: float | None = 1 / 16,
                 rebalance_mode: str = "stop_world",
                 migration_step_objects: int = 64,
                 free_floor: float | None = None):
        if rebalance_mode not in ("stop_world", "incremental"):
            raise ValueError(f"unknown rebalance_mode {rebalance_mode!r} "
                             f"(expected 'stop_world' or 'incremental')")
        self.rebalance_mode = rebalance_mode
        self.migration_step_objects = int(migration_step_objects)
        self.free_floor = free_floor
        self._migration: dict | None = None   # {"plan": MigrationPlan,
        #                                        "next": step index}
        self.n_migration_steps = 0
        self.objects_migrated = 0
        self.device_splits = device_splits
        self.device_merges = device_merges
        self.headroom_frac = headroom_frac
        self.n_grows = 0
        self.batchers = [MutationBatcher(t, max_batch=max_batch,
                                         device_splits=device_splits,
                                         device_merges=device_merges)
                         for t in trees]
        self.wal = wal
        self.ckpt = ckpt
        self.max_batch = int(max_batch)
        self.max_skew = max_skew
        self.min_objects = min_objects
        self.mesh = mesh
        self.axis = axis
        if mesh is not None and mesh.shape[axis] != len(trees):
            raise ValueError(
                f"mesh axis {axis!r} has {mesh.shape[axis]} devices for "
                f"{len(trees)} shards (need exactly one per shard)")
        # mesh mode: the stacked forest is the source of truth between
        # rebalances; None = truth lives in the per-shard batchers
        self._stacked: TreeArrays | None = None
        self._unstack_cache: tuple | None = None   # (stacked, shard views)
        self._shard_nodes = [t.max_nodes for t in trees]
        self.epochs = EpochManager(tuple(self.trees))
        self.owner: dict[int, int] = {}
        self._step = 0
        self.n_rebalances = 0
        self._rebuild_ownership()

    @property
    def trees(self) -> list[TreeArrays]:
        if self._stacked is not None:
            # cache the unstacked view per stacked-forest identity: slicing
            # materialises per-shard copies on CPU, and epoch publication +
            # stats read this after every batch
            if (self._unstack_cache is None
                    or self._unstack_cache[0] is not self._stacked):
                from repro.core.distributed import unstack_forest
                self._unstack_cache = (self._stacked, unstack_forest(
                    self._stacked, max_nodes=self._shard_nodes))
            return self._unstack_cache[1]
        return [b.tree for b in self.batchers]

    @property
    def n_shards(self) -> int:
        return len(self.batchers)

    @property
    def n_objects(self) -> int:
        return sum(t.n_objects for t in self.trees)

    def _rebuild_ownership(self) -> None:
        self.owner = {}
        for s, t in enumerate(self.trees):
            _, oids = live_objects(t)
            for o in oids:
                self.owner[int(o)] = s

    # -- routing -----------------------------------------------------------
    def route(self, ops, oids) -> np.ndarray:
        """Owner shard per row.  Deletes follow the ownership map (objects
        migrate under rebalancing); new inserts hash round-robin
        (oid mod S, matching ``build_forest``'s initial partition).  The
        map is scanned in log order so same-batch insert→delete pairs
        route consistently."""
        S = self.n_shards
        pending = dict(self.owner)
        out = np.empty(len(oids), np.int32)
        for i, (op, oid) in enumerate(zip(ops, oids)):
            o = int(oid)
            s = pending.get(o, o % S)
            out[i] = s
            if op == OP_INSERT:
                pending[o] = s
            elif op == OP_DELETE:
                pending.pop(o, None)
        return out

    # -- mutations ---------------------------------------------------------
    def apply(self, ops, xs, oids, *, log: bool = True) -> BatchResult:
        ops = np.asarray(ops, np.int32)
        xs = np.asarray(xs, np.float32)
        oids = np.asarray(oids, np.int32)
        check_oids(oids)
        if log and self.wal is not None:
            with obs.span("mutation.wal_append", n=len(ops)):
                self.wal.append_batch(ops.astype(np.int8), xs, oids)
        owner = self.route(ops, oids)
        with obs.span("mutation.apply", n=len(ops),
                      plane="mesh" if self.mesh is not None else "host"):
            if self.mesh is not None:
                res = self._apply_mesh(ops, xs, oids, owner)
            else:
                res = self._apply_host(ops, xs, oids, owner)
        applied = res.statuses == smtree.ST_APPLIED
        for i in np.nonzero(applied)[0]:
            if ops[i] == OP_INSERT:
                self.owner[int(oids[i])] = int(owner[i])
            else:
                self.owner.pop(int(oids[i]), None)
        self._ensure_headroom()
        with obs.span("mutation.publish"):
            self.epochs.publish(tuple(self.trees))
        if obs.enabled():
            obs.counter("stream.batches_total").inc()
            obs.counter("stream.rows_total").inc(len(ops))
            obs.counter("stream.escalated_rows_total").inc(res.n_escalated)
            obs.counter("stream.device_splits_total").inc(res.n_split)
            obs.counter("stream.device_merges_total").inc(res.n_merge)
        return res

    def _ensure_headroom(self) -> None:
        """Ahead-of-time free-ring growth (epoch-publish point): double any
        shard whose ring fell below the watermark, so the next batch's
        split pass cannot exhaust it mid-collective.  Both control-plane
        modes read the same per-shard scalars and grow at the same points,
        which keeps mesh ≡ host bitwise (and WAL replay deterministic)."""
        if self.headroom_frac is None:
            return
        needy = [s for s, t in enumerate(self.trees)
                 if smtree.needs_headroom(t, frac=self.headroom_frac)]
        if not needy:
            return
        trees = list(self.trees)
        for s in needy:
            trees[s] = smtree.grow_tree(trees[s])
        for b, t in zip(self.batchers, trees):
            b.tree = t
        # growth is host-side: drop the mesh-resident stacked form, the
        # next collective apply restacks from the fresh shards
        self._stacked = None
        self._shard_nodes = [t.max_nodes for t in trees]
        self.n_grows += len(needy)

    def _apply_host(self, ops, xs, oids, owner) -> BatchResult:
        """Host-centric path: route rows to their shard's batcher.

        Cohorts are cut on the *global* log — the same boundaries the mesh
        path's collectives use — so escalation interleaves with the scans
        at identical points in every shard's op sequence and the two modes
        stay bitwise-interchangeable (a shard-local cut would let one
        shard's scan run ahead of another shard's repeat-induced
        boundary)."""
        statuses = np.zeros(len(ops), np.int32)
        n_fast = n_esc = n_split = n_merge = 0
        cohorts = cut_cohorts(oids)
        for start, end in cohorts:
            for cs in range(start, end, self.max_batch):
                ce = min(cs + self.max_batch, end)
                for s in range(self.n_shards):
                    rows = cs + np.nonzero(owner[cs:ce] == s)[0]
                    if not len(rows):
                        continue
                    r = self.batchers[s].apply(ops[rows], xs[rows],
                                               oids[rows])
                    statuses[rows] = r.statuses
                    n_fast += r.n_fast
                    n_esc += r.n_escalated
                    n_split += r.n_split
                    n_merge += r.n_merge
        return BatchResult(statuses, n_fast, n_esc, len(cohorts), n_split,
                           n_merge)

    def _apply_mesh(self, ops, xs, oids, owner) -> BatchResult:
        """Mesh-resident path: cut-cohorts → one collective apply + one
        collective split pass + one collective merge pass per cohort →
        psum'd statuses; host escalation only for the residual rows (a
        blocked split chain — ring exhaustion — which ahead-of-time
        headroom growth makes a cold assert-path)."""
        from repro.core import distributed as dist
        if self._stacked is None:
            self._stacked = dist.stack_trees([b.tree for b in self.batchers])
        forest = self._stacked
        statuses = np.zeros(len(ops), np.int32)
        n_fast = n_esc = n_split = n_merge = 0
        cohorts = cut_cohorts(oids)
        for start, end in cohorts:
            for cs in range(start, end, self.max_batch):
                ce = min(cs + self.max_batch, end)
                c_ops, c_xs, c_oids, c_owner = _pad_cohort(
                    ops[cs:ce], xs[cs:ce], oids[cs:ce], owner[cs:ce],
                    self.max_batch)
                forest, st = dist.forest_apply_mutations(
                    forest, self.mesh, c_ops, c_xs, c_oids, c_owner,
                    axis=self.axis)
                st = np.array(jax.device_get(st))[:ce - cs]
                ovf = (np.nonzero((st == smtree.ST_OVERFLOW)
                                  & (c_ops[:ce - cs] == OP_INSERT))[0]
                       if self.device_splits else np.array([], np.int64))
                # power-of-two-ladder split collectives (bounded jit cache
                # per forest geometry, no padded NOP steps — a pad costs
                # as much as a real split); stopping at the first
                # still-blocked chunk is conservative but bitwise-safe —
                # the host control plane produces the identical split for
                # any row the device would have absorbed
                c0 = 0
                for w in smtree.split_chunks(len(ovf)):
                    chunk = ovf[c0:c0 + w]
                    c0 += w
                    k = len(chunk)
                    k_ops = np.full(w, smtree.OP_NOP, np.int32)
                    k_ops[:k] = OP_INSERT
                    k_xs = np.zeros((w, xs.shape[1]), np.float32)
                    k_xs[:k] = c_xs[chunk]
                    k_oids = np.full(w, -1, np.int32)
                    k_oids[:k] = c_oids[chunk]
                    k_owner = np.zeros(w, np.int32)
                    k_owner[:k] = c_owner[chunk]
                    forest, k_st = dist.forest_apply_splits(
                        forest, self.mesh, k_ops, k_xs, k_oids, k_owner,
                        axis=self.axis)
                    k_st = np.asarray(jax.device_get(k_st))[:k]
                    st[chunk[k_st == smtree.ST_SPLIT]] = smtree.ST_SPLIT
                    if (k_st == smtree.ST_OVERFLOW).any():
                        break
                # merge collectives: underflow rows resolve on device only
                # once every overflow row has (the host reference resolves
                # all overflows before any underflow; a residual blocked
                # split must reach the host first to keep the structure-
                # edit order — and the bitwise tree — identical)
                unf = (np.nonzero((st == smtree.ST_UNDERFLOW)
                                  & (c_ops[:ce - cs] == OP_DELETE))[0]
                       if (self.device_merges
                           and not (st == smtree.ST_OVERFLOW).any())
                       else np.array([], np.int64))
                # unlike the split ladder there is no blocked-chunk
                # decision between merge dispatches (merges never
                # allocate), so every chunk is dispatched back-to-back
                # and the statuses sync once — one host round-trip per
                # cohort instead of one per chunk
                c0 = 0
                pending = []
                for w in smtree.merge_chunks(len(unf)):
                    chunk = unf[c0:c0 + w]
                    c0 += w
                    k = len(chunk)
                    k_ops = np.full(w, smtree.OP_NOP, np.int32)
                    k_ops[:k] = OP_DELETE
                    k_oids = np.full(w, -1, np.int32)
                    k_oids[:k] = c_oids[chunk]
                    k_owner = np.zeros(w, np.int32)
                    k_owner[:k] = c_owner[chunk]
                    forest, k_st = dist.forest_apply_merges(
                        forest, self.mesh, k_ops, k_oids, k_owner,
                        axis=self.axis)
                    pending.append((chunk, k, k_st))
                for chunk, k, k_st in pending:
                    st[chunk] = np.asarray(jax.device_get(k_st))[:k]
                esc = np.isin(st, (smtree.ST_OVERFLOW, smtree.ST_UNDERFLOW))
                n_esc += int(esc.sum())
                n_split += int((st == smtree.ST_SPLIT).sum())
                n_merge += int((st == smtree.ST_MERGE).sum())
                n_fast += int((st == smtree.ST_APPLIED).sum())
                st[np.isin(st, (smtree.ST_SPLIT, smtree.ST_MERGE))] = \
                    smtree.ST_APPLIED
                if esc.any():
                    forest = self._escalate_mesh(
                        forest, st, ops[cs:ce], xs[cs:ce], oids[cs:ce],
                        owner[cs:ce])
                statuses[cs:ce] = st
        self._stacked = forest
        return BatchResult(statuses, n_fast, n_esc, len(cohorts), n_split,
                           n_merge)

    def _escalate_mesh(self, forest, st, ops, xs, oids, owner):
        """Unstack only to run the host control plane on the shards that
        still hold unresolved rows, then restack (the rare path)."""
        from repro.core import distributed as dist
        trees = dist.unstack_forest(forest, max_nodes=self._shard_nodes)
        esc = np.nonzero(np.isin(st, (smtree.ST_OVERFLOW,
                                      smtree.ST_UNDERFLOW)))[0]
        obs.record_event("stream.host_escalation", n_rows=int(len(esc)))
        for s in sorted(set(int(owner[i]) for i in esc)):
            rows = np.array([i for i in esc if owner[i] == s])
            sub = st[rows].copy()
            trees[s] = escalate_rows(trees[s], sub, ops[rows], xs[rows],
                                     oids[rows])
            st[rows] = sub
        self._shard_nodes = [t.max_nodes for t in trees]
        return dist.stack_trees(trees)

    def insert_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_INSERT)
        return self.apply(ops, xs, oids, **kw)

    def delete_batch(self, xs, oids, **kw) -> BatchResult:
        ops, xs, oids = _mutation_log(xs, oids, OP_DELETE)
        return self.apply(ops, xs, oids, **kw)

    # -- queries (host-side scatter-gather; mesh serving uses forest_knn) --
    def knn(self, queries, *, k: int = 8, max_frontier: int = 64):
        """Global kNN over a *pinned* epoch's shards: per-shard cohort
        descent + host top-k merge.  Returns (dists [b, k], ids [b, k]).
        The pin (``EpochManager.reading``) keeps the version resident for
        the whole descent even if a concurrent writer publishes and retires
        epochs mid-query."""
        with self.epochs.reading() as trees:
            ds, ids = [], []
            on = obs.enabled()
            for t in trees:
                if on and obs.want_level_stats():
                    res, pruned = smtree.knn(t, queries, k=k,
                                             max_frontier=max_frontier,
                                             level_stats=True)
                    obs.observe_query_result(res, pruned)
                else:
                    res = smtree.knn(t, queries, k=k,
                                     max_frontier=max_frontier)
                ds.append(np.asarray(res.dists))
                ids.append(np.asarray(res.ids))
        d = np.concatenate(ds, axis=1)
        i = np.concatenate(ids, axis=1)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        return np.take_along_axis(d, order, 1), np.take_along_axis(i, order, 1)

    # -- maintenance -------------------------------------------------------
    def maintenance(self, *, log: bool = True) -> bool:
        """Bounded background repair; returns True when repair work ran.

        ``stop_world`` mode: detect skew and rebuild the touched shards in
        one pass (the original behaviour).  ``incremental`` mode: when a
        migration plan is active, execute exactly one bounded step;
        otherwise consult the trigger and, when it fires, record the full
        deterministic plan in the WAL and execute its first step.  At most
        one step per call keeps the publish-time pause bounded regardless
        of how deep the skew is — callers (the front-end mutation daemon,
        the drill loops) invoke this once per mutation batch."""
        if self._migration is not None:
            self._migration_step(log=log)
            return True
        stats = collect_stats(self.trees)
        if obs.enabled():
            obs.gauge("rebalance.skew").set(stats.skew)
        if not needs_rebalance(stats, max_skew=self.max_skew,
                               min_objects=self.min_objects,
                               free_floor=self.free_floor):
            return False
        seed = (self.wal.next_seq if self.wal is not None
                else self.n_rebalances)
        if self.rebalance_mode == "stop_world":
            self._run_rebalance(int(seed), log=log)
            return True
        plan = plan_migration(self.trees, seed=int(seed),
                              step_objects=self.migration_step_objects)
        if not plan.steps:
            return False
        if log and self.wal is not None:
            self.wal.append_migration_plan(plan.to_params())
        self._install_migration(plan)
        self._migration_step(log=log)
        return True

    def _install_migration(self, plan: MigrationPlan, *,
                           next_step: int = 0) -> None:
        if self._migration is not None:
            raise ValueError("migration plan installed while another is "
                             "still active (corrupt WAL or snapshot?)")
        self._migration = {"plan": plan, "next": int(next_step)}
        obs.record_event("stream.migration_plan", seed=plan.seed,
                         steps=len(plan.steps), objects=plan.total)

    @property
    def migration_active(self) -> bool:
        return self._migration is not None

    def _extract(self, donor: int, oids: np.ndarray):
        """(vecs, found) for ids on the donor shard.  Mesh mode gathers
        through the owner-routed collective — tree pages stay device-
        resident, only the [m, dim] vectors come back — padded to the
        plan's step width so the jit cache holds one entry per forest
        geometry."""
        if self.mesh is not None:
            from repro.core import distributed as dist
            if self._stacked is None:
                self._stacked = dist.stack_trees(
                    [b.tree for b in self.batchers])
            w = max(self.migration_step_objects, len(oids))
            p_oids = np.full(w, -1, np.int32)
            p_oids[:len(oids)] = oids
            p_owner = np.full(w, -1, np.int32)
            p_owner[:len(oids)] = donor
            vecs, found = dist.forest_extract_objects(
                self._stacked, self.mesh, p_oids, p_owner, axis=self.axis)
            return (np.asarray(jax.device_get(vecs))[:len(oids)],
                    np.asarray(jax.device_get(found))[:len(oids)])
        vecs, found = smtree.extract_objects(self.batchers[donor].tree, oids)
        return np.asarray(vecs), np.asarray(found)

    def _migration_step(self, *, log: bool, expect: int | None = None) -> int:
        """Execute one bounded move from the active plan: extract the
        step's still-donor-owned objects and re-apply them as a normal
        delete-on-donor / insert-on-receiver conflict-free cohort pair
        through the standard apply path, then publish exactly one epoch.
        Readers pinned to the previous epoch see each object on the donor;
        the new epoch shows it on the receiver — never twice, never zero
        times.  Returns the number of objects re-homed."""
        mig = self._migration
        if mig is None:
            raise ValueError("no active migration plan")
        idx = mig["next"]
        if expect is not None and expect != idx:
            raise ValueError(
                f"WAL migration step {expect} does not match resume "
                f"position {idx} (truncated or reordered log)")
        plan: MigrationPlan = mig["plan"]
        step = plan.steps[idx]
        if log and self.wal is not None:
            self.wal.append_migration_step({"seed": plan.seed, "step": idx})
        t0 = time.perf_counter()
        # ids may have been deleted or re-routed since planning: move only
        # those still owned by the donor.  The owner map evolves
        # identically under replay, so the filter is deterministic.
        oids = np.asarray([o for o in step.oids
                           if self.owner.get(int(o)) == step.donor],
                          np.int32)
        moved = 0
        with obs.span("mutation.migration_step", n=len(oids), step=idx):
            n = 0
            if len(oids):
                vecs, found = self._extract(step.donor, oids)
                oids, vecs = oids[found], vecs[found]
                n = len(oids)
            if n:
                ops = np.concatenate([np.full(n, OP_DELETE, np.int32),
                                      np.full(n, OP_INSERT, np.int32)])
                xs = np.concatenate([vecs, vecs]).astype(np.float32)
                both = np.concatenate([oids, oids])
                owner = np.concatenate(
                    [np.full(n, step.donor, np.int32),
                     np.full(n, step.receiver, np.int32)])
                if self.mesh is not None:
                    res = self._apply_mesh(ops, xs, both, owner)
                else:
                    res = self._apply_host(ops, xs, both, owner)
                st = res.statuses
                for i, o in enumerate(oids):
                    o = int(o)
                    if st[n + i] == smtree.ST_APPLIED:
                        self.owner[o] = step.receiver
                        moved += 1
                    elif st[i] == smtree.ST_APPLIED:
                        # delete landed but the insert did not: the object
                        # is gone from both shards — drop it from the map
                        # rather than advertise a phantom owner
                        self.owner.pop(o, None)
        mig["next"] = idx + 1
        if mig["next"] >= len(plan.steps):
            self._migration = None
            self.n_rebalances += 1
            obs.record_event("stream.migration_done", seed=plan.seed,
                             steps=len(plan.steps))
        self._ensure_headroom()
        with obs.span("mutation.publish"):
            self.epochs.publish(tuple(self.trees),
                                meta={"migration": {"seed": plan.seed,
                                                    "step": idx}})
        self.n_migration_steps += 1
        self.objects_migrated += moved
        if obs.enabled():
            obs.counter("rebalance.migration_steps_total").inc()
            obs.counter("rebalance.objects_moved_total").inc(moved)
            obs.histogram("rebalance.step_pause_s").observe(
                time.perf_counter() - t0)
        return moved

    def apply_control(self, kind: str, params: dict) -> None:
        """Replay one WAL control record through the same state machine
        the live writer ran.  ``rebalance`` records re-run the stop-world
        rebuild with the recorded seed (also the path for WALs predating
        incremental mode); ``migration_plan`` records re-install the
        recorded schedule; ``migration_step`` records re-execute the next
        bounded move, asserting the recorded index so a truncated or
        reordered log fails loudly instead of silently diverging."""
        if kind == KIND_REBALANCE:
            self._run_rebalance(int(params["seed"]), log=False)
        elif kind == KIND_MIGRATION_PLAN:
            self._install_migration(MigrationPlan.from_params(params))
        elif kind == KIND_MIGRATION_STEP:
            self._migration_step(log=False, expect=int(params["step"]))
        else:
            raise ValueError(f"unknown WAL control record kind {kind!r}")

    def _run_rebalance(self, seed: int, *, log: bool) -> None:
        obs.record_event("stream.rebalance", seed=seed)
        if log and self.wal is not None:
            self.wal.append_rebalance({"seed": seed})
        trees, moved, _ = rebalance_shards(self.trees, seed=seed)
        for b, t in zip(self.batchers, trees):
            b.tree = t
        # rebuilds happen host-side: drop the mesh-resident stacked form,
        # the next collective apply restacks from the fresh shards
        self._stacked = None
        self._shard_nodes = [t.max_nodes for t in trees]
        self.n_rebalances += 1
        self._rebuild_ownership()
        self._ensure_headroom()   # rebalance is a headroom-growth point too
        self.epochs.publish(tuple(self.trees))

    # -- snapshots ---------------------------------------------------------
    def stacked(self) -> TreeArrays:
        if self._stacked is not None:
            return self._stacked
        from repro.core.distributed import stack_trees
        return stack_trees(self.trees)

    def _extra(self) -> dict:
        proto = self.trees[0]
        mig = self._migration
        return {"kind": "smforest", "n_shards": self.n_shards,
                "capacity": proto.capacity, "dim": proto.dim,
                "metric": proto.metric, "min_fill": proto.min_fill,
                "shard_max_nodes": [t.max_nodes for t in self.trees],
                "n_rebalances": self.n_rebalances,
                "rebalance_mode": self.rebalance_mode,
                "n_migration_steps": self.n_migration_steps,
                # a snapshot taken mid-plan must carry the remaining
                # schedule: the WAL tail after this point holds only step
                # records, and replaying them needs the installed plan
                "migration": (None if mig is None else
                              {"params": mig["plan"].to_params(),
                               "next": int(mig["next"])}),
                "wal_seq": (self.wal.next_seq - 1 if self.wal is not None
                            else -1)}

    def snapshot(self, step: int | None = None) -> int:
        if self.ckpt is None:
            raise ValueError("no CheckpointManager configured")
        step = self._step if step is None else step
        self.ckpt.save(step, {"forest": self.stacked()},
                       extra=self._extra())
        self._step = step + 1
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, *, wal: WriteAheadLog | None = None,
                ckpt=None, **kw) -> "StreamingForest":
        """Last snapshot + WAL tail replay (bitwise-deterministic: batch
        records re-run the batcher, control records re-run through
        ``apply_control`` — a snapshot taken mid-migration re-installs the
        remaining plan from the manifest before the tail's step records
        resume it)."""
        from repro.core.distributed import stack_trees, unstack_forest
        from repro.dist.checkpoint import read_manifest, restore_checkpoint
        manifest = read_manifest(ckpt_dir)
        extra = manifest["extra"]
        shard_nodes = extra["shard_max_nodes"]
        template = stack_trees([_tree_template(extra, max_nodes=m)
                                for m in shard_nodes])
        state, _ = restore_checkpoint(ckpt_dir, {"forest": template},
                                      step=manifest["step"])
        trees = unstack_forest(state["forest"], max_nodes=shard_nodes)
        kw.setdefault("rebalance_mode",
                      extra.get("rebalance_mode", "stop_world"))
        forest = cls(trees, wal=wal, ckpt=ckpt, **kw)
        forest._step = manifest["step"] + 1
        forest.n_rebalances = extra.get("n_rebalances", 0)
        forest.n_migration_steps = extra.get("n_migration_steps", 0)
        mig = extra.get("migration")
        if mig:
            forest._install_migration(
                MigrationPlan.from_params(mig["params"]),
                next_step=int(mig["next"]))
        if wal is not None:
            for rec in wal.replay(after_seq=extra["wal_seq"]):
                if rec.kind == KIND_BATCH:
                    forest.apply(rec.ops.astype(np.int32), rec.xs, rec.oids,
                                 log=False)
                else:
                    forest.apply_control(rec.kind, rec.params or {})
        return forest
