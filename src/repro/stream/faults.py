"""Seeded, deterministic fault injection for the replication plane.

Every recovery path in the transport/lease/router stack must be *exercised*
by tests, not argued for — and a chaos test is only a test when it replays
the same failures bit-for-bit from its seed.  This module is the one source
of injected badness:

  * **frame faults** — ``FaultInjector.filter`` drops / duplicates /
    reorders a response's wire messages, and ``torn`` truncates a data
    chunk's body mid-write (the shipped-segment analogue of a crash
    mid-append).  The transport threads the injector through its send
    path (``stream.transport.WalShipServer(fault=...)``), so the receiver's
    resync machinery — not the happy path — carries the bytes.
  * **timing faults** — ``maybe_delay`` injects bounded latency so
    per-connection timeouts and SLO paths actually fire.
  * **liveness faults** — ``drop_heartbeat`` starves the router's failure
    detector (``serve.router.ReplicaRouter``), forcing degraded mode and
    failover without killing any real thread.
  * **process faults** — kill-and-restart is *not* simulated here: tests
    call the endpoints' real ``stop()``/``start()`` (and the leader's
    ``WriteAheadLog.close``) so recovery runs the genuine resume code.

All draws come from one ``random.Random(seed)`` stream per injector; a
given (seed, call sequence) produces the same fault schedule on every run,
which is what lets CI pin chaos seeds (tests/test_chaos.py) instead of
praying over flakes.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

__all__ = ["FaultPlan", "FaultInjector", "NO_FAULTS"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Probabilities for each fault class (all off by default).

    ``drop_p``/``dup_p``/``reorder_p`` act per wire message; ``torn_p``
    per data chunk (body truncated to a seeded fraction); ``delay_p``
    sleeps up to ``delay_max_s``; ``heartbeat_drop_p`` acts per heartbeat
    delivery."""
    seed: int = 0
    drop_p: float = 0.0
    dup_p: float = 0.0
    reorder_p: float = 0.0
    torn_p: float = 0.0
    delay_p: float = 0.0
    delay_max_s: float = 0.005
    heartbeat_drop_p: float = 0.0


class FaultInjector:
    """One seeded fault stream (thread-safe: draws are serialized so a
    multi-threaded run still consumes one deterministic sequence)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._lock = threading.Lock()
        self.counts = {"drop": 0, "dup": 0, "reorder": 0, "torn": 0,
                       "delay": 0, "heartbeat_drop": 0}

    def _hit(self, p: float) -> bool:
        with self._lock:
            return p > 0.0 and self._rng.random() < p

    # -- frame faults ------------------------------------------------------
    def filter(self, messages: list) -> list:
        """Apply drop/duplicate/reorder to a list of outgoing wire
        messages.  Reorder swaps adjacent survivors (a bounded shuffle:
        TCP delivers what we send in order, so this models the *shipping
        layer* re-framing, not arbitrary network reordering)."""
        plan = self.plan
        out = []
        for m in messages:
            if self._hit(plan.drop_p):
                self.counts["drop"] += 1
                continue
            out.append(m)
            if self._hit(plan.dup_p):
                self.counts["dup"] += 1
                out.append(m)
        i = 0
        while i + 1 < len(out):
            if self._hit(plan.reorder_p):
                self.counts["reorder"] += 1
                out[i], out[i + 1] = out[i + 1], out[i]
                i += 2          # a swapped pair is settled
            else:
                i += 1
        return out

    def torn(self, body: bytes) -> bytes:
        """Maybe truncate a data chunk mid-write (torn shipped segment).
        Never returns empty for a non-empty body — a zero-byte chunk is
        indistinguishable from no progress and would stall the drill
        rather than exercise the torn-tail scan."""
        if len(body) > 1 and self._hit(self.plan.torn_p):
            self.counts["torn"] += 1
            with self._lock:
                k = self._rng.randint(1, len(body) - 1)
            return body[:k]
        return body

    # -- timing faults -----------------------------------------------------
    def maybe_delay(self) -> None:
        if self._hit(self.plan.delay_p):
            self.counts["delay"] += 1
            with self._lock:
                d = self._rng.uniform(0.0, self.plan.delay_max_s)
            time.sleep(d)

    # -- liveness faults ---------------------------------------------------
    def drop_heartbeat(self) -> bool:
        """True when this heartbeat delivery should be starved."""
        if self._hit(self.plan.heartbeat_drop_p):
            self.counts["heartbeat_drop"] += 1
            return True
        return False


NO_FAULTS = FaultInjector(FaultPlan())
