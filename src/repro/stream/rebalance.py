"""Background forest rebalancing after skewed mutation streams.

A sharded SM-forest degrades under skew: a delete stream concentrated on a
few shards leaves them underfull (every query still pays their descent,
and their nodes sit near min-fill) while insert-heavy shards deepen.  This
module closes the ROADMAP item: it tracks per-shard live-object counts and
node fill-factor histograms, detects skew, and redistributes objects by
**rebuilding only the affected shards with ``bulk_build`` over donor
ranges** — donors shed their highest-id surplus, receivers absorb it, and
untouched shards keep their arrays bitwise intact.

Everything here is deterministic given the input trees and a seed: the
decision to rebalance is recorded in the WAL (``append_rebalance``) so a
snapshot + tail replay re-executes the identical rebuild at the identical
point in the mutation order (repro.stream.pipeline, DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.smtree import TreeArrays, bulk_build, empty_tree

__all__ = ["ShardStats", "collect_stats", "needs_rebalance",
           "rebalance_shards", "live_objects"]

_FILL_BINS = np.array([0.0, 0.25, 0.5, 0.75, 1.0 + 1e-9])


@dataclasses.dataclass
class ShardStats:
    live_counts: np.ndarray    # [S] live objects per shard
    fill_hist: np.ndarray      # [S, 4] alive-node fill-fraction histogram
    free_nodes: np.ndarray     # [S] unallocated node slots

    @property
    def total(self) -> int:
        return int(self.live_counts.sum())

    @property
    def skew(self) -> float:
        """Most-loaded vs least-loaded shard, add-one smoothed
        (1.0 = perfectly balanced).  max/min rather than max/mean: a
        single shard drained by a skewed delete stream barely moves the
        mean of S shards but collapses the min — exactly the case the
        rebalancer exists for."""
        if self.live_counts.size == 0:
            return 1.0
        return float((self.live_counts.max() + 1)
                     / (self.live_counts.min() + 1))


def live_objects(tree: TreeArrays) -> tuple[np.ndarray, np.ndarray]:
    """(vectors [m, dim], ids [m]) of every live object, in deterministic
    node-major order."""
    valid = np.asarray(tree.valid)
    mask = (valid & np.asarray(tree.is_leaf)[:, None]
            & np.asarray(tree.alive)[:, None])
    return np.asarray(tree.vecs)[mask], np.asarray(tree.oid)[mask]


def collect_stats(trees: list[TreeArrays]) -> ShardStats:
    counts, hists, free = [], [], []
    for t in trees:
        alive = np.asarray(t.alive)
        cnt = np.asarray(t.count)
        counts.append(t.n_objects)
        fills = cnt[alive] / t.capacity
        hists.append(np.histogram(fills, bins=_FILL_BINS)[0])
        free.append(int((~alive).sum()))
    return ShardStats(np.asarray(counts, np.int64),
                      np.stack(hists).astype(np.int64),
                      np.asarray(free, np.int64))


def needs_rebalance(stats: ShardStats, *, max_skew: float = 1.5,
                    min_objects: int = 64) -> bool:
    """Trigger policy: fire when the most loaded shard holds ``max_skew``×
    the least loaded one.  Tiny forests never trigger — rebuilding them
    costs more than the skew."""
    if stats.total < min_objects:
        return False
    return stats.skew > max_skew


def _targets(counts: np.ndarray) -> np.ndarray:
    """Balanced per-shard targets: total split as evenly as integers allow
    (first ``total mod S`` shards take the extra object)."""
    S = len(counts)
    total = int(counts.sum())
    base = total // S
    t = np.full(S, base, np.int64)
    t[:total - base * S] += 1
    return t


def rebalance_shards(trees: list[TreeArrays], *, seed: int = 0,
                     ) -> tuple[list[TreeArrays], int, dict]:
    """Redistribute live objects toward balanced shard sizes.

    Donors (above target) shed their highest-id objects; the pooled
    surplus fills receivers (below target) in shard order.  Every affected
    shard is rebuilt with ``bulk_build`` over its new object set (seeded
    ``seed + shard``); unaffected shards are returned as-is (bitwise).
    Returns (trees, n_moved, params) where ``params`` round-trips through
    the WAL for deterministic replay."""
    S = len(trees)
    per_shard = [live_objects(t) for t in trees]
    counts = np.asarray([len(oids) for _, oids in per_shard], np.int64)
    targets = _targets(counts)

    pool_vecs: list[np.ndarray] = []
    pool_oids: list[np.ndarray] = []
    keep: list[tuple[np.ndarray, np.ndarray]] = []
    touched = [False] * S
    for s in range(S):
        vecs, oids = per_shard[s]
        surplus = int(counts[s] - targets[s])
        if surplus > 0:
            order = np.argsort(oids, kind="stable")
            donate, retain = order[-surplus:], order[:-surplus]
            pool_vecs.append(vecs[donate])
            pool_oids.append(oids[donate])
            keep.append((vecs[retain], oids[retain]))
            touched[s] = True
        else:
            keep.append((vecs, oids))
    moved = int(sum(len(o) for o in pool_oids))
    if moved == 0:
        return trees, 0, {"seed": int(seed), "moved": 0}
    pv = np.concatenate(pool_vecs)
    po = np.concatenate(pool_oids)
    order = np.argsort(po, kind="stable")
    pv, po = pv[order], po[order]

    out: list[TreeArrays] = []
    cursor = 0
    proto = trees[0]
    for s in range(S):
        vecs, oids = keep[s]
        deficit = int(targets[s] - counts[s])
        if deficit > 0:
            vecs = np.concatenate([vecs, pv[cursor:cursor + deficit]])
            oids = np.concatenate([oids, po[cursor:cursor + deficit]])
            cursor += deficit
            touched[s] = True
        if not touched[s]:
            out.append(trees[s])
        elif len(oids) == 0:
            out.append(empty_tree(
                dim=proto.dim, capacity=proto.capacity,
                max_nodes=max(16, trees[s].max_nodes), metric=proto.metric,
                min_fill_frac=proto.min_fill / proto.capacity))
        else:
            out.append(bulk_build(
                np.asarray(vecs, np.float32), ids=np.asarray(oids),
                capacity=proto.capacity, metric=proto.metric,
                min_fill_frac=proto.min_fill / proto.capacity,
                seed=int(seed) + s))
    return out, moved, {"seed": int(seed), "moved": moved}
