"""Background forest rebalancing after skewed mutation streams.

A sharded SM-forest degrades under skew: a delete stream concentrated on a
few shards leaves them underfull (every query still pays their descent,
and their nodes sit near min-fill) while insert-heavy shards deepen.  This
module closes the ROADMAP item: it tracks per-shard live-object counts and
node fill-factor histograms, detects skew, and redistributes objects by
**rebuilding only the affected shards with ``bulk_build`` over donor
ranges** — donors shed their highest-id surplus, receivers absorb it, and
untouched shards keep their arrays bitwise intact.

Two repair strategies share the trigger and the donor/receiver pairing
math:

- **Stop-the-world** (``rebalance_shards``): rebuild every touched shard
  with ``bulk_build`` in one pass.  Simple, but the rebuild is a
  publish-time cliff (~hundreds of ms at bench scale) — kept as the
  baseline and as the replay path for WALs written before incremental
  mode existed.
- **Incremental** (``plan_migration`` → ``MigrationPlan``): emit a
  deterministic schedule of bounded steps (one donor, one receiver, at
  most ``step_objects`` ids each).  The streaming forest executes at most
  one step per mutation batch as a normal delete-on-donor /
  insert-on-receiver cohort behind the epoch mechanism, so skew drains
  continuously with no cliff (repro.stream.pipeline, DESIGN.md §16).

Everything here is deterministic given the input trees and a seed: the
decision to rebalance — and, in incremental mode, the full plan plus each
executed step — is recorded in the WAL (``append_rebalance`` /
``append_migration_plan`` / ``append_migration_step``) so a snapshot +
tail replay re-executes the identical repair at the identical point in
the mutation order (repro.stream.pipeline, DESIGN.md §10, §16).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.smtree import TreeArrays, bulk_build, empty_tree

__all__ = ["ShardStats", "collect_stats", "needs_rebalance",
           "rebalance_shards", "live_objects", "GeometryMismatch",
           "check_geometry", "MigrationStep", "MigrationPlan",
           "plan_migration"]

_FILL_BINS = np.array([0.0, 0.25, 0.5, 0.75, 1.0 + 1e-9])


@dataclasses.dataclass
class ShardStats:
    live_counts: np.ndarray    # [S] live objects per shard
    fill_hist: np.ndarray      # [S, 4] alive-node fill-fraction histogram
    free_nodes: np.ndarray     # [S] unallocated node slots

    @property
    def total(self) -> int:
        return int(self.live_counts.sum())

    @property
    def skew(self) -> float:
        """Most-loaded vs least-loaded shard, add-one smoothed
        (1.0 = perfectly balanced).  max/min rather than max/mean: a
        single shard drained by a skewed delete stream barely moves the
        mean of S shards but collapses the min — exactly the case the
        rebalancer exists for."""
        if self.live_counts.size == 0:
            return 1.0
        return float((self.live_counts.max() + 1)
                     / (self.live_counts.min() + 1))


def live_objects(tree: TreeArrays) -> tuple[np.ndarray, np.ndarray]:
    """(vectors [m, dim], ids [m]) of every live object, in deterministic
    node-major order."""
    valid = np.asarray(tree.valid)
    mask = (valid & np.asarray(tree.is_leaf)[:, None]
            & np.asarray(tree.alive)[:, None])
    return np.asarray(tree.vecs)[mask], np.asarray(tree.oid)[mask]


def collect_stats(trees: list[TreeArrays]) -> ShardStats:
    counts, hists, free = [], [], []
    for t in trees:
        alive = np.asarray(t.alive)
        cnt = np.asarray(t.count)
        counts.append(t.n_objects)
        fills = cnt[alive] / t.capacity
        hists.append(np.histogram(fills, bins=_FILL_BINS)[0])
        free.append(int((~alive).sum()))
    return ShardStats(np.asarray(counts, np.int64),
                      np.stack(hists).astype(np.int64),
                      np.asarray(free, np.int64))


def needs_rebalance(stats: ShardStats, *, max_skew: float = 1.5,
                    min_objects: int = 64,
                    free_floor: float | None = None) -> bool:
    """Trigger policy: fire when the most loaded shard holds ``max_skew``×
    the least loaded one.  Tiny forests never trigger — rebuilding them
    costs more than the skew.

    With ``free_floor`` set, additionally fire on free-ring pressure: an
    over-target shard whose unallocated-node fraction has dropped below
    the floor is about to force a mid-batch host ``grow_tree`` escalation,
    and shedding its surplus (merges reclaim nodes as objects leave) is
    cheaper than growing its arrays.  Balanced-but-starved shards are not
    a rebalancing problem — migration cannot shed anything from a shard
    already at target, so those stay with the apply path's headroom
    growth."""
    if stats.total < min_objects:
        return False
    if stats.skew > max_skew:
        return True
    if free_floor is not None and stats.live_counts.size:
        alive = stats.fill_hist.sum(axis=1)
        frac = stats.free_nodes / np.maximum(alive + stats.free_nodes, 1)
        pressured = frac < free_floor
        over_target = stats.live_counts > _targets(stats.live_counts)
        if bool((pressured & over_target).any()):
            return True
    return False


class GeometryMismatch(ValueError):
    """Forest shards disagree on tree geometry (capacity / dim / metric /
    min-fill).  Moving objects between such shards — or rebuilding a
    drained one from shard 0's prototype — would silently manufacture a
    divergent shard, so redistribution refuses up front."""


def check_geometry(trees: list[TreeArrays]) -> None:
    """Assert donor/receiver geometry compatibility across the forest.

    Every redistribution path rebuilds or grows shards from shard 0's
    (capacity, dim, metric, min_fill) prototype; raise a typed error if
    any shard disagrees instead of building a divergent one."""
    if not trees:
        return
    p = trees[0]
    ref = (p.capacity, p.dim, p.metric, p.min_fill)
    for s, t in enumerate(trees[1:], 1):
        got = (t.capacity, t.dim, t.metric, t.min_fill)
        if got != ref:
            raise GeometryMismatch(
                f"shard {s} geometry (capacity, dim, metric, min_fill)="
                f"{got!r} differs from shard 0 {ref!r}; cross-shard object "
                f"moves would rebuild a divergent shard")


def _targets(counts: np.ndarray) -> np.ndarray:
    """Balanced per-shard targets: total split as evenly as integers allow
    (first ``total mod S`` shards take the extra object)."""
    S = len(counts)
    total = int(counts.sum())
    base = total // S
    t = np.full(S, base, np.int64)
    t[:total - base * S] += 1
    return t


def rebalance_shards(trees: list[TreeArrays], *, seed: int = 0,
                     ) -> tuple[list[TreeArrays], int, dict]:
    """Redistribute live objects toward balanced shard sizes.

    Donors (above target) shed their highest-id objects; the pooled
    surplus fills receivers (below target) in shard order.  Every affected
    shard is rebuilt with ``bulk_build`` over its new object set (seeded
    ``seed + shard``); unaffected shards are returned as-is (bitwise).
    Returns (trees, n_moved, params) where ``params`` round-trips through
    the WAL for deterministic replay."""
    check_geometry(trees)
    S = len(trees)
    per_shard = [live_objects(t) for t in trees]
    counts = np.asarray([len(oids) for _, oids in per_shard], np.int64)
    targets = _targets(counts)

    pool_vecs: list[np.ndarray] = []
    pool_oids: list[np.ndarray] = []
    keep: list[tuple[np.ndarray, np.ndarray]] = []
    touched = [False] * S
    for s in range(S):
        vecs, oids = per_shard[s]
        surplus = int(counts[s] - targets[s])
        if surplus > 0:
            order = np.argsort(oids, kind="stable")
            donate, retain = order[-surplus:], order[:-surplus]
            pool_vecs.append(vecs[donate])
            pool_oids.append(oids[donate])
            keep.append((vecs[retain], oids[retain]))
            touched[s] = True
        else:
            keep.append((vecs, oids))
    moved = int(sum(len(o) for o in pool_oids))
    if moved == 0:
        return trees, 0, {"seed": int(seed), "moved": 0}
    pv = np.concatenate(pool_vecs)
    po = np.concatenate(pool_oids)
    order = np.argsort(po, kind="stable")
    pv, po = pv[order], po[order]

    out: list[TreeArrays] = []
    cursor = 0
    proto = trees[0]
    for s in range(S):
        vecs, oids = keep[s]
        deficit = int(targets[s] - counts[s])
        if deficit > 0:
            vecs = np.concatenate([vecs, pv[cursor:cursor + deficit]])
            oids = np.concatenate([oids, po[cursor:cursor + deficit]])
            cursor += deficit
            touched[s] = True
        if not touched[s]:
            out.append(trees[s])
        elif len(oids) == 0:
            out.append(empty_tree(
                dim=proto.dim, capacity=proto.capacity,
                max_nodes=max(16, trees[s].max_nodes), metric=proto.metric,
                min_fill_frac=proto.min_fill / proto.capacity))
        else:
            out.append(bulk_build(
                np.asarray(vecs, np.float32), ids=np.asarray(oids),
                capacity=proto.capacity, metric=proto.metric,
                min_fill_frac=proto.min_fill / proto.capacity,
                seed=int(seed) + s))
    return out, moved, {"seed": int(seed), "moved": moved}


# --------------------------------------------------------------------------
# Incremental migration planning (DESIGN.md §16)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MigrationStep:
    """One bounded move: re-home ``oids`` from shard ``donor`` to shard
    ``receiver``.  A step is a single delete-on-donor / insert-on-receiver
    cohort, so executing it costs one normal apply dispatch + one epoch
    publish."""
    donor: int
    receiver: int
    oids: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Deterministic migration schedule.  The full plan (not just the
    seed) rides the WAL control record: replay — including resuming after
    a crash mid-plan — re-installs exactly this object→shard assignment
    even though the trees have mutated since planning time."""
    seed: int
    steps: tuple[MigrationStep, ...]

    @property
    def total(self) -> int:
        return sum(len(s.oids) for s in self.steps)

    def to_params(self) -> dict:
        return {"seed": int(self.seed),
                "steps": [[int(s.donor), int(s.receiver),
                           [int(o) for o in s.oids]] for s in self.steps]}

    @classmethod
    def from_params(cls, params: dict) -> "MigrationPlan":
        steps = tuple(MigrationStep(int(d), int(r),
                                    tuple(int(o) for o in oids))
                      for d, r, oids in params["steps"])
        return cls(int(params["seed"]), steps)


def plan_migration(trees: list[TreeArrays], *, seed: int = 0,
                   step_objects: int = 64) -> MigrationPlan:
    """Plan the same redistribution ``rebalance_shards`` would perform,
    as a schedule of bounded steps instead of a one-shot rebuild.

    The donor/receiver pairing is decision-for-decision the stop-the-world
    math: donors (above target) shed their highest-id surplus, the pooled
    surplus — stable-sorted by object id — fills receivers (below target)
    in shard order.  The assignments are then grouped by (donor, receiver)
    pair (pairs in first-appearance order, oid order preserved within a
    pair — donors interleave in the oid-sorted pool, so cutting on raw
    pair changes would degenerate to one-object steps) and each group is
    cut into steps of at most ``step_objects`` ids, so every step stays a
    bounded conflict-free two-shard cohort.  Deterministic given
    (trees, seed, step_objects)."""
    check_geometry(trees)
    S = len(trees)
    per_shard = [live_objects(t)[1] for t in trees]
    counts = np.asarray([len(oids) for oids in per_shard], np.int64)
    targets = _targets(counts)

    pool_oids: list[np.ndarray] = []
    pool_donor: list[np.ndarray] = []
    for s in range(S):
        surplus = int(counts[s] - targets[s])
        if surplus > 0:
            order = np.argsort(per_shard[s], kind="stable")
            donate = order[-surplus:]
            pool_oids.append(per_shard[s][donate])
            pool_donor.append(np.full(surplus, s, np.int64))
    if not pool_oids:
        return MigrationPlan(int(seed), ())
    po = np.concatenate(pool_oids)
    pd = np.concatenate(pool_donor)
    order = np.argsort(po, kind="stable")
    po, pd = po[order], pd[order]

    # receivers consume pool slices in shard order — identical to the
    # stop-the-world cursor walk (surpluses and deficits sum equal by
    # _targets construction, so the whole pool is assigned)
    pr = np.empty(len(po), np.int64)
    cursor = 0
    for s in range(S):
        deficit = int(targets[s] - counts[s])
        if deficit > 0:
            pr[cursor:cursor + deficit] = s
            cursor += deficit

    groups: dict[tuple[int, int], list[int]] = {}
    for oid, d, r in zip(po.tolist(), pd.tolist(), pr.tolist()):
        groups.setdefault((int(d), int(r)), []).append(int(oid))
    steps: list[MigrationStep] = []
    for (d, r), oids in groups.items():
        for c in range(0, len(oids), step_objects):
            steps.append(MigrationStep(d, r,
                                       tuple(oids[c:c + step_objects])))
    return MigrationPlan(int(seed), tuple(steps))
