"""Synthetic vector datasets reproducing the paper's §4.1 distributions.

Three distributions, all over [0, 1]^20 (object size is constant at 20 dims;
experiment dimensionality is varied in the *metric*, not the data):

* ``clustered`` — points distributed around randomly generated seed points
  using a trigonometric radial falloff, each vector component generated
  independently (the paper notes this produces density ridges parallel to
  the coordinate axes — we keep that artefact deliberately, Fig. 4).
* ``nonuniform`` — a polynomial transform of uniform randoms (Fig. 9).
* ``uniform`` — iid U[0,1).
"""
from __future__ import annotations

import numpy as np

FULL_DIMS = 20  # paper: constant object size, 20-d vectors


def clustered(n: int, *, dims: int = FULL_DIMS, n_clusters: int = 50,
              spread: float = 0.12, seed: int = 0) -> np.ndarray:
    """Trig-falloff clusters around random seeds, per-component independent.

    Each component c of a point near seed s is  s_c + spread * sin(pi*(u-0.5))
    with u ~ U[0,1): sin concentrates mass near the seed (higher density close
    to seed points), and independence across components yields the paper's
    axis-parallel density ridges.
    """
    rng = np.random.default_rng(seed)
    seeds = rng.random((n_clusters, dims))
    which = rng.integers(0, n_clusters, size=n)
    u = rng.random((n, dims))
    offs = spread * np.sin(np.pi * (u - 0.5)) ** 3  # odd power: peaked at 0
    pts = seeds[which] + offs
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def nonuniform(n: int, *, dims: int = FULL_DIMS, power: int = 3,
               seed: int = 0) -> np.ndarray:
    """Polynomial transform of uniforms: x -> x^power, mirrored around 0.5."""
    rng = np.random.default_rng(seed)
    u = rng.random((n, dims))
    x = 0.5 + 0.5 * np.sign(u - 0.5) * np.abs(2 * u - 1) ** power
    return x.astype(np.float32)


def uniform(n: int, *, dims: int = FULL_DIMS, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((n, dims)).astype(np.float32)


DISTRIBUTIONS = {
    "clustered": clustered,
    "nonuniform": nonuniform,
    "uniform": uniform,
}


def make_dataset(kind: str, n: int, *, dims: int = FULL_DIMS, seed: int = 0) -> np.ndarray:
    try:
        fn = DISTRIBUTIONS[kind]
    except KeyError:
        raise KeyError(f"unknown distribution {kind!r}; have {sorted(DISTRIBUTIONS)}") from None
    return fn(n, dims=dims, seed=seed)
