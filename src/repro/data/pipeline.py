"""Deterministic, checkpointable, sharded synthetic LM data pipeline.

Real deployments drop in a tokenised corpus reader behind the same API.  The
synthetic stream is a counter-based hash (stateless — batch i is a pure
function of (seed, step, shard)), which gives us:
  * exact restart: resuming at step k reproduces the same batches bitwise
    (tested in tests/test_checkpoint.py),
  * per-host sharding with no coordination: each data-parallel rank draws its
    slice of the global batch by index,
  * infinite length without storage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """splitmix-ish integer hash, vectorised."""
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def synth_batch(cfg: DataConfig, step: int, *, shard: int = 0,
                n_shards: int = 1, with_labels: bool = True) -> dict:
    """Global batch slice for `shard` of `n_shards` at `step`."""
    assert cfg.global_batch % n_shards == 0
    b = cfg.global_batch // n_shards
    rows = (np.arange(b) + shard * b).astype(np.uint32)
    cols = np.arange(cfg.seq_len + 1, dtype=np.uint32)
    base = (np.uint32(cfg.seed) * np.uint32(2654435761)
            + np.uint32(step) * np.uint32(97531))
    grid = _hash_u32(base + rows[:, None] * np.uint32(7919) + cols[None, :])
    toks = (grid % np.uint32(cfg.vocab_size)).astype(np.int32)
    out = {"tokens": toks[:, :-1]}
    if with_labels:
        out["labels"] = toks[:, 1:]
    return out


def batches_for(cfg: ArchConfig, shape: ShapeSpec, *, seed=0):
    """Iterator of global batches matching the model's input_specs."""
    dc = DataConfig(seed=seed, vocab_size=cfg.vocab_size,
                    seq_len=shape.seq_len, global_batch=shape.global_batch)
    step = 0
    rng = np.random.default_rng(seed)
    while True:
        batch = synth_batch(dc, step)
        if cfg.frontend == "vision_stub":
            n_img = cfg.n_image_tokens
            batch["tokens"] = batch["tokens"][:, : shape.seq_len - n_img]
            batch["image_embeds"] = rng.standard_normal(
                (shape.global_batch, n_img, cfg.d_model), np.float32)
            batch["labels"] = batch["labels"][:, : shape.seq_len]
        if cfg.is_encdec:
            dec = min(cfg.max_target_len, max(8, shape.seq_len // 8))
            batch = {
                "frames": rng.standard_normal(
                    (shape.global_batch, shape.seq_len, cfg.d_model),
                    np.float32),
                "tokens": batch["tokens"][:, :dec],
                "labels": batch["labels"][:, :dec],
            }
        yield step, batch
        step += 1
