from repro.data.datagen import make_dataset, clustered, nonuniform, uniform  # noqa: F401
