"""GSPMD sharding policy: pure functions from (config, pytree, mesh) to
``PartitionSpec`` trees.

Design rules (DESIGN.md §7):
  * 'model' is the tensor-parallel axis.  Attention shards the *head* axis
    (weights are head-shaped, see models/attention.py), FFNs shard the hidden
    dim, vocab-sized matrices shard the vocab dim, SSM/xLSTM blocks shard
    d_inner / d_x.  K/V projections are replicated (kv heads are tiny).
  * 'data' (times 'pod' when present) is the data-parallel axis; parameters
    above ``FSDP_MIN_ELEMS`` additionally shard their largest free dim over
    'data' (FSDP), and ZeRO-1 extends every optimizer-moment leaf with 'data'
    on its first free dim (``opt_state_pspec``).
  * Every rule is guarded by exact divisibility — jit argument shardings
    reject uneven shards — so the same table serves every arch in
    configs/all_archs.py on any mesh shape; an axis that does not divide is
    simply dropped (the spec degrades to replication, never errors).
  * Rules are duck-typed on the mesh (only ``.shape``/``.axis_names`` are
    read) so they unit-test without devices (tests/test_sharding_rules.py).

Also hosts the small runtime layer the model code uses:
``use_mesh``/``_ambient_mesh`` (an explicit ambient-mesh stack that works on
every jax version, with or without ``jax.sharding.set_mesh``),
``constrain``/``constrain_batch_seq`` (divisibility-guarded
with_sharding_constraint), ``set_sequence_parallel`` and a ``shard_map``
compat wrapper.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Parameters with at least this many elements get their largest free dim
# sharded over 'data' on top of tensor parallelism (FSDP).  64 MiB of f32 —
# big enough that smoke/test configs stay simply TP-sharded.
FSDP_MIN_ELEMS = 1 << 24

# Axes that compose the data-parallel dimension, outermost first ('pod' is
# the DCN axis of the multipod mesh, see launch/mesh.py).
DP_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# ambient mesh (compat layer: jax<=0.4 has no jax.sharding.set_mesh)
# ---------------------------------------------------------------------------
_MESH_STACK: list[Any] = []
_SEQ_PARALLEL = False


def _ambient_mesh():
    """The innermost mesh set via ``use_mesh`` (None outside any context)."""
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Portable replacement for ``jax.sharding.set_mesh`` (absent in older jax):
    ``constrain`` resolves axis names against this mesh, and the physical
    ``Mesh`` context is entered too so named in-jit collectives resolve.
    """
    _MESH_STACK.append(mesh)
    try:
        if hasattr(mesh, "__enter__"):
            with mesh:
                yield mesh
        else:  # duck-typed mesh (tests)
            yield mesh
    finally:
        _MESH_STACK.pop()


def set_sequence_parallel(flag: bool) -> None:
    """Megatron-style sequence parallelism on the residual stream: when on,
    ``constrain_batch_seq`` additionally shards the sequence dim over
    'model'.  Trace-time switch (set by train_step from TrainSettings)."""
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = bool(flag)


def shard_map(f=None, mesh=None, in_specs=None, out_specs=None,
              axis_names=None, **kwargs):
    """shard_map across jax versions.

    Newer jax exposes ``jax.shard_map(..., check_vma=, axis_names=)``, older
    only ``jax.experimental.shard_map.shard_map(..., check_rep=, auto=)``;
    this wrapper accepts either spelling (and partial application as a
    decorator) and forwards to whichever exists.  ``axis_names`` is the set
    of *manual* axes (new-API convention): on new jax it passes through, so
    e.g. the MoE expert-parallel body stays manual over 'data' only while
    GSPMD tensor-shards the expert FFN over 'model'.  On old jax the
    equivalent partial-manual spelling (``auto=`` complement) hard-crashes
    the XLA SPMD partitioner for these bodies, so the wrapper falls back to
    fully-manual there — numerically identical, at the cost of replicated
    expert FFN compute across 'model' on that jax version only.
    Replication checking is always off — the forest/moe bodies do their own
    collectives."""
    if f is None:                       # functools.partial decorator form
        return lambda fn: shard_map(fn, mesh, in_specs, out_specs,
                                    axis_names=axis_names, **kwargs)
    kwargs.pop("check_rep", None)
    kwargs.pop("check_vma", None)
    if hasattr(jax, "shard_map"):
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, **kwargs)


# ---------------------------------------------------------------------------
# mesh introspection helpers (duck-typed: Mesh, AbstractMesh or test fakes)
# ---------------------------------------------------------------------------
def _mesh_sizes(mesh) -> dict[str, int]:
    if hasattr(mesh, "shape") and mesh.shape is not None:
        return dict(mesh.shape)
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _axes_of(part) -> tuple[str, ...]:
    if part is None:
        return ()
    return part if isinstance(part, tuple) else (part,)


def _used_axes(entries) -> set:
    return {a for e in entries for a in _axes_of(e)}


def batch_dp(mesh):
    """The composite data-parallel spec entry for this mesh: 'data', or
    ('pod', 'data') on the multipod mesh."""
    sizes = _mesh_sizes(mesh)
    dp = tuple(a for a in DP_AXES if a in sizes)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def _dp_entry(mesh, dim_size: int):
    """Data-parallel entry for a batch dim, or None if it does not divide."""
    sizes = _mesh_sizes(mesh)
    dp = tuple(a for a in DP_AXES if a in sizes and sizes[a] > 1)
    if not dp:
        return None
    total = math.prod(sizes[a] for a in dp)
    if dim_size % total == 0:
        return dp if len(dp) > 1 else dp[0]
    # fall back to the inner 'data' axis alone (pod stays replicated)
    if "data" in dp and dim_size % sizes["data"] == 0:
        return "data"
    return None


def to_named(specs, mesh):
    """Map a PartitionSpec tree to NamedShardings on a concrete mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
# Dense-wrapped weights ({"w": ...}) keyed by their owner, mapped to the
# tensor-parallel dim (negative index into the leaf shape).  Column-parallel
# projections shard their output dim (-1); row-parallel ones their input
# dim (-2) so the following contraction reduces with one psum.
_DENSE_COL = ("wi/w", "wg/w", "up/w", "in_proj/w", "dt_proj/w", "wv/w",
              "w_o/w", "wq/w", "wk/w", "slstm/w")
_DENSE_ROW = ("wo/w", "down/w", "out_proj/w", "x_proj/w")


def _tp_rule(key: str, ndim: int) -> int | None:
    """Tensor-parallel dim (negative index) for a param path, or None."""
    last = key.rsplit("/", 1)[-1]
    # replicated: norms, biases, routers, tiny gate tables, position tables
    if "norm" in key or last in ("scale", "bias", "b", "b_if", "router",
                                 "pos_embed", "dec_pos", "r"):
        return None
    if key.endswith("lm_head/w"):
        return -1                       # vocab (column) parallel
    # attention: head-sharded q/out, replicated k/v (kv heads are tiny and
    # broadcast to query-head groups — keeps attention collective-free)
    if "attn/" in key:                  # matches attn/ and xattn/
        if last in ("wq", "bq"):
            return -2                   # [.., D, H, dh] / [.., H, dh]
        if last == "wo":
            return -3                   # [.., H, dh, D]
        return None                     # wk, wv, bk, bv
    for suffix in _DENSE_COL:
        if key.endswith(suffix):
            return -1
    for suffix in _DENSE_ROW:
        if key.endswith(suffix):
            return -2
    # bare (stacked) weights: MoE experts, SSM/xLSTM tables
    if last in ("wi", "wg"):
        return -1                       # moe [.., E, D, F]: hidden dim
    if last == "wo":
        return -2                       # moe [.., E, F, D]: hidden dim
    if last in ("conv_w", "conv_b", "D"):
        return -1                       # [.., k, d_inner] / [.., d_inner]
    if last in ("A_log", "w_if"):
        return -2                       # [.., d_inner, n] / [.., dx, 2H]
    return None


def param_pspecs(cfg, params, mesh):
    """PartitionSpec tree for a parameter pytree (arrays or
    ShapeDtypeStructs) of this arch on this mesh."""
    sizes = _mesh_sizes(mesh)
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)
    ep = bool(getattr(cfg, "moe_ep", False))
    n_experts = getattr(cfg, "padded_experts", 0)

    def rule(path, leaf):
        key = _path_str(path)
        shape = tuple(leaf.shape)
        ndim = len(shape)
        entries: list = [None] * ndim
        last = key.rsplit("/", 1)[-1]
        no_fsdp = False

        if last == "embed":
            # vocab-parallel, never FSDP'd: the tied head matmul wants the
            # d_model dim intact (tests/test_sharding_rules.py pins this)
            if model > 1 and shape[0] % model == 0:
                entries[0] = "model"
            no_fsdp = True
        else:
            tp = _tp_rule(key, ndim)
            if tp is not None and model > 1:
                dim = ndim + tp
                if 0 <= dim < ndim and shape[dim] % model == 0:
                    entries[dim] = "model"
            if ep and last in ("wi", "wg", "wo") and "moe/" in key \
                    and ndim >= 3 and data > 1 and n_experts \
                    and shape[ndim - 3] % data == 0:
                # expert parallelism: experts ride the data axis (A2A
                # dispatch); that axis is then spoken for — no FSDP on top
                entries[ndim - 3] = "data"
                no_fsdp = True

        if not no_fsdp and data > 1 and "data" not in _used_axes(entries) \
                and math.prod(shape) >= FSDP_MIN_ELEMS:
            free = [i for i in range(ndim)
                    if entries[i] is None and shape[i] % data == 0]
            if free:
                entries[max(free, key=lambda i: shape[i])] = "data"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_pspec(param_spec: P, shape, mesh) -> P:
    """ZeRO-1: extend a param's spec with 'data' on its first free,
    evenly-divisible dim for the optimizer moment of that param."""
    sizes = _mesh_sizes(mesh)
    data = sizes.get("data", 1)
    entries = [param_spec[i] if i < len(param_spec) else None
               for i in range(len(shape))]
    if data > 1 and "data" not in _used_axes(entries):
        for i, dim in enumerate(shape):
            if entries[i] is None and dim % data == 0:
                entries[i] = "data"
                break
    return P(*entries)


# ---------------------------------------------------------------------------
# input / cache / output rules
# ---------------------------------------------------------------------------
def input_pspecs(cfg, kind: str, inputs, mesh):
    """Batch-dim data parallelism for every model input leaf."""
    del kind

    def rule(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        entries = [_dp_entry(mesh, shape[0])] + [None] * (len(shape) - 1)
        return P(*entries)

    return jax.tree.map(rule, inputs)


_KV_KEYS = ("kv", "self_k", "self_v", "cross_k", "cross_v")


def cache_pspecs(cfg, cache, mesh, *, seq_shard: bool = False):
    """Decode-cache shardings.  KV caches [layers, b, KV, S, dh] shard batch
    over the dp axes and — for long contexts (``seq_shard``) or whenever
    'model' divides — the sequence axis; kv heads stay replicated (matching
    the attention weight rules).  Recurrent-state caches (SSM/xLSTM) shard
    batch plus their largest inner dim over 'model'."""
    sizes = _mesh_sizes(mesh)
    model = sizes.get("model", 1)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        keys = {str(getattr(p, "key", "")) for p in path}
        entries: list = [None] * ndim
        if ndim >= 2:
            entries[1] = _dp_entry(mesh, shape[1])
        if keys & set(_KV_KEYS) and ndim == 5:
            seq_axes: list[str] = []
            prod = 1
            candidates = ["model"]
            if seq_shard:
                # long-context: fold free dp axes into the sequence split too
                candidates += [a for a in DP_AXES
                               if a in sizes and a not in
                               _used_axes(entries)]
            for a in candidates:
                if sizes.get(a, 1) > 1 and shape[3] % (prod * sizes[a]) == 0:
                    seq_axes.append(a)
                    prod *= sizes[a]
            if seq_axes:
                entries[3] = tuple(seq_axes) if len(seq_axes) > 1 \
                    else seq_axes[0]
        elif ndim >= 3 and model > 1:
            # recurrent state: TP its largest inner dim (d_inner / dx / dh)
            free = [i for i in range(2, ndim) if shape[i] % model == 0]
            if free:
                entries[max(free, key=lambda i: shape[i])] = "model"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, cache)


def logits_pspec(mesh) -> P:
    """[batch, seq, vocab] logits: dp on batch, vocab-parallel on 'model'."""
    return P(batch_dp(mesh), None, "model")


def query_pspecs(mesh, batch_size: int) -> P:
    """SM-tree query-cohort sharding: [b, dim] batches split over the dp
    axes (divisibility-guarded), tree pages replicated.  The cohort descent
    (core/smtree.py) is batched over b in every op, so GSPMD runs each
    query shard's descent locally with zero collectives — the serving fast
    path for the kNN-LM datastore and ``launch/serve.py --mesh host``."""
    return P(_dp_entry(mesh, batch_size), None)


# ---------------------------------------------------------------------------
# activation constraints (used inside model code)
# ---------------------------------------------------------------------------
def constrain(x, *parts):
    """with_sharding_constraint against the ambient mesh, with every axis
    guarded by existence and exact divisibility.  Each positional arg is the
    preference for one dim of ``x``: None, an axis name, or a tuple of axis
    names tried outermost-first.  No-op outside a ``use_mesh`` context."""
    mesh = _ambient_mesh()
    if mesh is None or not hasattr(mesh, "devices"):
        return x
    sizes = _mesh_sizes(mesh)
    entries: list = []
    for i in range(x.ndim):
        pref = parts[i] if i < len(parts) else None
        chosen: list[str] = []
        prod = 1
        for a in _axes_of(pref):
            if sizes.get(a, 1) > 1 and x.shape[i] % (prod * sizes[a]) == 0:
                chosen.append(a)
                prod *= sizes[a]
        entries.append(tuple(chosen) if len(chosen) > 1
                       else (chosen[0] if chosen else None))
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_batch_seq(x):
    """Pin [b, s, D] activations: batch over dp; sequence over 'model' when
    sequence parallelism is on (see ``set_sequence_parallel``)."""
    if x.ndim != 3:
        return constrain(x, DP_AXES)
    return constrain(x, DP_AXES, "model" if _SEQ_PARALLEL else None, None)
