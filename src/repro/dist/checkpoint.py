"""Fault-tolerant checkpointing: atomic tmp-then-rename step directories,
``keep=N`` rotation, optional async writes, and a JSON manifest carrying
(step, extras) so kill/resume is bitwise-deterministic.

Layout (one directory per step, the rename is the commit point):

    <dir>/step_00000042/
        manifest.json     step, extras, per-leaf dtype/shape table
        arrays.npz        leaves in template flatten order (arrays only —
                          object leaves are rejected before any I/O)

A crashed writer leaves only a ``.tmp-*`` directory behind, which readers
ignore — ``latest_step`` can never observe a partial checkpoint
(tests/test_checkpoint.py::test_atomic_write_never_partial).

Restore takes a *template* pytree (structure + dtypes) and an optional
shardings tree: leaves are placed straight onto their target devices, which
is what lets a checkpoint written under one mesh restore onto another
(elastic resharding, tests/_dist_worker.py::scenario_elastic_reshard).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

_STEP_PREFIX = "step_"
_TMP_PREFIX = ".tmp-"
_ARRAYS = "arrays.npz"
_MANIFEST = "manifest.json"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:08d}")


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}"


def _to_host(tree) -> list[np.ndarray]:
    """Fetch every leaf to host memory synchronously.

    Must happen before any deferred write: the caller may donate these
    buffers to the next jitted step immediately after ``save`` returns.
    Non-array leaves become object arrays, rejected here so atomicity never
    depends on how far a partial write got."""
    leaves = jax.tree_util.tree_leaves(tree)
    host = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype == object:
            raise TypeError(f"checkpoint leaf is not an array: {leaf!r}")
        host.append(arr)
    return host


def fsync_directory(path: str) -> None:
    """fsync a directory fd so a just-committed rename survives power loss.

    The tmp-then-rename commit is atomic per POSIX, but the *directory
    entry* for the renamed name only becomes durable once the parent
    directory is synced (DESIGN.md §9).  Windows has no directory fds;
    there the call is a no-op."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return   # platform without directory fds
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write(directory: str, step: int, host: list[np.ndarray],
           extra: dict | None, fsync_dir: bool = False) -> None:
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = os.path.join(directory,
                       f"{_TMP_PREFIX}{_STEP_PREFIX}{step:08d}.{os.getpid()}")
    try:
        os.makedirs(tmp, exist_ok=True)
        payload = {}
        dtypes = []
        for i, arr in enumerate(host):
            dtypes.append({"dtype": str(arr.dtype), "shape": list(arr.shape)})
            if arr.dtype.name == "bfloat16":
                arr = arr.view(np.uint16)   # npz has no native bf16
            payload[_leaf_name(i)] = arr
        # object leaves were already rejected in _to_host, so nothing here
        # can pickle; restore additionally loads with allow_pickle=False
        np.savez(os.path.join(tmp, _ARRAYS), **payload)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"step": step, "extra": extra or {},
                       "n_leaves": len(host), "leaves": dtypes}, f)
            if fsync_dir:
                f.flush()
                os.fsync(f.fileno())
        if fsync_dir:
            # file contents must hit disk before the rename that publishes
            # them, else the commit point can expose empty files after a crash
            with open(os.path.join(tmp, _ARRAYS), "rb") as f:
                os.fsync(f.fileno())
            fsync_directory(tmp)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic commit
        if fsync_dir:
            fsync_directory(directory)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def save_checkpoint(directory: str, step: int, tree,
                    extra: dict | None = None, *,
                    fsync_dir: bool = False) -> str:
    """Write ``tree`` as checkpoint ``step``; returns the committed path.

    ``fsync_dir`` adds the directory fsync after the rename commit
    (durability across power loss, at a measurable latency cost — see the
    ``ckpt_fsync_dir_ms`` row in benchmarks/BENCH_PR3.json)."""
    _write(directory, step, _to_host(tree), extra, fsync_dir)
    return _step_dir(directory, step)


def _complete_steps(directory: str) -> list[int]:
    if not directory or not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(_STEP_PREFIX):
            continue
        path = os.path.join(directory, name)
        if not os.path.exists(os.path.join(path, _MANIFEST)):
            continue
        try:
            steps.append(int(name.split("_", 1)[1]))
        except ValueError:
            continue
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    """Highest committed checkpoint step, or None."""
    steps = _complete_steps(directory)
    return steps[-1] if steps else None


def read_manifest(directory: str, step: int | None = None) -> dict:
    """Load a committed checkpoint's manifest without touching the arrays.

    The stream subsystem restores in two phases: the manifest's ``extra``
    carries the tree geometry (max_nodes, capacity, ...) needed to build
    the restore *template*, plus the WAL sequence number where tail replay
    must resume (repro.stream.pipeline)."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint found in {directory!r}")
    with open(os.path.join(_step_dir(directory, step), _MANIFEST)) as f:
        return json.load(f)


def _sharding_leaves(template, shardings) -> list[Any]:
    """Per-leaf shardings aligned with the template's flatten order.

    ``shardings`` mirrors a subset of the template's top-level keys (e.g.
    restore params sharded, optimizer state to host); missing keys restore
    unsharded."""
    n_total = len(jax.tree_util.tree_leaves(template))
    if not shardings:
        return [None] * n_total
    if not isinstance(template, dict):
        leaves = jax.tree_util.tree_leaves(shardings)
        assert len(leaves) == n_total, (len(leaves), n_total)
        return leaves
    out: list[Any] = []
    for key in sorted(template):        # jax flattens dicts in sorted order
        n = len(jax.tree_util.tree_leaves(template[key]))
        sub = shardings.get(key) if isinstance(shardings, dict) else None
        if sub is None:
            out.extend([None] * n)
        else:
            leaves = jax.tree_util.tree_leaves(sub)
            assert len(leaves) == n, (key, len(leaves), n)
            out.extend(leaves)
    return out


def restore_checkpoint(directory: str, template, *, step: int | None = None,
                       shardings=None):
    """Load a checkpoint into the structure of ``template``.

    Returns (tree, manifest).  Leaves with an entry in ``shardings`` are
    device_put straight onto their target sharding (works across mesh
    shapes); others come back as host-backed jax arrays."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint found in {directory!r}")
    path = _step_dir(directory, step)
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    if manifest["n_leaves"] != len(leaves_t):
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"template has {len(leaves_t)}")
    sh_leaves = _sharding_leaves(template, shardings)
    out = []
    with np.load(os.path.join(path, _ARRAYS), allow_pickle=False) as z:
        for i, (tmpl, sh) in enumerate(zip(leaves_t, sh_leaves)):
            arr = z[_leaf_name(i)]
            want = manifest["leaves"][i]["dtype"]
            if want != str(arr.dtype):  # bf16 stored as its uint16 bits
                arr = arr.view(np.dtype(want))
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class CheckpointManager:
    """Rotating checkpoint writer with optional async (background-thread)
    serialization.

    ``save`` always snapshots leaves to host *synchronously* — callers donate
    the device buffers to the next step — and only the file write is
    deferred.  ``wait()`` drains pending writes (call before exit)."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True, fsync_dir: bool = False):
        self.directory = directory
        self.keep = keep
        self.fsync_dir = fsync_dir
        self._lock = threading.Lock()
        self._pending: list[Future] = []
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="ckpt")
                      if async_write else None)

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        host = _to_host(tree)
        if self._pool is None:
            _write(self.directory, step, host, extra, self.fsync_dir)
            self._rotate()
            return
        with self._lock:
            # surface earlier async failures *now*, not at final wait():
            # a full disk at step 1k must not let a 100k-step run believe
            # it is checkpointed.  Also prunes completed futures.
            done = [f for f in self._pending if f.done()]
            self._pending = [f for f in self._pending if not f.done()]
            for fut in done:
                fut.result()
            self._pending.append(
                self._pool.submit(self._write_and_rotate, step, host, extra))

    def _write_and_rotate(self, step, host, extra):
        _write(self.directory, step, host, extra, self.fsync_dir)
        self._rotate()

    def _rotate(self) -> None:
        steps = _complete_steps(self.directory)
        for old in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(_step_dir(self.directory, old), ignore_errors=True)

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for fut in pending:
            fut.result()

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)
