"""Distributed substrate: GSPMD sharding policy, fault-tolerant
checkpointing, and gradient compression.

Three modules, consumed by every launch/train/serve layer:

  * ``sharding``    — pure-function partitioning rules (params, inputs,
    caches, optimizer state) for a ``{data, model}`` (optionally ``pod``)
    mesh, plus the ambient-mesh helpers (``use_mesh``, ``constrain``) the
    model code uses to pin activation layouts.
  * ``checkpoint``  — atomic tmp-then-rename checkpoints with ``keep=N``
    rotation, optional async writes, and a manifest enabling
    bitwise-deterministic kill/resume (tests/test_checkpoint.py).
  * ``compression`` — int8 quantize/mean-reduce/dequantize gradient
    all-reduce (with error feedback residual), no-op when disabled.
"""
from repro.dist import checkpoint, compression, sharding  # noqa: F401
