"""int8 gradient compression for the data-parallel all-reduce.

Wire scheme (per leaf): share one f32 scale = pmax(max|g|)/127 across the
reduction axis, quantize to int8, sum as int32 (exact — 8-bit lanes cannot
overflow a 32-bit accumulator at any realistic DP degree), dequantize once.
The *error-feedback residual* g - deq(q(g)) is returned alongside so callers
can fold it into the next step's gradient (standard EF-SGD; bounded by one
quantisation step, asserted in tests/_dist_worker.py::scenario_compressed_psum).

Two entry points:
  * ``compressed_psum_mean`` — explicit collective form for shard_map code.
  * ``compressed_mean_hook`` — GSPMD form for jitted train steps where
    autodiff already produced globally-reduced grads: quantize/dequantize
    in place (same numerics the wire format would impose), passthrough when
    compression is off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_QMAX = 127.0


def _scale_of(g: jax.Array, axis_name: str | None = None) -> jax.Array:
    amax = jnp.max(jnp.abs(g))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    return jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / _QMAX


def _quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(g / scale), -_QMAX, _QMAX).astype(jnp.int8)


def compressed_mean_hook(grads, mode: str = "int8", ef=None):
    """Quantize-dequantize every floating grad leaf (int8, shared f32 scale).

    No-op passthrough for ``mode`` in (None, 'none').  Leaf dtypes are
    preserved so the optimizer update is oblivious to compression.

    With ``ef`` (a grads-shaped tree of error-feedback residuals, or None
    for the first step), the residual is folded into the gradient *before*
    quantisation — standard EF-SGD: q(g + e_prev) — and the call returns
    ``(grads_out, ef_next)`` where ``ef_next = (g + e_prev) - deq(...)``.
    Threading ``ef_next`` back in each step makes the quantisation error a
    delayed correction instead of a bias: the running sum of dequantized
    gradients tracks the running sum of true gradients to within one
    quantisation step (tests/test_error_feedback.py), which is what
    restores convergence parity at int8.  Without ``ef`` the return is
    just ``grads_out`` (the pre-EF API, unchanged)."""
    if mode in (None, "none", False):
        return grads if ef is None else (grads, ef)

    def leaf(g, e=None):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g, e     # EF placeholder passes through untouched
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e.astype(jnp.float32)
        scale = _scale_of(gf)
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (gf - deq).astype(g.dtype)

    if ef is None:
        return jax.tree.map(lambda g: leaf(g)[0], grads)
    pairs = jax.tree.map(leaf, grads, ef)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
    out = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    ef_next = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return out, ef_next


def init_ef_state(params):
    """Zero error-feedback residuals shaped like the floating param/grad
    leaves (non-floating leaves carry a zero scalar placeholder so the
    tree structure matches)."""
    return jax.tree.map(
        lambda p: (jnp.zeros_like(p)
                   if jnp.issubdtype(p.dtype, jnp.floating)
                   else jnp.zeros((), jnp.float32)), params)


def compressed_psum_mean(tree, axis_name: str, ef=None):
    """Compressed mean all-reduce over ``axis_name`` (shard_map context).

    Returns (mean_tree, err_tree): the dequantized cross-rank mean per leaf,
    and the local error-feedback residual g - deq(q(g)).  With ``ef`` the
    previous residual is folded in before quantisation (EF-SGD), so the
    returned err_tree is the *next* EF state to thread back in."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e=None):
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e.astype(jnp.float32)
        scale = _scale_of(gf, axis_name)
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = (total.astype(jnp.float32) * scale / n).astype(g.dtype)
        err = (gf - deq).astype(g.dtype)
        return mean, err

    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
    pairs = (jax.tree.map(leaf, tree) if ef is None
             else jax.tree.map(leaf, tree, ef))
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    err = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, err
