"""int8 gradient compression for the data-parallel all-reduce.

Wire scheme (per leaf): share one f32 scale = pmax(max|g|)/127 across the
reduction axis, quantize to int8, sum as int32 (exact — 8-bit lanes cannot
overflow a 32-bit accumulator at any realistic DP degree), dequantize once.
The *error-feedback residual* g - deq(q(g)) is returned alongside so callers
can fold it into the next step's gradient (standard EF-SGD; bounded by one
quantisation step, asserted in tests/_dist_worker.py::scenario_compressed_psum).

Two entry points:
  * ``compressed_psum_mean`` — explicit collective form for shard_map code.
  * ``compressed_mean_hook`` — GSPMD form for jitted train steps where
    autodiff already produced globally-reduced grads: quantize/dequantize
    in place (same numerics the wire format would impose), passthrough when
    compression is off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_QMAX = 127.0


def _scale_of(g: jax.Array, axis_name: str | None = None) -> jax.Array:
    amax = jnp.max(jnp.abs(g))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    return jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / _QMAX


def _quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(g / scale), -_QMAX, _QMAX).astype(jnp.int8)


def compressed_mean_hook(grads, mode: str = "int8"):
    """Quantize-dequantize every floating grad leaf (int8, shared f32 scale).

    No-op passthrough for ``mode`` in (None, 'none').  Leaf dtypes are
    preserved so the optimizer update is oblivious to compression."""
    if mode in (None, "none", False):
        return grads

    def leaf(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        gf = g.astype(jnp.float32)
        scale = _scale_of(gf)
        q = _quantize(gf, scale)
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(leaf, grads)


def compressed_psum_mean(tree, axis_name: str):
    """Compressed mean all-reduce over ``axis_name`` (shard_map context).

    Returns (mean_tree, err_tree): the dequantized cross-rank mean per leaf,
    and the local error-feedback residual g - deq(q(g))."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g):
        gf = g.astype(jnp.float32)
        scale = _scale_of(gf, axis_name)
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = (total.astype(jnp.float32) * scale / n).astype(g.dtype)
        err = (gf - deq).astype(g.dtype)
        return mean, err

    pairs = jax.tree.map(leaf, tree)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    err = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, err
