"""Whisper-style encoder-decoder (audio family).

The conv frontend is a stub per the assignment: ``input_specs`` supplies
precomputed frame embeddings [b, s_enc, D] (what the two conv layers would
emit).  Encoder: bidirectional self-attention, sinusoidal positions.
Decoder: causal self-attention + cross-attention, learned positions, bounded
at ``max_target_len``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain_batch_seq
from repro.kernels import ops
from repro.kernels.attention_xla import decode_attention
from repro.models import attention as attn_mod
from repro.models.layers import (apply_norm, mlp_apply, mlp_init,
                                 norm_init, sinusoidal_positions,
                                 truncated_normal)


def _xattn_init(key, cfg, dtype):
    return attn_mod.attn_init(key, cfg, dtype)


def init_encdec(cfg: ArchConfig, rng) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    D = cfg.d_model

    def enc_block(key):
        ka, kf = jax.random.split(key)
        return {"norm1": norm_init(D, cfg.norm, dtype),
                "attn": attn_mod.attn_init(ka, cfg, dtype),
                "norm2": norm_init(D, cfg.norm, dtype),
                "ffn": mlp_init(kf, D, cfg.d_ff, dtype, gated=cfg.gated_mlp)}

    def dec_block(key):
        ka, kx, kf = jax.random.split(key, 3)
        return {"norm1": norm_init(D, cfg.norm, dtype),
                "attn": attn_mod.attn_init(ka, cfg, dtype),
                "normx": norm_init(D, cfg.norm, dtype),
                "xattn": _xattn_init(kx, cfg, dtype),
                "norm2": norm_init(D, cfg.norm, dtype),
                "ffn": mlp_init(kf, D, cfg.d_ff, dtype, gated=cfg.gated_mlp)}

    enc_keys = jax.random.split(k1, cfg.encoder_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": truncated_normal(k3, (cfg.padded_vocab, D), 1.0, dtype),
        "dec_pos": truncated_normal(k4, (cfg.max_target_len, D), 0.02, dtype),
        "enc_blocks": jax.vmap(enc_block)(enc_keys),
        "dec_blocks": jax.vmap(dec_block)(dec_keys),
        "enc_norm": norm_init(D, cfg.norm, dtype),
        "final_norm": norm_init(D, cfg.norm, dtype),
    }


def _proj_qkv(p, x_q, x_kv):
    q = jnp.einsum("bsd,dhe->bhse", x_q, p["wq"].astype(x_q.dtype))
    k = jnp.einsum("bsd,dhe->bhse", x_kv, p["wk"].astype(x_kv.dtype))
    v = jnp.einsum("bsd,dhe->bhse", x_kv, p["wv"].astype(x_kv.dtype))
    return q, k, v


def _self_attn(p, cfg, x, pos, causal, impl):
    q, k, v = _proj_qkv(p, x, x)
    out = ops.attention(q, k, v, causal=causal, impl=impl or attn_mod.ATTN_IMPL)
    return jnp.einsum("bhse,hed->bsd", out, p["wo"].astype(out.dtype))


def _cross_attn(p, cfg, x, mem, impl):
    q, k, v = _proj_qkv(p, x, mem)
    out = ops.attention(q, k, v, causal=False, impl=impl or attn_mod.ATTN_IMPL)
    return jnp.einsum("bhse,hed->bsd", out, p["wo"].astype(out.dtype))


def encode(params, cfg: ArchConfig, frames, *, attn_impl=None):
    """frames: [b, s_enc, D] stub embeddings -> encoder memory."""
    b, s, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + jnp.asarray(sinusoidal_positions(s, D), x.dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block(x, p):
        x = constrain_batch_seq(x)
        h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        x = x + _self_attn(p["attn"], cfg, h, pos, False, attn_impl)
        h = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        return x + mlp_apply(p["ffn"], h, gated=cfg.gated_mlp), None

    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


def encdec_forward(params, cfg: ArchConfig, batch, *, remat=False,
                   attn_impl=None):
    """batch: {frames [b,s_enc,D], tokens [b,s_dec]} -> (logits, aux)."""
    mem = encode(params, cfg, batch["frames"], attn_impl=attn_impl)
    tok = batch["tokens"]
    b, s = tok.shape
    x = params["embed"][tok].astype(mem.dtype)
    x = x + params["dec_pos"][:s].astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block(x, p):
        x = constrain_batch_seq(x)
        h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        x = x + _self_attn(p["attn"], cfg, h, pos, True, attn_impl)
        h = apply_norm(p["normx"], x, cfg.norm, cfg.norm_eps)
        x = x + _cross_attn(p["xattn"], cfg, h, mem, attn_impl)
        h = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        return x + mlp_apply(p["ffn"], h, gated=cfg.gated_mlp), None

    f = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(f, x, params["dec_blocks"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, {"lb_loss": 0.0, "z_loss": 0.0, "drop_frac": 0.0}


# ---- cached decode ----------------------------------------------------------
def encdec_init_cache(cfg: ArchConfig, batch: int, enc_len: int, dtype=None):
    """Self-attn KV cache (bounded by max_target_len) + cross-attn K/V
    (computed once from the encoder memory at prefill)."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    KV, dh = cfg.padded_kv_heads, cfg.d_head
    L = cfg.n_layers
    S = cfg.max_target_len
    return {
        "self_k": jnp.zeros((L, batch, KV, S, dh), dtype),
        "self_v": jnp.zeros((L, batch, KV, S, dh), dtype),
        "cross_k": jnp.zeros((L, batch, KV, enc_len, dh), dtype),
        "cross_v": jnp.zeros((L, batch, KV, enc_len, dh), dtype),
    }


def encdec_prefill_cache(params, cfg, frames, cache, *, attn_impl=None):
    """Run the encoder and fill the cross-attention K/V."""
    mem = encode(params, cfg, frames, attn_impl=attn_impl)
    b, sm, _ = mem.shape
    KV, dh = cfg.n_kv_heads, cfg.d_head

    def per_layer(p):
        k = jnp.einsum("bsd,dhe->bhse", mem, p["xattn"]["wk"].astype(mem.dtype))
        v = jnp.einsum("bsd,dhe->bhse", mem, p["xattn"]["wv"].astype(mem.dtype))
        return k, v

    ks, vs = jax.vmap(per_layer)(params["dec_blocks"])
    return dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                cross_v=vs.astype(cache["cross_v"].dtype))


def encdec_decode_step(params, cfg: ArchConfig, token, cache, pos_scalar):
    b = token.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = params["embed"][token][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["dec_pos"][pos_scalar][None, None].astype(x.dtype)

    def block(x1, xs):
        p, sk, sv, ck, cv = xs
        h = apply_norm(p["norm1"], x1, cfg.norm, cfg.norm_eps)
        q, k1, v1 = _proj_qkv(p["attn"], h, h)
        S = sk.shape[2]
        hit = (jnp.arange(S, dtype=jnp.int32) == pos_scalar)[None, None, :, None]
        sk = jnp.where(hit, k1.astype(sk.dtype), sk)
        sv = jnp.where(hit, v1.astype(sv.dtype), sv)
        kv_len = jnp.full((b,), pos_scalar + 1, jnp.int32)
        o = decode_attention(q, sk.astype(q.dtype), sv.astype(q.dtype),
                             kv_len=kv_len)
        x1 = x1 + jnp.einsum("bhse,hed->bsd", o,
                             p["attn"]["wo"].astype(o.dtype))
        h = apply_norm(p["normx"], x1, cfg.norm, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhe->bhse", h, p["xattn"]["wq"].astype(h.dtype))
        ox = decode_attention(qx, ck.astype(qx.dtype), cv.astype(qx.dtype))
        x1 = x1 + jnp.einsum("bhse,hed->bsd", ox,
                             p["xattn"]["wo"].astype(ox.dtype))
        h = apply_norm(p["norm2"], x1, cfg.norm, cfg.norm_eps)
        x1 = x1 + mlp_apply(p["ffn"], h, gated=cfg.gated_mlp)
        return x1, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        block, x, (params["dec_blocks"], cache["self_k"], cache["self_v"],
                   cache["cross_k"], cache["cross_v"]))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits[:, 0], dict(cache, self_k=sk, self_v=sv)
