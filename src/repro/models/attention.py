"""GQA attention block: train/prefill (flash path) + cached decode.

Sharding-first design decisions (see DESIGN.md §6):
  * Projection weights are HEAD-SHAPED ([D, H, dh] / [H, dh, D]) and sharded
    on the head axis — never flat [D, H*dh] + reshape, which fights GSPMD
    when H doesn't divide the model axis (yi-34b 56H, starcoder2 24H, ...).
    Uneven head counts just pad.
  * K/V weights and activations are REPLICATED across 'model' (kv heads are
    2..32 — the projection is tiny) and broadcast to query heads via
    jnp.repeat, which is free on the sharded head axis.  This keeps the
    attention einsums collective-free under TP.
  * Decode uses the safe-softmax formulation whose (m, l, acc) statistics
    combine across sequence-sharded KV caches (long-context decode).

``ATTN_IMPL``: 'pallas' on TPU, 'xla' (chunked scan flash) for CPU
lowering/dry-run, 'xla_naive' for tiny test shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.attention_xla import decode_attention
from repro.models.layers import apply_rope, truncated_normal

ATTN_IMPL = "xla"  # module-level default; launchers override


def attn_init(key, cfg, dtype):
    D, dh = cfg.d_model, cfg.d_head
    H, KV = cfg.padded_heads, cfg.padded_kv_heads
    ks = jax.random.split(key, 4)
    scale = D ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (D, H, dh), scale, dtype),
        "wk": truncated_normal(ks[1], (D, KV, dh), scale, dtype),
        "wv": truncated_normal(ks[2], (D, KV, dh), scale, dtype),
        "wo": truncated_normal(ks[3], (H, dh, D), (H * dh) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dh), dtype)
        p["bk"] = jnp.zeros((KV, dh), dtype)
        p["bv"] = jnp.zeros((KV, dh), dtype)
    return p


def _head_mask(cfg, out):
    """Zero the padded q-heads (axis 1 of [b, H, s, dh]) so padding is
    exactly inert (no gradient ever reaches pad-head parameters)."""
    Hp = cfg.padded_heads
    if Hp == cfg.n_heads:
        return out
    mask = (jnp.arange(Hp) < cfg.n_heads).astype(out.dtype)
    return out * mask[None, :, None, None]


def _project_qkv(p, cfg, x, pos):
    """x: [b, s, D] -> q [b, H, s, dh], k/v [b, KV, s, dh]."""
    q = jnp.einsum("bsd,dhe->bhse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bhse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bhse", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
        k = k + p["bk"].astype(x.dtype)[None, :, None, :]
        v = v + p["bv"].astype(x.dtype)[None, :, None, :]
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, pos[:, None, :], cfg.rope_theta)
        k = apply_rope(k, pos[:, None, :], cfg.rope_theta)
    return q, k, v


def _out_proj(p, cfg, out):
    """out: [b, H, s, dh] -> [b, s, D]."""
    return jnp.einsum("bhse,hed->bsd", out, p["wo"].astype(out.dtype))


def attn_apply(p, cfg, x, *, pos, impl=None):
    """Full-sequence causal attention.  x: [b, s, D]; pos: [b, s]."""
    q, k, v = _project_qkv(p, cfg, x, pos)
    g = cfg.padded_heads // cfg.padded_kv_heads
    if g > 1:  # broadcast KV to query heads (free on the sharded head axis)
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    out = ops.attention(q, k, v, causal=True, impl=impl or ATTN_IMPL)
    return _out_proj(p, cfg, _head_mask(cfg, out))


def attn_decode(p, cfg, x1, cache_kv, pos_scalar):
    """Single-token decode.  x1: [b, 1, D]; cache_kv: (k, v) [b, KV, S, dh];
    pos_scalar: [] position of the new token.  Returns (y1, new_cache).

    The cache insert is a masked (elementwise) write: it partitions with no
    collectives whether S is sharded over 'model' (decode_32k) or
    ('data','model') (long_500k) — a dynamic_update_slice at a dynamic index
    on a sharded axis would regather the cache.  decode_attention handles
    GQA by folding q (tiny at decode) rather than repeating K/V (which would
    multiply cache reads by the group size)."""
    b = x1.shape[0]
    pos = jnp.full((b, 1), pos_scalar, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x1, pos)
    ck, cv = cache_kv
    S = ck.shape[2]
    hit = (jnp.arange(S, dtype=jnp.int32) == pos_scalar)[None, None, :, None]
    ck = jnp.where(hit, k.astype(ck.dtype), ck)
    cv = jnp.where(hit, v.astype(cv.dtype), cv)
    kv_len = jnp.full((b,), pos_scalar + 1, jnp.int32)
    out = decode_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                           kv_len=kv_len)
    return _out_proj(p, cfg, _head_mask(cfg, out)), (ck, cv)


def init_kv_cache(cfg, batch: int, length: int, dtype) -> tuple:
    KV, dh = cfg.padded_kv_heads, cfg.d_head
    shape = (batch, KV, length, dh)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
