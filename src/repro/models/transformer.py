"""Unified decoder-LM assembly for all block families.

The layer stack is a `lax.scan` over ``n_periods`` stacked copies of the
config's ``block_pattern`` (one period = one pytree level, e.g. jamba's
(mamba, mamba_moe, ..., attn, ...) 8-layer period).  Scanning keeps HLO size
and compile time flat in depth — essential for the 40-cell dry-run — and the
period is the remat (activation-checkpoint) unit.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain, constrain_batch_seq
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import apply_norm, dense, dense_init, mlp_apply, \
    mlp_init, norm_init, truncated_normal


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(kind: str, key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": norm_init(cfg.d_model, cfg.norm, dtype)}
    if kind in ("attn", "attn_moe"):
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    elif kind in ("mamba", "mamba_moe"):
        p["mamba"] = ssm_mod.mamba_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = xlstm_mod.mlstm_init(ks[0], cfg, dtype)
        return p                      # xLSTM blocks carry no separate FFN
    elif kind == "slstm":
        p["slstm"] = xlstm_mod.slstm_init(ks[0], cfg, dtype)
        return p
    else:
        raise ValueError(kind)
    p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if kind.endswith("_moe"):
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp)
    return p


def init_lm(cfg: ArchConfig, rng) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    params: dict[str, Any] = {
        "embed": truncated_normal(k_embed, (cfg.padded_vocab, cfg.d_model),
                                  1.0, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.padded_vocab,
                                       dtype)
    blocks = []
    keys = jax.random.split(k_blocks, len(cfg.block_pattern))
    for j, kind in enumerate(cfg.block_pattern):
        pkeys = jax.random.split(keys[j], cfg.n_periods)
        blocks.append(jax.vmap(
            lambda k: _block_init(kind, k, cfg, dtype))(pkeys))
    params["blocks"] = blocks
    if cfg.pos_embedding == "learned":
        params["pos_embed"] = truncated_normal(
            k_head, (cfg.max_target_len, cfg.d_model), 0.02, dtype)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _block_apply(kind, p, cfg, x, pos, attn_impl):
    aux = None
    h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    if kind in ("attn", "attn_moe"):
        x = x + attn_mod.attn_apply(p["attn"], cfg, h, pos=pos, impl=attn_impl)
    elif kind in ("mamba", "mamba_moe"):
        x = x + ssm_mod.mamba_apply(p["mamba"], cfg, h)
    elif kind == "mlstm":
        return x + xlstm_mod.mlstm_apply(p["mlstm"], cfg, h), aux
    elif kind == "slstm":
        return x + xlstm_mod.slstm_apply(p["slstm"], cfg, h), aux
    h = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
    if kind.endswith("_moe"):
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        x = x + y
    elif cfg.d_ff:
        x = x + mlp_apply(p["ffn"], h, gated=cfg.gated_mlp)
    return x, aux


def embed_inputs(params, cfg: ArchConfig, batch: dict):
    """Token (+ stub-frontend) embedding.  Returns (x [b,s,D], pos [b,s])."""
    emb = params["embed"]
    x = emb[batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))
    if cfg.frontend == "vision_stub":
        img = batch["image_embeds"].astype(x.dtype)     # [b, n_img, D]
        x = jnp.concatenate([img, x], axis=1)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][:s].astype(x.dtype)
    return x, pos


def lm_forward(params, cfg: ArchConfig, batch: dict, *, remat: bool = False,
               attn_impl: str | None = None):
    """Full-sequence forward.  Returns (logits [b,s,V], aux dict)."""
    x, pos = embed_inputs(params, cfg, batch)
    x = constrain_batch_seq(x)   # pin DP before the layer scan (GSPMD would
                                 # otherwise happily replicate the batch)

    def period_fn(x, period_params):
        aux_sums = jnp.zeros((3,), jnp.float32)
        x = constrain_batch_seq(x)
        for j, kind in enumerate(cfg.block_pattern):
            x, aux = _block_apply(kind, period_params[j], cfg, x, pos,
                                  attn_impl)
            x = constrain_batch_seq(x)
            if aux is not None:
                aux_sums = aux_sums + jnp.stack(
                    [aux["lb_loss"], aux["z_loss"], aux["drop_frac"]])
        return x, aux_sums

    f = jax.checkpoint(period_fn) if remat else period_fn
    x, aux_sums = jax.lax.scan(lambda c, p: f(c, p), x, params["blocks"])
    aux_sums = aux_sums.sum(0)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = dense(params["lm_head"], x)
    logits = constrain(logits, ("pod", "data"), None, "model")
    n_moe = sum(1 for k in cfg.block_pattern if k.endswith("_moe"))
    denom = max(1, n_moe * cfg.n_periods)
    aux = {"lb_loss": aux_sums[0] / denom, "z_loss": aux_sums[1] / denom,
           "drop_frac": aux_sums[2] / denom}
    return logits, aux


# ---------------------------------------------------------------------------
# decode (single token, cached)
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, length: int, dtype=None) -> list:
    """Cache pytree: one entry per in-period block, leaves stacked
    [n_periods, ...]."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)

    def stack(make):
        leaves = [make() for _ in range(cfg.n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    cache = []
    for kind in cfg.block_pattern:
        if kind in ("attn", "attn_moe"):
            cache.append(stack(lambda: {"kv": attn_mod.init_kv_cache(
                cfg, batch, length, dtype)}))
        elif kind in ("mamba", "mamba_moe"):
            cache.append(stack(lambda: ssm_mod.mamba_init_cache(
                cfg, batch, dtype)))
        elif kind == "mlstm":
            cache.append(stack(lambda: xlstm_mod.mlstm_init_cache(
                cfg, batch, dtype)))
        elif kind == "slstm":
            cache.append(stack(lambda: xlstm_mod.slstm_init_cache(
                cfg, batch, dtype)))
    return cache


def _block_decode(kind, p, cfg, x1, cslice, pos_scalar):
    h = apply_norm(p["norm1"], x1, cfg.norm, cfg.norm_eps)
    if kind in ("attn", "attn_moe"):
        y, kv = attn_mod.attn_decode(p["attn"], cfg, h, cslice["kv"], pos_scalar)
        x1 = x1 + y
        new_c = {"kv": kv}
    elif kind in ("mamba", "mamba_moe"):
        y, new_c = ssm_mod.mamba_decode(p["mamba"], cfg, h, cslice)
        x1 = x1 + y
    elif kind == "mlstm":
        y, new_c = xlstm_mod.mlstm_decode(p["mlstm"], cfg, h, cslice)
        return x1 + y, new_c
    elif kind == "slstm":
        y, new_c = xlstm_mod.slstm_decode(p["slstm"], cfg, h, cslice)
        return x1 + y, new_c
    h = apply_norm(p["norm2"], x1, cfg.norm, cfg.norm_eps)
    if kind.endswith("_moe"):
        # dropless at decode: worst case every token routes to one expert
        y, _ = moe_mod.moe_apply(p["moe"], cfg, h, capacity=x1.shape[0])
        x1 = x1 + y
    elif cfg.d_ff:
        x1 = x1 + mlp_apply(p["ffn"], h, gated=cfg.gated_mlp)
    return x1, new_c


def lm_decode_step(params, cfg: ArchConfig, token, cache, pos_scalar):
    """token: [b] int32; pos_scalar: [] int32.  Returns (logits [b,V], cache)."""
    x = params["embed"][token][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][pos_scalar][None, None].astype(x.dtype)

    def period_fn(x1, xs):
        period_params, cslices = xs
        x1 = constrain_batch_seq(x1)
        new_slices = []
        for j, kind in enumerate(cfg.block_pattern):
            x1, nc = _block_decode(kind, period_params[j], cfg, x1,
                                   cslices[j], pos_scalar)
            x1 = constrain_batch_seq(x1)
            new_slices.append(nc)
        return x1, new_slices

    x, new_cache = jax.lax.scan(period_fn, x, (params["blocks"], cache))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = dense(params["lm_head"], x)
    return logits[:, 0], new_cache
