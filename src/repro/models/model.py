"""Unified model API: init / forward / decode, per-family dispatch, and
``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run — weak-type
correct, shardable, never allocated).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import encdec, transformer


def init_params(cfg: ArchConfig, rng) -> dict:
    if cfg.is_encdec:
        return encdec.init_encdec(cfg, rng)
    return transformer.init_lm(cfg, rng)


def forward(params, cfg: ArchConfig, batch: dict, *, remat: bool = False,
            attn_impl: str | None = None):
    """Full-sequence forward -> (logits, aux)."""
    if cfg.is_encdec:
        return encdec.encdec_forward(params, cfg, batch, remat=remat,
                                     attn_impl=attn_impl)
    return transformer.lm_forward(params, cfg, batch, remat=remat,
                                  attn_impl=attn_impl)


def init_cache(cfg: ArchConfig, batch: int, length: int, dtype=None):
    if cfg.is_encdec:
        return encdec.encdec_init_cache(cfg, batch, enc_len=length, dtype=dtype)
    return transformer.init_cache(cfg, batch, length, dtype=dtype)


def decode_step(params, cfg: ArchConfig, token, cache, pos_scalar):
    """One-token decode with cache -> (logits [b, V], new cache)."""
    if cfg.is_encdec:
        return encdec.encdec_decode_step(params, cfg, token, cache, pos_scalar)
    return transformer.lm_decode_step(params, cfg, token, cache, pos_scalar)


def loss_fn(logits, labels, mask):
    """Mean next-token cross-entropy (labels already shifted).  float32.

    The gold logit is selected with a masked reduction rather than
    take_along_axis: a dynamic gather along the vocab axis would force GSPMD
    to all-gather the (vocab-sharded) logits — the masked sum partitions to
    a cheap [b, s] psum instead."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    V = logits.shape[-1]
    hit = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1) \
        == labels[..., None]
    gold = jnp.sum(jnp.where(hit, lf, 0.0), axis=-1)
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------
def _whisper_lens(cfg: ArchConfig, shape: ShapeSpec) -> tuple[int, int]:
    """Map the LM (seq_len, batch) cell onto enc/dec lengths: encoder takes
    seq_len frames; decoder is bounded by Whisper's 448-position window."""
    enc = shape.seq_len
    dec = min(cfg.max_target_len, max(8, shape.seq_len // 8))
    return enc, dec


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if cfg.is_encdec:
        enc, dec = _whisper_lens(cfg, shape)
        if shape.kind == "train":
            return {"frames": sds((B, enc, cfg.d_model), f32),
                    "tokens": sds((B, dec), i32),
                    "labels": sds((B, dec), i32)}
        if shape.kind == "prefill":
            return {"frames": sds((B, enc, cfg.d_model), f32),
                    "tokens": sds((B, dec), i32)}
        return {"token": sds((B,), i32)}       # decode

    if cfg.frontend == "vision_stub":
        n_img = cfg.n_image_tokens
        s_txt = max(1, S - n_img)
        if shape.kind == "train":
            return {"tokens": sds((B, s_txt), i32),
                    "image_embeds": sds((B, n_img, cfg.d_model), f32),
                    "labels": sds((B, s_txt + n_img), i32)}
        if shape.kind == "prefill":
            return {"tokens": sds((B, s_txt), i32),
                    "image_embeds": sds((B, n_img, cfg.d_model), f32)}
        return {"token": sds((B,), i32)}

    if shape.kind == "train":
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), i32)}
    return {"token": sds((B,), i32)}           # decode: 1 new token


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the decode cache of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        enc, _ = _whisper_lens(cfg, shape)
        fn = lambda: init_cache(cfg, B, enc)
    else:
        fn = lambda: init_cache(cfg, B, S)
    return jax.eval_shape(fn)


def param_specs(cfg: ArchConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocation."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(seed)))


def exact_param_count(cfg: ArchConfig) -> int:
    import numpy as np
    specs = param_specs(cfg)
    # np.prod with int64: leaf shapes like [4, 16, 4096, 14336] overflow int32
    return sum(int(np.prod(l.shape, dtype=np.int64))
               for l in jax.tree.leaves(specs))
