"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory with hidden-state recurrence, sequential scan).

mLSTM uses exponential input gating with the max-stabiliser m_t:
    m_t = max(m_{t-1} + logsig(f̃_t), ĩ_t)
    C_t = f'_t C_{t-1} + i'_t k_t v_t^T,  n_t = f'_t n_{t-1} + i'_t k_t
    h_t = o_t ⊙ (C_t^T q_t) / max(|n_t·q_t|, exp(-m_t))
with f'_t = exp(f̃ + m_{t-1} − m_t), i'_t = exp(ĩ − m_t).  The stabiliser
recurrence is a max-plus scan — associative — so it runs as one
`associative_scan` over the full sequence; the matrix recurrence has scalar
per-(batch, head, step) coefficients, so it parallelises *chunkwise* with
log-space intra-chunk decays (the TPU-friendly form: two MXU einsums per
chunk instead of a length-S recurrence).

sLSTM's gates depend on h_{t-1} (true nonlinear recurrence — not scannable);
it runs as a `lax.scan` over time, as in the paper (1 of 8 blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import apply_norm, dense, dense_init, norm_init, \
    truncated_normal

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================
def mlstm_init(key, cfg, dtype):
    D = cfg.d_model
    dx = int(cfg.xlstm_proj_factor * D)
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], D, 2 * dx, dtype),
        "conv_w": truncated_normal(ks[1], (4, dx), 0.5, dtype),
        "conv_b": jnp.zeros((dx,), dtype),
        "wq": dense_init(ks[2], dx, dx, dtype),
        "wk": dense_init(ks[3], dx, dx, dtype),
        # v and o consume the up-projection LINEARLY, so they are fused into
        # direct [D, dx] projections of the (model-replicated) block input:
        # same function class, fewer FLOPs (D < dx), and it removes the
        # all-gather of the dx-sharded up activation that column-parallel
        # wv/w_o would otherwise force (measured in EXPERIMENTS.md §Perf)
        "wv": dense_init(ks[4], D, dx, dtype),
        "w_if": truncated_normal(ks[5], (dx, 2 * H), dx ** -0.5, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "w_o": dense_init(ks[6], D, dx, dtype),
        "outnorm": norm_init(dx, "rmsnorm", jnp.float32),
        "down": dense_init(ks[7], dx, D, dtype),
    }


def _mlstm_gates(p, xc, H):
    """xc: [b, s, dx] -> (log_f, log_i) each [b, s, H] float32."""
    g = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i, f_pre = jnp.split(g, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    return log_f, log_i


def _stabiliser(log_f, log_i, m0):
    """m_t = max(m_{t-1} + log_f_t, log_i_t) via max-plus associative scan.
    log_f/log_i: [b, s, H]; m0: [b, H].  Returns m: [b, s, H]."""
    def comb(l, r):
        a1, b1 = l
        a2, b2 = r
        return (a1 + a2, jnp.maximum(b1 + a2, b2))
    A, B = jax.lax.associative_scan(comb, (log_f, log_i), axis=1)
    return jnp.maximum(m0[:, None] + A, B)


def mlstm_cell(q, k, v, log_f, log_i, state, *, chunk: int = 256):
    """Chunkwise-parallel mLSTM cell.

    q,k,v: [b, H, s, dh]; log_f, log_i: [b, s, H];
    state: (C [b,H,dh,dh], n [b,H,dh], m [b,H]).
    Returns (h [b,H,s,dh], new_state)."""
    b, H, s, dh = q.shape
    C0, n0, m0 = state
    # keep q/k/v in their (bf16) wire dtype: the row-parallel psum then moves
    # half the bytes; all contractions below accumulate in f32 via
    # preferred_element_type
    qf = q * jnp.asarray(dh ** -0.5, q.dtype)
    kf = k
    vf = v

    m = _stabiliser(log_f, log_i, m0)                     # [b, s, H]
    m_prev = jnp.concatenate([m0[:, None], m[:, :-1]], axis=1)
    log_fp = log_f + m_prev - m                           # <= 0
    log_ip = log_i - m

    L = min(chunk, s)
    n_chunks = -(-s // L)
    sp = n_chunks * L
    if sp != s:  # pad with identity steps (log_f'=0 -> but must keep m const)
        padw = ((0, 0), (0, sp - s), (0, 0))
        log_fp = jnp.pad(log_fp, padw)
        log_ip = jnp.pad(log_ip, padw, constant_values=NEG)
        m = jnp.pad(m, padw, mode="edge")
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, sp - s), (0, 0)))

    def reshape_sc(a):  # [b, sp, H] -> [n_chunks, b, H, L]
        return a.reshape(b, n_chunks, L, H).transpose(1, 0, 3, 2)

    def reshape_qkv(a):  # [b, H, sp, dh] -> [n_chunks, b, H, L, dh]
        return a.reshape(b, H, n_chunks, L, dh).transpose(2, 0, 1, 3, 4)

    lf_c, li_c, m_c = map(reshape_sc, (log_fp, log_ip, m))
    q_c, k_c, v_c = map(reshape_qkv, (qf, kf, vf))

    def step(carry, blk):
        C, n = carry
        lf, li, mm, qq, kk, vv = blk   # [b,H,L], [b,H,L,dh]
        f32 = jnp.float32
        G = jnp.cumsum(lf, axis=-1)                        # [b,H,L]
        # inter-chunk: h_inter_t = exp(G_t) * (q_t @ C_in)
        inter = jnp.einsum("bhld,bhde->bhle", qq, C.astype(qq.dtype),
                           preferred_element_type=f32) \
            * jnp.exp(G)[..., None]
        n_inter = jnp.einsum("bhld,bhd->bhl", qq, n.astype(qq.dtype),
                             preferred_element_type=f32) * jnp.exp(G)
        # intra-chunk: decay(τ->t) = exp(G_t − G_τ + li_τ), τ <= t
        dec = G[:, :, :, None] - G[:, :, None, :] + li[:, :, None, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(causal, dec, NEG)
        w = jnp.exp(dec)                                   # [b,H,L,L]
        scores = jnp.einsum("bhld,bhkd->bhlk", qq, kk,
                            preferred_element_type=f32) * w
        intra = jnp.einsum("bhlk,bhkd->bhld", scores, vv.astype(f32))
        n_intra = jnp.einsum("bhlk,bhkd->bhld", w, kk.astype(f32))
        n_t = jnp.einsum("bhld,bhld->bhl", qq, n_intra.astype(qq.dtype),
                         preferred_element_type=f32) + n_inter
        h_num = inter + intra
        denom = jnp.maximum(jnp.abs(n_t), jnp.exp(-mm))[..., None]
        h = h_num / denom
        # chunk-final state
        gl = G[:, :, -1]
        wC = jnp.exp(gl[..., None] - G + li)               # [b,H,L]
        C = C * jnp.exp(gl)[..., None, None] + jnp.einsum(
            "bhl,bhld,bhle->bhde", wC, kk.astype(f32), vf_ := vv.astype(f32))
        n = n * jnp.exp(gl)[..., None] + jnp.einsum(
            "bhl,bhld->bhd", wC, kk.astype(f32))
        return (C, n), h

    (C, n), hs = jax.lax.scan(step, (C0, n0), (lf_c, li_c, m_c, q_c, k_c, v_c))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, H, sp, dh)[:, :, :s]
    m_last = m[:, s - 1] if sp == s else m[:, s - 1]
    return h, (C, n, m_last)


def mlstm_apply(p, cfg, x, *, chunk: int = 256):
    """Full mLSTM block.  x: [b, s, D] -> [b, s, D]."""
    b, s, D = x.shape
    H = cfg.n_heads
    dx = int(cfg.xlstm_proj_factor * D)
    dh = dx // H
    up = dense(p["up"], x)
    xm, z = jnp.split(up, 2, axis=-1)                     # [b, s, dx]
    # causal conv(4) + silu on the mLSTM branch
    pad = jnp.pad(xm, ((0, 0), (3, 0), (0, 0)))
    xc = sum(pad[:, i:i + s] * p["conv_w"][i].astype(x.dtype)
             for i in range(4)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    q = dense(p["wq"], xc).reshape(b, s, H, dh).transpose(0, 2, 1, 3)
    k = dense(p["wk"], xc).reshape(b, s, H, dh).transpose(0, 2, 1, 3)
    v = dense(p["wv"], x).reshape(b, s, H, dh).transpose(0, 2, 1, 3)
    log_f, log_i = _mlstm_gates(p, xc, H)
    C0 = jnp.zeros((b, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, H, dh), jnp.float32)
    m0 = jnp.zeros((b, H), jnp.float32)
    h, _ = mlstm_cell(q, k, v, log_f, log_i, (C0, n0, m0), chunk=chunk)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, dx)
    o = jax.nn.sigmoid(dense(p["w_o"], x).astype(jnp.float32))
    h = apply_norm(p["outnorm"], h.astype(jnp.float32), "rmsnorm", 1e-5)
    h = (h * o).astype(x.dtype) * jax.nn.silu(z)
    return dense(p["down"], h)


def mlstm_init_cache(cfg, batch, dtype):
    D = cfg.d_model
    dx = int(cfg.xlstm_proj_factor * D)
    H = cfg.n_heads
    dh = dx // H
    return {
        "conv": jnp.zeros((batch, 3, dx), dtype),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_decode(p, cfg, x1, cache):
    b = x1.shape[0]
    D = cfg.d_model
    H = cfg.n_heads
    dx = int(cfg.xlstm_proj_factor * D)
    dh = dx // H
    up = dense(p["up"], x1)
    xm, z = jnp.split(up, 2, axis=-1)                     # [b, 1, dx]
    window = jnp.concatenate([cache["conv"], xm], axis=1)  # [b, 4, dx]
    xc = (window * p["conv_w"].astype(x1.dtype)[None]).sum(1, keepdims=True) \
        + p["conv_b"].astype(x1.dtype)
    xc = jax.nn.silu(xc)
    q = dense(p["wq"], xc).reshape(b, H, dh) * dh ** -0.5
    k = dense(p["wk"], xc).reshape(b, H, dh)
    v = dense(p["wv"], x1[:, 0]).reshape(b, H, dh)
    log_f, log_i = _mlstm_gates(p, xc, H)                  # [b, 1, H]
    log_f, log_i = log_f[:, 0], log_i[:, 0]
    m_new = jnp.maximum(cache["m"] + log_f, log_i)
    fp = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    ip = jnp.exp(log_i - m_new)[..., None]
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    C = cache["C"] * fp[..., None] + ip[..., None] * kf[..., :, None] * vf[..., None, :]
    n = cache["n"] * fp + ip * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(b, 1, dx)
    o = jax.nn.sigmoid(dense(p["w_o"], x1).astype(jnp.float32))[:, 0][:, None]
    h = apply_norm(p["outnorm"], h, "rmsnorm", 1e-5)
    h = (h * o).astype(x1.dtype) * jax.nn.silu(z)
    out = dense(p["down"], h)
    return out, {"conv": window[:, 1:], "C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM
# ===========================================================================
def slstm_init(key, cfg, dtype):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    f_up = -(-int(4 * D / 3) // 128) * 128   # lane/TP aligned
    ks = jax.random.split(key, 5)
    return {
        "w": truncated_normal(ks[0], (D, 4 * D), D ** -0.5, dtype),
        "b": jnp.concatenate([jnp.zeros((D,)), jnp.zeros((D,)),
                              3.0 * jnp.ones((D,)), jnp.zeros((D,))]
                             ).astype(jnp.float32),
        "r": truncated_normal(ks[1], (H, dh, 4 * dh), dh ** -0.5, jnp.float32),
        "gnorm": norm_init(D, "rmsnorm", jnp.float32),
        "up": dense_init(ks[2], D, 2 * f_up, dtype),
        "down": dense_init(ks[3], f_up, D, dtype),
    }


def _slstm_scan(p, cfg, wx, state):
    """wx: [b, s, 4D] input projections; state: (c, n, h, m) each [b, D].
    Returns (h_seq [b, s, D], new_state).  Sequential over s."""
    b, s, _ = wx.shape
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H

    dp = ("pod", "data")

    def pin(a):   # keep the recurrence replicated over 'model': a per-step
        return constrain(a, dp, None)   # model collective would dominate

    def step(carry, wx_t):
        c, n, h, m = carry
        rh = jnp.einsum("bhd,hde->bhe", h.reshape(b, H, dh),
                        p["r"]).reshape(b, 4 * D)
        pre = wx_t.astype(jnp.float32) + rh + p["b"]
        z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        log_f = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        ip = jnp.exp(i_pre - m_new)
        fp = jnp.exp(log_f + m - m_new)
        c = pin(fp * c + ip * z)
        n = pin(fp * n + ip)
        h = pin(o * (c / jnp.maximum(n, 1e-6)))
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), (c, n, h, m)


def slstm_apply(p, cfg, x):
    b, s, D = x.shape
    wx = x @ p["w"].astype(x.dtype)
    state = tuple(jnp.zeros((b, D), jnp.float32) for _ in range(4))
    h, _ = _slstm_scan(p, cfg, wx, state)
    h = apply_norm(p["gnorm"], h, "rmsnorm", 1e-5).astype(x.dtype)
    up = dense(p["up"], h)
    a, g = jnp.split(up, 2, axis=-1)
    return dense(p["down"], jax.nn.gelu(a) * g)


def slstm_init_cache(cfg, batch, dtype):
    D = cfg.d_model
    return {k: jnp.zeros((batch, D), jnp.float32) for k in "cnhm"}


def slstm_decode(p, cfg, x1, cache):
    b = x1.shape[0]
    wx = x1 @ p["w"].astype(x1.dtype)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    h, (c, n, hh, m) = _slstm_scan(p, cfg, wx, state)
    h = apply_norm(p["gnorm"], h, "rmsnorm", 1e-5).astype(x1.dtype)
    up = dense(p["up"], h)
    a, g = jnp.split(up, 2, axis=-1)
    out = dense(p["down"], jax.nn.gelu(a) * g)
    return out, {"c": c, "n": n, "h": hh, "m": m}
