"""Mamba (selective SSM) block — jamba's sequence mixer.

Train path: chunked selective scan — `lax.scan` over sequence chunks with an
`associative_scan` inside each chunk, so the [B, L, d_inner, state] working
set is bounded by the chunk length (the TPU analogue of the fused CUDA
selective-scan: bound the h-materialisation window, keep it in fast memory).
Decode path: single-step recurrence, O(1) per token — this is what makes
jamba's long_500k cell run.

Layout: d_inner is the sharded axis (TP over 'model'); the scan is
elementwise over d_inner so it needs no cross-shard communication.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, truncated_normal


def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_init(key, cfg, dtype):
    D = cfg.d_model
    di = cfg.ssm_expand * D
    n = cfg.ssm_state
    kc = cfg.ssm_conv
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dtype),
        "conv_w": truncated_normal(ks[1], (kc, di), kc ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], r, di, dtype, bias=True),
        "A_log": jnp.log(A),                      # [di, n] f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, D, dtype),
    }


def _ssm_params(p, cfg, xc):
    """xc: [..., di] post-conv activations -> (dt, B, C) selective params."""
    n = cfg.ssm_state
    r = _dt_rank(cfg)
    dbc = dense(p["x_proj"], xc)
    dt, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt).astype(jnp.float32))  # [..., di]
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_apply(p, cfg, x, *, chunk: int = 256):
    """x: [b, s, D] -> [b, s, D] (causal)."""
    b, s, D = x.shape
    di = cfg.ssm_expand * D
    n = cfg.ssm_state
    kc = cfg.ssm_conv

    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                  # [b, s, di]

    # causal depthwise conv along s
    pad = jnp.pad(xi, ((0, 0), (kc - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + s, :] * p["conv_w"][i].astype(x.dtype)
             for i in range(kc)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_params(p, cfg, xc)               # [b,s,di],[b,s,n],[b,s,n]
    A = -jnp.exp(p["A_log"])                           # [di, n]
    xcf = xc.astype(jnp.float32)

    L = min(chunk, s)
    n_chunks = -(-s // L)
    sp = n_chunks * L

    def padc(a):
        return jnp.pad(a, ((0, 0), (0, sp - s)) + ((0, 0),) * (a.ndim - 2))

    dtc = padc(dt).reshape(b, n_chunks, L, di).transpose(1, 0, 2, 3)
    Bc = padc(Bm).reshape(b, n_chunks, L, n).transpose(1, 0, 2, 3)
    Cc = padc(Cm).reshape(b, n_chunks, L, n).transpose(1, 0, 2, 3)
    xcc = padc(xcf).reshape(b, n_chunks, L, di).transpose(1, 0, 2, 3)

    def chunk_step(h, blk):
        dt_, B_, C_, x_ = blk                          # [b, L, ...]
        dA = jnp.exp(dt_[..., None] * A)               # [b, L, di, n]
        dBx = (dt_ * x_)[..., None] * B_[:, :, None, :]
        # inclusive associative scan of h' = a*h + u within the chunk
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])
        aa, uu = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
        hs = aa * h[:, None] + uu                      # [b, L, di, n]
        y = jnp.einsum("blin,bln->bli", hs, C_)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (dtc, Bc, Cc, xcc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, sp, di)[:, :s]
    y = y + xcf * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return dense(p["out_proj"], y)


def mamba_init_cache(cfg, batch, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(p, cfg, x1, cache):
    """x1: [b, 1, D] -> (y1, new_cache); O(1) per token."""
    b = x1.shape[0]
    kc = cfg.ssm_conv
    xz = dense(p["in_proj"], x1)
    xi, z = jnp.split(xz, 2, axis=-1)                  # [b, 1, di]

    window = jnp.concatenate([cache["conv"], xi], axis=1)   # [b, kc, di]
    xc = (window * p["conv_w"].astype(x1.dtype)[None]).sum(1, keepdims=True) \
        + p["conv_b"].astype(x1.dtype)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_params(p, cfg, xc)               # [b,1,di],[b,1,n]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)[:, 0]              # [b, di, n]
    dBx = ((dt * xc.astype(jnp.float32))[..., None]
           * Bm[:, :, None, :])[:, 0]                  # [b, di, n]
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0])[:, None, :]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x1.dtype) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, {"conv": window[:, 1:], "h": h}
