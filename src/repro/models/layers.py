"""Shared neural-net layers (pure JAX, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def dense_init(key, d_in, d_out, dtype, *, bias=False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": truncated_normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d, kind, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind, eps):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---- rotary position embeddings -------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., s, d]; pos: broadcastable to [..., s]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    ang = pos[..., None].astype(jnp.float32) * freqs   # [..., s, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / 10_000 ** (dim / d)
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ---- MLPs -------------------------------------------------------------------
def mlp_init(key, d, f, dtype, *, gated=True):
    ks = jax.random.split(key, 3)
    if gated:
        return {"wi": dense_init(ks[0], d, f, dtype),
                "wg": dense_init(ks[1], d, f, dtype),
                "wo": dense_init(ks[2], f, d, dtype)}
    return {"wi": dense_init(ks[0], d, f, dtype),
            "wo": dense_init(ks[2], f, d, dtype)}


def mlp_apply(p, x, *, gated=True):
    if gated:
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x))
    return dense(p["wo"], h)
