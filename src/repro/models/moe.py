"""Mixture-of-Experts FFN with capacity-based dispatch.

Top-k routing -> per-expert capacity C = tokens*k/E * capacity_factor;
tokens above capacity are dropped (standard Switch/GShard semantics, drop
fraction reported via aux).  Expert FFN weights are stored stacked [E, ...]
and tensor-parallel over the mesh 'model' axis on the hidden dim (see
dist/sharding.py); an expert-parallel all_to_all variant is a recorded §Perf
alternative.  Shared experts (qwen2-moe) run densely for every token.

The router runs in float32 (standard practice: bf16 router logits destabilise
top-k at scale).  Aux losses: load-balancing (Switch) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_init, mlp_apply, truncated_normal


def moe_init(key, cfg, dtype):
    D = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.padded_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": truncated_normal(ks[0], (D, E), D ** -0.5, jnp.float32),
        "wi": truncated_normal(ks[1], (E, D, F), D ** -0.5, dtype),
        "wg": truncated_normal(ks[2], (E, D, F), D ** -0.5, dtype),
        "wo": truncated_normal(ks[3], (E, F, D), F ** -0.5, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], D, F * cfg.n_shared_experts, dtype,
                               gated=True)
    return p


def _data_axis_size() -> int:
    from repro.dist.sharding import _ambient_mesh
    mesh = _ambient_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", ()))) \
        if hasattr(mesh, "axis_sizes") else dict(mesh.shape)
    return int(sizes.get("data", 1))


def moe_apply_ep(p, cfg, x, capacity: int | None = None):
    """Expert-parallel MoE via shard_map: local capacity dispatch + explicit
    all_to_all over 'data' (experts sharded E/G per data rank), GSPMD 'auto'
    for the tensor-parallel FFN inside.  A GSPMD-only formulation was tried
    and refuted (EXPERIMENTS.md §Perf B2): the partitioner cannot prove the
    dispatch scatter's batch dimension parallel and all-reduces the full
    [E, C, D] buffer per layer."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import _ambient_mesh

    mesh = _ambient_mesh()
    b, s, D = x.shape
    E, k = cfg.padded_experts, cfg.experts_per_token
    T = b * s
    sizes = {} if mesh is None else (
        dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", ())))
        if hasattr(mesh, "axis_sizes") else dict(mesh.shape))
    G = int(sizes.get("data", 1))
    if mesh is None or G <= 1 or b % G or E % G:
        return moe_apply(p, cfg, x, capacity)
    C = capacity or max(1, int(T * k / cfg.n_experts * cfg.capacity_factor))
    Cg = max(1, -(-min(C, T) // G))
    F = cfg.moe_d_ff or cfg.d_ff

    def body(xl, router, wi, wg, wo):
        # xl: [b/G, s, D] local tokens; wi/wg: [E/G, D, F]; wo: [E/G, F, D]
        bl = xl.shape[0]
        Tl = bl * s
        xf = xl.reshape(Tl, D)
        logits = xf.astype(jnp.float32) @ router               # [Tl, E]
        if E != cfg.n_experts:
            pad_mask = jnp.arange(E) >= cfg.n_experts
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        probs = jax.nn.softmax(logits, -1)
        gate_v, gate_i = jax.lax.top_k(probs, k)
        gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)
        onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.int32)
        flat = onehot.reshape(Tl * k, E)
        pos = ((jnp.cumsum(flat, axis=0) - 1) * flat).sum(-1)   # [Tl*k]
        keep = pos < Cg
        e_idx = gate_i.reshape(-1)
        c_idx = jnp.clip(pos, 0, Cg - 1)
        src = jnp.repeat(xf, k, axis=0)
        buf = jnp.zeros((E, Cg, D), xl.dtype)
        buf = buf.at[e_idx, c_idx].add(jnp.where(keep[:, None], src, 0))
        # dispatch all_to_all: [E, Cg, D] -> [E/G, G*Cg, D]
        buf = jax.lax.all_to_all(buf, "data", split_axis=0, concat_axis=1,
                                 tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype))
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf,
                                        wi.astype(xl.dtype))
        out_e = jnp.einsum("ecf,efd->ecd", h, wo.astype(xl.dtype))
        # combine all_to_all: [E/G, G*Cg, D] -> [E, Cg, D]
        out_e = jax.lax.all_to_all(out_e, "data", split_axis=1, concat_axis=0,
                                   tiled=True)
        picked = out_e[e_idx, c_idx]
        w = (gate_v.reshape(-1, 1) * keep[:, None]).astype(xl.dtype)
        y = (picked * w).reshape(Tl, k, D).sum(1).reshape(bl, s, D)
        me = probs.mean(0)
        ce = (flat.sum(0) / jnp.maximum(flat.sum(), 1)).astype(jnp.float32)
        aux = jnp.stack([cfg.n_experts * jnp.sum(me * ce),
                         jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
                         1.0 - keep.mean()])
        return y, aux[None]

    from repro.dist.sharding import shard_map
    fn = shard_map(body, mesh,
                   (P("data", None, None), P(), P("data"),
                    P("data"), P("data")),
                   (P("data", None, None), P("data")),
                   axis_names={"data"})   # manual over 'data' only: GSPMD
    #                                       keeps the expert FFN TP-sharded
    y, aux = fn(x, p["router"], p["wi"], p["wg"], p["wo"])
    aux = aux.mean(0)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, gated=True)
    return y, {"lb_loss": aux[0], "z_loss": aux[1], "drop_frac": aux[2]}


def moe_apply(p, cfg, x, capacity: int | None = None):
    """x: [b, s, D] -> (y, aux) with aux = dict(lb_loss, z_loss, drop_frac).

    ``capacity`` overrides the per-expert buffer size; decode passes C=T for
    dropless (deterministic) serving."""
    if cfg.moe_ep and capacity is None:
        return moe_apply_ep(p, cfg, x, capacity)
    b, s, D = x.shape
    E, k = cfg.padded_experts, cfg.experts_per_token
    T = b * s
    C = capacity or max(1, int(T * k / cfg.n_experts * cfg.capacity_factor))
    C = min(C, T)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])            # [T, E]
    if E != cfg.n_experts:   # padded experts never win routing
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    gate_v, gate_i = jax.lax.top_k(probs, k)                    # [T, k]
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.int32)         # [T, k, E]
    flat = onehot.reshape(T * k, E)
    pos_in_e = jnp.cumsum(flat, axis=0) * flat - 1              # [T*k, E]
    pos = (pos_in_e * flat).sum(-1).reshape(T, k)               # [T, k]
    keep = (pos < C) & (pos >= 0)

    # scatter tokens into [E, C, D]
    e_idx = gate_i.reshape(-1)
    c_idx = jnp.clip(pos.reshape(-1), 0, C - 1)
    buf = jnp.zeros((E, C, D), x.dtype)
    src = jnp.repeat(xf, k, axis=0)
    buf = buf.at[e_idx, c_idx].add(jnp.where(keep.reshape(-1, 1), src, 0))
    if cfg.moe_ep:
        # expert parallelism: dispatch buffer sharded by expert over 'data'
        # (GSPMD lowers the scatter/gather to all_to_all), expert weights
        # live E-sharded — no FSDP weight regathers
        from repro.dist.sharding import constrain
        buf = constrain(buf, "data", None, None)

    # expert FFN (SwiGLU), batched over E
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))  # [E, C, D]

    # gather back with gate weights
    picked = out_e[e_idx, c_idx]                                 # [T*k, D]
    w = (gate_v.reshape(-1, 1) * keep.reshape(-1, 1)).astype(x.dtype)
    y = (picked * w).reshape(T, k, D).sum(1).reshape(b, s, D)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, gated=True)

    # aux losses
    me = probs.mean(0)                                            # [E]
    ce = (flat.sum(0) / jnp.maximum(flat.sum(), 1)).astype(jnp.float32)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    drop_frac = 1.0 - keep.mean()
    return y, {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": drop_frac}
