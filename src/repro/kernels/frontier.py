"""Pallas TPU kernel: fused frontier scoring for the SM-tree cohort descent.

Every level of the level-synchronous kNN descent must evaluate the metric
between each query of the cohort and every entry of every node on that
query's frontier, then derive three per-entry quantities (DESIGN.md §8):

  * ``dmax``   = d + r          for valid internal entries (the d_max bound:
                                 each subtree holds an object within d + r)
  * ``score``  = d - r          for valid internal entries (the triangle-
                                 inequality prune test / closest-first key)
  * ``leaf_d`` = d              for valid leaf entries (exact candidates)

XLA expresses this as a ``[b, F, cap, dim]`` gather followed by the metric
reduction — one full materialisation of every touched node page *per query*
in HBM.  This kernel instead keys the pipeline on the frontier itself: the
``[b, F]`` node-id table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps read the ids
before the body runs and the Pallas pipeline streams exactly the referenced
node pages (``vecs``/``radius``/validity rows) HBM→VMEM, double-buffered
across grid steps.  Distances and all three outputs are computed in one
VMEM-resident pass; nothing of size ``[b, F, cap, dim]`` ever exists.

Grid: ``(b, F)`` — one step per (query, frontier-slot) pair.  Invalid slots
(node id < 0, the frontier padding) emit +inf rows; the metric itself is the
shared definition in ``core/metric.py`` whose fixed-association tree-fold
makes the kernel bitwise identical to the XLA path (``frontier_scores_xla``)
— asserted by tests/test_frontier_kernel.py in interpret mode, which runs
this exact kernel code on CPU CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.metric import get_metric

# python literal (not a jnp scalar): kernels may not capture traced consts
_INF = float("inf")


def _frontier_kernel(fids_ref, q_ref, vecs_ref, rad_ref, ival_ref, lval_ref,
                     dmax_ref, score_ref, leafd_ref, *, metric: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    ok = fids_ref[i, j] >= 0
    q = q_ref[0, :]                      # [dim]
    e = vecs_ref[0, :, :]                # [cap, dim] — the streamed node page
    d = get_metric(metric)(q[None, :], e)            # [cap]
    r = rad_ref[0, :]
    iv = (ival_ref[0, :] != 0) & ok
    lv = (lval_ref[0, :] != 0) & ok
    dmax_ref[0, 0, :] = jnp.where(iv, d + r, _INF)
    score_ref[0, 0, :] = jnp.where(iv, d - r, _INF)
    leafd_ref[0, 0, :] = jnp.where(lv, d, _INF)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def frontier_scores_pallas(fids, queries, vecs, radius, internal_valid,
                           leaf_valid, *, metric: str, interpret: bool = False):
    """Fused frontier scoring.

    fids           [b, F] i32  — frontier node ids (-1 = empty slot)
    queries        [b, dim] f32
    vecs           [N, cap, dim] f32 — node pages (entry reference values)
    radius         [N, cap] f32      — entry covering radii
    internal_valid [N, cap] — nonzero where a valid internal entry
    leaf_valid     [N, cap] — nonzero where a valid leaf entry

    Returns (dmax, score, leaf_d), each [b, F, cap] f32 with +inf at masked
    positions.  ``interpret=True`` runs the identical kernel through the
    Pallas interpreter (the CPU CI path).
    """
    b, w = fids.shape
    _, cap, dim = vecs.shape
    internal_valid = internal_valid.astype(jnp.int8)
    leaf_valid = leaf_valid.astype(jnp.int8)

    def node_row(ndim_tail):
        # block index for a [N, ...] page row selected by the prefetched id;
        # empty slots clamp to row 0 and are masked in the kernel body
        return lambda i, j, fids: (jnp.maximum(fids[i, j], 0),) + (0,) * ndim_tail

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, w),
        in_specs=[
            pl.BlockSpec((1, dim), lambda i, j, fids: (i, 0)),
            pl.BlockSpec((1, cap, dim), node_row(2)),
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap), lambda i, j, fids: (i, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j, fids: (i, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j, fids: (i, j, 0)),
        ],
    )
    out_shape = [jax.ShapeDtypeStruct((b, w, cap), jnp.float32)] * 3
    return pl.pallas_call(
        functools.partial(_frontier_kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(fids, queries, vecs, radius, internal_valid, leaf_valid)


@functools.partial(jax.jit, static_argnames=("metric",))
def frontier_scores_xla(fids, queries, vecs, radius, internal_valid,
                        leaf_valid, *, metric: str):
    """Reference/escape-hatch implementation: the gather the kernel avoids.

    Materialises the [b, F, cap, dim] entry gather and reduces with the same
    shared metric definition — bitwise identical outputs to the kernel: the
    tree-fold + rounding pins in core/metric.py fix the value up to op
    rounding, and jitting keeps both paths whole-program-compiled (eager
    per-op execution rounds sqrt/fusions differently on CPU)."""
    nodes = jnp.maximum(fids, 0)
    ok = (fids >= 0)[:, :, None]
    d = get_metric(metric)(queries[:, None, None, :], vecs[nodes])
    r = radius[nodes]
    iv = (internal_valid[nodes] != 0) & ok
    lv = (leaf_valid[nodes] != 0) & ok
    return (jnp.where(iv, d + r, _INF),
            jnp.where(iv, d - r, _INF),
            jnp.where(lv, d, _INF))
