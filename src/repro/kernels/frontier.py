"""Pallas TPU kernel: fused frontier scoring for the SM-tree cohort descent.

Every level of the level-synchronous kNN descent must evaluate the metric
between each query of the cohort and every entry of every node on that
query's frontier, then derive four per-entry quantities (DESIGN.md §8/§17):

  * ``dmax``   = d + r          for valid internal entries (the d_max bound:
                                 each subtree holds an object within d + r)
  * ``score``  = d - r          for valid internal entries (the triangle-
                                 inequality prune test / closest-first key)
  * ``leaf_d`` = d              for valid leaf entries (exact candidates)
  * ``dq``     = d              for valid internal entries — the raw
                                 query-to-routing-object distance the descent
                                 carries to the next level as ``d(q, parent)``

XLA expresses this as a ``[b, F, cap, dim]`` gather followed by the metric
reduction — one full materialisation of every touched node page *per query*
in HBM.  This kernel instead keys the pipeline on the frontier itself: the
``[b, F]`` node-id table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps read the ids
before the body runs and the Pallas pipeline streams exactly the referenced
node pages (``vecs``/``radius``/``pdist``/validity rows) HBM→VMEM,
double-buffered across grid steps.  Distances and all four outputs are
computed in one VMEM-resident pass; nothing of size ``[b, F, cap, dim]``
ever exists.

Parent-distance pre-filter (DESIGN.md §17): when the caller supplies the
``pdist`` page (d(entry, parent routing object), maintained by every
mutation path), the per-frontier ``qpd`` vector (d(q, parent) — the
distance that admitted each frontier node, computed at the previous level)
and the per-query radius ``rq``, the prologue drops every entry with

    |qpd - pdist| > rq + r + _PRUNE_PAD

*before* the metric eval: by the triangle inequality
|d(q,p) - d(e,p)| <= d(q,e), so such an entry provably fails the descent's
d - r <= r_q + eps prune test and its distance never needed computing.
Filtered entries' VPU lanes are masked (``jnp.where`` on the page input)
and a node whose entries are all filtered skips the reduction entirely
(``pl.when``).  Outputs are bitwise identical to the unfiltered kernel —
only the evaluation count changes.

Grid: ``(b, F)`` — one step per (query, frontier-slot) pair.  Invalid slots
(node id < 0, the frontier padding) emit +inf rows; the metric itself is the
shared definition in ``core/metric.py`` whose fixed-association tree-fold
makes the kernel bitwise identical to the XLA path (``frontier_scores_xla``)
— asserted by tests/test_frontier_kernel.py in interpret mode, which runs
this exact kernel code on CPU CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.metric import get_metric

# python literal (not a jnp scalar): kernels may not capture traced consts
_INF = float("inf")

# Filter slack: _EPS (1e-5, the descent's prune-test pad in core/smtree.py)
# plus another 1e-5 absorbing f32 rounding of the triangle lower bound
# (|d(q,p) - pdist| is computed from two independently rounded f32
# distances; the true d(q,e) can undershoot it by a few ulps).  An entry
# filtered at rq + r + _PRUNE_PAD therefore has d - r > rq + _EPS and
# would have been discarded by the prune test anyway — the derivation and
# the exact-boundary tests live in DESIGN.md §17 /
# tests/test_frontier_kernel.py.
_PRUNE_PAD = 2e-5

_IMPLS = ("pallas", "xla")


def _emit(dmax_ref, score_ref, leafd_ref, dq_ref, iv, lv, live, q_ref,
          vecs_ref, r, *, metric: str, mask_lanes: bool):
    """Shared kernel epilogue: evaluate the metric for one streamed node
    page and write the four output rows, or emit +inf rows without touching
    the VPU when no entry needs a distance (``pl.when`` whole-node skip)."""
    any_live = jnp.any(live)

    @pl.when(any_live)
    def _():
        q = q_ref[0, :]                  # [dim]
        e = vecs_ref[0, :, :]            # [cap, dim] — the streamed node page
        if mask_lanes:
            # filtered entries: zero the lanes so the reduction they ride
            # through is dead weight the compiler can drop; live entries'
            # inputs are untouched, keeping d bitwise equal to the
            # unfiltered kernel
            e = jnp.where(live[:, None], e, 0.0)
        d = get_metric(metric)(q[None, :], e)        # [cap]
        dmax_ref[0, 0, :] = jnp.where(iv, d + r, _INF)
        score_ref[0, 0, :] = jnp.where(iv, d - r, _INF)
        leafd_ref[0, 0, :] = jnp.where(lv, d, _INF)
        dq_ref[0, 0, :] = jnp.where(iv, d, _INF)

    @pl.when(jnp.logical_not(any_live))
    def _():
        inf_row = jnp.full_like(r, _INF)
        dmax_ref[0, 0, :] = inf_row
        score_ref[0, 0, :] = inf_row
        leafd_ref[0, 0, :] = inf_row
        dq_ref[0, 0, :] = inf_row


def _frontier_kernel(fids_ref, q_ref, vecs_ref, rad_ref, ival_ref, lval_ref,
                     dmax_ref, score_ref, leafd_ref, dq_ref, *, metric: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    ok = fids_ref[i, j] >= 0
    r = rad_ref[0, :]
    iv = (ival_ref[0, :] != 0) & ok
    lv = (lval_ref[0, :] != 0) & ok
    _emit(dmax_ref, score_ref, leafd_ref, dq_ref, iv, lv, iv | lv,
          q_ref, vecs_ref, r, metric=metric, mask_lanes=False)


def _frontier_kernel_pruned(fids_ref, q_ref, qpd_ref, rq_ref, vecs_ref,
                            rad_ref, pd_ref, ival_ref, lval_ref,
                            dmax_ref, score_ref, leafd_ref, dq_ref, *,
                            metric: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    ok = fids_ref[i, j] >= 0
    r = rad_ref[0, :]
    # triangle-inequality pre-filter on the already-resident scalars — no
    # metric eval yet.  Invalid slots carry qpd = +inf, so keep is all-False
    # there and the whole page is skipped.
    lb = jnp.abs(qpd_ref[0, 0] - pd_ref[0, :])
    keep = lb <= rq_ref[0, 0] + r + _PRUNE_PAD
    iv = (ival_ref[0, :] != 0) & ok & keep
    lv = (lval_ref[0, :] != 0) & ok & keep
    _emit(dmax_ref, score_ref, leafd_ref, dq_ref, iv, lv, iv | lv,
          q_ref, vecs_ref, r, metric=metric, mask_lanes=True)


def _check_prune_args(pdist, qpd, rq):
    given = [x is not None for x in (pdist, qpd, rq)]
    if any(given) and not all(given):
        raise ValueError("parent-distance filtering needs all of "
                         "pdist, qpd and rq (or none of them)")
    return all(given)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def frontier_scores_pallas(fids, queries, vecs, radius, internal_valid,
                           leaf_valid, *, metric: str, interpret: bool = False,
                           pdist=None, qpd=None, rq=None):
    """Fused frontier scoring.

    fids           [b, F] i32  — frontier node ids (-1 = empty slot)
    queries        [b, dim] f32
    vecs           [N, cap, dim] f32 — node pages (entry reference values)
    radius         [N, cap] f32      — entry covering radii
    internal_valid [N, cap] — nonzero where a valid internal entry
    leaf_valid     [N, cap] — nonzero where a valid leaf entry

    Optional parent-distance filter inputs (all three or none):

    pdist          [N, cap] f32 — d(entry, parent routing object) pages
    qpd            [b, F] f32   — d(q, parent routing object) per frontier
                                  slot (+inf at empty slots)
    rq             [b] f32      — current query radius (pre-level value of
                                  min(topk_d[k-1], r_cap, ub))

    Returns (dmax, score, leaf_d, dq), each [b, F, cap] f32 with +inf at
    masked/filtered positions.  ``interpret=True`` runs the identical
    kernel through the Pallas interpreter (the CPU CI path).
    """
    prune = _check_prune_args(pdist, qpd, rq)
    b, w = fids.shape
    _, cap, dim = vecs.shape
    internal_valid = internal_valid.astype(jnp.int8)
    leaf_valid = leaf_valid.astype(jnp.int8)

    def node_row(ndim_tail):
        # block index for a [N, ...] page row selected by the prefetched id;
        # empty slots clamp to row 0 and are masked in the kernel body
        return lambda i, j, fids: (jnp.maximum(fids[i, j], 0),) + (0,) * ndim_tail

    q_spec = pl.BlockSpec((1, dim), lambda i, j, fids: (i, 0))
    out_spec = pl.BlockSpec((1, 1, cap), lambda i, j, fids: (i, j, 0))
    if prune:
        in_specs = [
            q_spec,
            pl.BlockSpec((1, 1), lambda i, j, fids: (i, j)),   # qpd
            pl.BlockSpec((1, 1), lambda i, j, fids: (i, 0)),   # rq
            pl.BlockSpec((1, cap, dim), node_row(2)),
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),               # pdist page
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),
        ]
        operands = (fids, queries, qpd, rq[:, None], vecs, radius,
                    pdist, internal_valid, leaf_valid)
        kernel = _frontier_kernel_pruned
    else:
        in_specs = [
            q_spec,
            pl.BlockSpec((1, cap, dim), node_row(2)),
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),
            pl.BlockSpec((1, cap), node_row(1)),
        ]
        operands = (fids, queries, vecs, radius, internal_valid, leaf_valid)
        kernel = _frontier_kernel

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, w),
        in_specs=in_specs,
        out_specs=[out_spec] * 4,
    )
    out_shape = [jax.ShapeDtypeStruct((b, w, cap), jnp.float32)] * 4
    return pl.pallas_call(
        functools.partial(kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("metric",))
def frontier_scores_xla(fids, queries, vecs, radius, internal_valid,
                        leaf_valid, *, metric: str,
                        pdist=None, qpd=None, rq=None):
    """Reference/escape-hatch implementation: the gather the kernel avoids.

    Materialises the [b, F, cap, dim] entry gather and reduces with the same
    shared metric definition — bitwise identical outputs to the kernel: the
    tree-fold + rounding pins in core/metric.py fix the value up to op
    rounding, and jitting keeps both paths whole-program-compiled (eager
    per-op execution rounds sqrt/fusions differently on CPU).

    The parent-distance filter (pdist/qpd/rq — see frontier_scores_pallas)
    applies the identical keep mask and zeroes filtered rows via jnp.where
    *before* the metric eval; on XLA:CPU the compiler still schedules the
    full reduction shape, so this buys parity and honest eval counters, not
    wall-clock (DESIGN.md §17 — the lane skip is a kernel-path win)."""
    prune = _check_prune_args(pdist, qpd, rq)
    nodes = jnp.maximum(fids, 0)
    ok = (fids >= 0)[:, :, None]
    r = radius[nodes]
    iv = (internal_valid[nodes] != 0) & ok
    lv = (leaf_valid[nodes] != 0) & ok
    e = vecs[nodes]
    if prune:
        lb = jnp.abs(qpd[:, :, None] - pdist[nodes])
        keep = lb <= rq[:, None, None] + r + _PRUNE_PAD
        iv = iv & keep
        lv = lv & keep
        e = jnp.where((iv | lv)[..., None], e, 0.0)
    d = get_metric(metric)(queries[:, None, None, :], e)
    return (jnp.where(iv, d + r, _INF),
            jnp.where(iv, d - r, _INF),
            jnp.where(lv, d, _INF),
            jnp.where(iv, d, _INF))


def frontier_scores(fids, queries, vecs, radius, internal_valid, leaf_valid,
                    *, metric: str, impl: str, interpret: bool = False,
                    pdist=None, qpd=None, rq=None):
    """Dispatch one level's frontier scoring to a backend by name.

    ``impl`` must name a scoring backend exactly — 'pallas' (the fused
    kernel; interpret-mode off-TPU) or 'xla' (the gather path).  Anything
    else raises ``ValueError`` naming the valid set rather than silently
    picking a default ('perquery' and 'auto' are descent-level toggles,
    resolved before this point by core/smtree._resolve_impl)."""
    if impl not in _IMPLS:
        raise ValueError(
            f"frontier_scores impl must be one of {_IMPLS}; got {impl!r}")
    if impl == "pallas":
        return frontier_scores_pallas(
            fids, queries, vecs, radius, internal_valid, leaf_valid,
            metric=metric, interpret=interpret,
            pdist=pdist, qpd=qpd, rq=rq)
    return frontier_scores_xla(
        fids, queries, vecs, radius, internal_valid, leaf_valid,
        metric=metric, pdist=pdist, qpd=qpd, rq=rq)
