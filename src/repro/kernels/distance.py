"""Pallas TPU kernel: tiled batched pairwise distances (+ fused prune mask).

This is the SM-tree's compute hot spot: every traversal level evaluates the
metric between a tile of queries and every entry of every frontier node.  The
kernel streams `[bq, d]` query and `[be, d]` entry blocks HBM->VMEM, reduces
over the feature dimension in `bd`-sized chunks (running max for d_inf /
running sum for squared-L2), and writes a `[bq, be]` distance tile.  All block
dims default to lane/sublane-aligned sizes (128, 8-multiples).

The optional fused epilogue applies the SM-tree triangle-inequality test
``d <= r_q + r_e`` in-register, emitting the survival mask alongside the
distances — saving one HBM round trip of the distance matrix on the pruning
path (the common case during descent).

Grid: (nq/bq, ne/be, d/bd); the reduction dim is innermost ("arbitrary"
semantics, accumulate in the output tile which Pallas keeps resident in VMEM
across the k-steps of a fixed (i, j) tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _dist_kernel(q_ref, e_ref, out_ref, *, metric: str, nk: int):
    k = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)          # [bq, bd]
    e = e_ref[...].astype(jnp.float32)          # [be, bd]
    if metric == "d_inf":
        part = jnp.max(jnp.abs(q[:, None, :] - e[None, :, :]), axis=-1)
        acc0 = jnp.zeros_like(part)
        combine = jnp.maximum
    elif metric == "sqeuclidean":
        # |q-e|^2 = |q|^2 - 2 q.e + |e|^2 : MXU does the q @ e.T contraction
        qq = jnp.sum(q * q, axis=-1, keepdims=True)          # [bq, 1]
        ee = jnp.sum(e * e, axis=-1, keepdims=True).T        # [1, be]
        qe = jax.lax.dot_general(q, e, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        part = qq - 2.0 * qe + ee
        acc0 = jnp.zeros_like(part)
        combine = lambda a, b: a + b
    elif metric == "ip":
        part = -jax.lax.dot_general(q, e, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        acc0 = jnp.zeros_like(part)
        combine = lambda a, b: a + b
    else:
        raise ValueError(metric)

    prev = jnp.where(k == 0, acc0, out_ref[...])
    out_ref[...] = combine(prev, part)


def _dist_prune_kernel(q_ref, e_ref, rq_ref, re_ref, out_ref, mask_ref,
                       *, metric: str, nk: int):
    """Same as _dist_kernel but fuses the triangle-inequality prune mask on
    the final reduction step."""
    _dist_kernel(q_ref, e_ref, out_ref, metric=metric, nk=nk)
    k = pl.program_id(2)

    @pl.when(k == nk - 1)
    def _():
        d = out_ref[...]
        if metric == "sqeuclidean":
            d = jnp.sqrt(jnp.maximum(d, 0.0))
        rq = rq_ref[...].astype(jnp.float32)    # [bq]
        re = re_ref[...].astype(jnp.float32)    # [be]
        mask_ref[...] = d <= rq[:, None] + re[None, :]


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("metric", "bq", "be", "bd", "interpret"))
def pairwise_distance_pallas(q: jax.Array, e: jax.Array, *, metric: str = "d_inf",
                             bq: int = 128, be: int = 128, bd: int = 128,
                             interpret: bool = False) -> jax.Array:
    """[nq, d] x [ne, d] -> [nq, ne] distances via the Pallas kernel."""
    nq, d = q.shape
    ne = e.shape[0]
    qp = _pad_to(_pad_to(q, bd, 1), bq, 0)
    # pad entries with +inf-ish sentinel? distances to padded entries are
    # sliced away below, so zero padding is fine.
    ep = _pad_to(_pad_to(e, bd, 1), be, 0)
    nqp, dp = qp.shape
    nep = ep.shape[0]
    nk = dp // bd
    grid = (nqp // bq, nep // be, nk)
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((be, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, be), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nqp, nep), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, ep)
    out = out[:nq, :ne]
    if metric == "sqeuclidean":
        out = jnp.maximum(out, 0.0)
    return out


@functools.partial(jax.jit, static_argnames=("metric", "bq", "be", "bd", "interpret"))
def pairwise_distance_prune_pallas(q, e, r_q, r_e, *, metric: str = "d_inf",
                                   bq: int = 128, be: int = 128, bd: int = 128,
                                   interpret: bool = False):
    """Fused distances + triangle-inequality survival mask.

    Returns (dist [nq, ne] float32, mask [nq, ne] bool).  For 'sqeuclidean'
    the returned distances are *squared* but the mask is computed on true
    distances (sqrt fused in-kernel)."""
    nq, d = q.shape
    ne = e.shape[0]
    qp = _pad_to(_pad_to(q, bd, 1), bq, 0)
    ep = _pad_to(_pad_to(e, bd, 1), be, 0)
    rqp = _pad_to(r_q.astype(jnp.float32), bq, 0, value=-1.0)   # padded queries match nothing
    rep = _pad_to(r_e.astype(jnp.float32), be, 0, value=-jnp.inf)
    nqp, dp = qp.shape
    nep = ep.shape[0]
    nk = dp // bd
    grid = (nqp // bq, nep // be, nk)
    dist, mask = pl.pallas_call(
        functools.partial(_dist_prune_kernel, metric=metric, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((be, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bq,), lambda i, j, k: (i,)),
            pl.BlockSpec((be,), lambda i, j, k: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, be), lambda i, j, k: (i, j)),
            pl.BlockSpec((bq, be), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nqp, nep), jnp.float32),
            jax.ShapeDtypeStruct((nqp, nep), jnp.bool_),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, ep, rqp, rep)
    return dist[:nq, :ne], mask[:nq, :ne]
