"""Public jit'd kernel entry points with backend dispatch.

``impl`` policy:
  * 'auto'    — Pallas/Mosaic on TPU, XLA reference elsewhere (CPU dry-run).
  * 'pallas'  — force the Mosaic kernel (TPU).
  * 'interpret' — Pallas interpret mode (CPU correctness validation).
  * 'xla'     — pure-jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distance import (pairwise_distance_pallas,
                                    pairwise_distance_prune_pallas)


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def pairwise_distance(q, e, *, metric: str = "d_inf", impl: str = "auto", **kw):
    """[nq, d] x [ne, d] -> [nq, ne] distances."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "xla":
        return ref.pairwise_distance_ref(q, e, metric=metric)
    if impl == "interpret":
        return pairwise_distance_pallas(q, e, metric=metric, interpret=True, **kw)
    return pairwise_distance_pallas(q, e, metric=metric, **kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _pallas_attention(q, k, v, causal, scale, interpret):
    from repro.kernels.flash_attention import flash_attention_fwd
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)


def _pallas_attention_fwd(q, k, v, causal, scale, interpret):
    return _pallas_attention(q, k, v, causal, scale, interpret), (q, k, v)


def _pallas_attention_bwd(causal, scale, interpret, res, g):
    # recompute backward through the chunked XLA flash (same math, O(s·d) mem)
    from repro.kernels.attention_xla import chunked_attention
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: chunked_attention(
        q_, k_, v_, causal=causal, scale=scale), q, k, v)
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              impl: str = "auto"):
    """Multi-head GQA attention.  q: [b,h,sq,d]; k,v: [b,hk,sk,d].

    impl: 'auto' | 'pallas' | 'interpret' | 'xla' (chunked flash-style scan)
    | 'xla_naive' (materialised logits — small shapes/tests only)."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "xla":
        from repro.kernels.attention_xla import chunked_attention
        return chunked_attention(q, k, v, causal=causal, scale=scale)
    if impl == "xla_naive":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return _pallas_attention(q, k, v, causal, scale, impl == "interpret")


def pairwise_distance_prune(q, e, r_q, r_e, *, metric: str = "d_inf",
                            impl: str = "auto", **kw):
    """Fused distances + triangle-inequality prune mask."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "xla":
        m = "sqeuclidean" if metric == "sqeuclidean" else metric
        dist = ref.pairwise_distance_ref(q, e, metric=m)
        true_dist = jnp.sqrt(jnp.maximum(dist, 0.0)) if m == "sqeuclidean" else dist
        return dist, ref.prune_mask_ref(true_dist, r_q, r_e)
    interp = impl == "interpret"
    return pairwise_distance_prune_pallas(q, e, r_q, r_e, metric=metric,
                                          interpret=interp, **kw)
