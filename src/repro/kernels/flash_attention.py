"""Pallas TPU flash attention (forward) with GQA and causal masking.

Design (TPU-native, not a CUDA port):
  * grid = (batch*q_heads, nq_blocks, nk_blocks) with the KV dimension
    innermost ("arbitrary" semantics) so the [bq, d] accumulator, running max
    and running sum live in VMEM scratch across the KV sweep of one q tile.
  * online softmax in float32 on the VPU; the two matmuls (q@k^T, p@v) hit
    the MXU with 128-aligned tiles.
  * GQA: KV blocks are selected by the BlockSpec index map
    (q-head -> kv-head = q_head // group), so KV for a group is fetched from
    HBM once per q-head without materialising the broadcast.
  * causal: block-level early-out via pl.when (skips the MXU work of fully
    masked tiles; the block fetch itself is pipelined by Pallas regardless —
    a scalar-prefetch kv-length map would also skip the fetch; measured as a
    §Perf item).

Backward runs as a chunked XLA recompute (see ops.attention): fwd kernel +
custom_vjp; a dedicated bwd kernel is a recorded optimisation opportunity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                      *, scale: float, causal: bool, sq: int, sk: int,
                      bq: int, bk: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions (causal offset aligns the *ends* of q and k, the
    # standard convention for decode/prefill with history)
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: in causal mode a tile whose lowest kpos exceeds the
    # highest qpos is fully masked
    run = True
    if causal:
        run = (kb * bk) <= (qb * bq + bq - 1 + (sk - sq))

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kpos < sk                           # padded keys
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                        # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # [bq, bk]
        corr = jnp.exp(m_prev - m_new)             # [bq, 1]
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalise():
        # rows with no unmasked key (padded q rows) have l == 0: emit zeros
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale: float | None = None,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = False) -> jax.Array:
    """q: [b, h, sq, d]; k, v: [b, hk, sk, d]; h % hk == 0.  Returns [b, h, sq, d]."""
    b, h, sq, d = q.shape
    _, hk, sk, _ = k.shape
    assert h % hk == 0, (h, hk)
    g = h // hk
    scale = d ** -0.5 if scale is None else scale

    sq_p = -(-sq // bq) * bq
    sk_p = -(-sk // bk) * bk
    qp = jnp.pad(q.reshape(b * h, sq, d), ((0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k.reshape(b * hk, sk, d), ((0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v.reshape(b * hk, sk, d), ((0, 0), (0, sk_p - sk), (0, 0)))

    grid = (b * h, sq_p // bq, sk_p // bk)

    def kv_index(bh, i, j):
        # flattened q-head index -> flattened kv-head index (GQA)
        return ((bh // h) * hk + (bh % h) // g, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                          sq=sq, sk=sk, bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :].reshape(b, h, sq, d)
