"""Memory-efficient chunked attention in pure XLA (lax.scan flash-style).

Same math as the Pallas kernel, expressed as a scan over KV chunks with an
online-softmax carry.  Used (a) as the lowering path on non-TPU backends (the
multi-pod dry-run compiles this), (b) as the recompute backward for the
Pallas forward, (c) as an oracle cross-check.  Fully differentiable; memory
is O(sq * d + chunk * d) per head instead of O(sq * sk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                      chunk: int = 512) -> jax.Array:
    """q: [b, h, sq, d]; k, v: [b, hk, sk, d].  float32 accumulation."""
    b, h, sq, d = q.shape
    _, hk, sk, _ = k.shape
    g = h // hk
    scale = d ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32) * scale
    qf = qf.reshape(b, hk, g * sq, d)  # group folded into rows; positions tracked below
    qpos = jnp.tile(jnp.arange(sq) + (sk - sq), g)                 # [g*sq]

    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    sk_p = n_chunks * chunk
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    kf = kf.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vf = vf.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, blk):
        m, l, acc, j = carry
        kc, vc = blk                                   # [b, hk, chunk, d]
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)      # [b, hk, g*sq, chunk]
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((b, hk, g * sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g * sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hk, g * sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kf, vf))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hk, g, sq, d).reshape(b, h, sq, d).astype(q.dtype)


def decode_attention(q1, k, v, *, scale: float | None = None,
                     kv_len: jax.Array | None = None) -> jax.Array:
    """Single-position decode attention.

    q1: [b, h, 1, d]; k, v: [b, hk, S, d] (the cache, possibly longer than the
    valid prefix); kv_len: [b] valid lengths (attend to positions < kv_len).
    Math in float32; safe-softmax.  This formulation psum-combines cleanly
    when the cache S axis is sharded (sequence-parallel decode, see
    models/attention.py).
    """
    b, h, _, d = q1.shape
    _, hk, S, _ = k.shape
    g = h // hk
    scale = d ** -0.5 if scale is None else scale
    qf = q1.astype(jnp.float32).reshape(b, hk, g, d) * scale
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    if kv_len is not None:
        mask = jnp.arange(S)[None, :] < kv_len[:, None]           # [b, S]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32)) / jnp.where(l == 0, 1, l)
    return out.reshape(b, h, 1, d).astype(q1.dtype)
