"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret-mode
sweeps in tests/) and the lowering path used on backends without Mosaic
(CPU dry-run): same math, standard XLA ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_distance_ref(q: jax.Array, e: jax.Array, metric: str = "d_inf") -> jax.Array:
    """[nq, d] x [ne, d] -> [nq, ne] distances.

    metric: 'd_inf' (Chebyshev), 'l2' (Euclidean), 'sqeuclidean', 'ip'
    (negative inner product, for MIPS-style retrieval over normalised keys).
    """
    q = q[:, None, :]
    e = e[None, :, :]
    if metric == "d_inf":
        return jnp.max(jnp.abs(q - e), axis=-1)
    if metric in ("l2", "sqeuclidean"):
        d2 = jnp.sum((q - e) ** 2, axis=-1)
        return jnp.sqrt(d2) if metric == "l2" else d2
    if metric == "ip":
        return -jnp.sum(q * e, axis=-1)
    raise ValueError(f"unknown metric {metric!r}")


def prune_mask_ref(dist: jax.Array, r_q: jax.Array, r_e: jax.Array) -> jax.Array:
    """Triangle-inequality survival mask: d(Q,O_n) <= r(Q) + r(O_n).

    dist: [nq, ne]; r_q: [nq] query search radii; r_e: [ne] covering radii.
    """
    return dist <= r_q[:, None] + r_e[None, :]


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Reference multi-head attention.  q: [b, h, sq, d]; k,v: [b, hk, sk, d]
    with h a multiple of hk (GQA: kv heads broadcast over query-head groups).
    Computes in float32, returns q.dtype."""
    b, h, sq, d = q.shape
    hk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    group = h // hk
    qf = q.astype(jnp.float32).reshape(b, hk, group, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        sk = k.shape[2]
        # query position i attends to key positions <= i + (sk - sq)
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return out.reshape(b, h, sq, d).astype(q.dtype)
