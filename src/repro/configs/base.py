"""Architecture configuration + registry.

One ``ArchConfig`` per assigned architecture (exact numbers from the
assignment table) plus reduced smoke variants.  ``block_pattern`` describes
one *period* of the layer stack; the model is a scan over
``n_layers // len(block_pattern)`` stacked periods (homogeneous pytree), which
keeps compile time and HLO size flat in depth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

# block kinds: "attn" (GQA + dense FFN), "attn_moe" (GQA + MoE FFN),
# "mamba" / "mamba_moe", "mlstm", "slstm"
BlockPattern = tuple[str, ...]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: BlockPattern = ("attn",)
    d_head: int = 0                # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0      # qwen2-moe style always-on experts
    moe_d_ff: int = 0              # per-expert hidden dim (if != d_ff)
    capacity_factor: float = 1.25

    # --- attention details ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    gated_mlp: bool = True         # SwiGLU (3-mat) vs classic 2-mat GELU
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    pos_embedding: str = "rope"    # rope | learned | sinusoidal

    # --- SSM (mamba) ---
    ssm_expand: int = 2
    ssm_state: int = 16
    ssm_conv: int = 4

    # --- xLSTM ---
    xlstm_proj_factor: float = 2.0

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0        # 0 -> decoder-only
    max_target_len: int = 448      # whisper decoder position bound
    n_audio_frames_per_s: int = 50

    # --- modality frontend stub ---
    frontend: str = "none"         # none | audio_stub | vision_stub
    n_image_tokens: int = 256      # vlm stub: patch-embedding count

    # --- TP-friendliness padding (dry-run/production overrides; 0/1 = off).
    # Padded q-heads are output-masked so the model is EXACTLY the assigned
    # architecture (zero gradient into pad heads); padded vocab rows are
    # ordinary unused slots (standard Megatron vocab padding).
    head_pad: int = 0              # pad n_heads up to a multiple of this
    vocab_pad_to: int = 1          # pad vocab_size up to a multiple of this
    expert_pad_to: int = 0         # pad n_experts up to a multiple (EP)
    moe_ep: bool = False           # expert parallelism over 'data' (A2A
    #                                dispatch) instead of FSDP weight gathers

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    norm_eps: float = 1e-5

    # --- capability flags ---
    subquadratic: bool = False     # supports long_500k decode

    def __post_init__(self):
        object.__setattr__(self, "d_head",
                           self.d_head or self.d_model // max(self.n_heads, 1))
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern)

    @property
    def padded_heads(self) -> int:
        if not self.head_pad:
            return self.n_heads
        return -(-self.n_heads // self.head_pad) * self.head_pad

    @property
    def padded_kv_heads(self) -> int:
        # MHA (KV == H) pads with the q heads; GQA keeps KV (replicated)
        return self.padded_heads if self.n_kv_heads == self.n_heads \
            else self.n_kv_heads

    @property
    def padded_experts(self) -> int:
        if not self.expert_pad_to or not self.n_experts:
            return self.n_experts
        return -(-self.n_experts // self.expert_pad_to) * self.expert_pad_to

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // self.vocab_pad_to) * self.vocab_pad_to

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def param_count(self) -> int:
        """Total parameters (embedding + blocks), exact per block kind."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, dh = self.n_heads, self.n_kv_heads, self.d_head
        tied = self.tie_embeddings or self.is_encdec  # enc-dec always ties
        total = V * D + (0 if tied else V * D)  # embed + head
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * dh
        dense_ffn = (3 if self.gated_mlp else 2) * D * F
        moe_ffn = (self.n_experts * 3 * D * (self.moe_d_ff or F)
                   + self.n_shared_experts * 3 * D * (self.moe_d_ff or F)
                   + D * self.n_experts)
        d_in = self.ssm_expand * D
        mamba = (D * 2 * d_in + d_in * self.ssm_conv
                 + d_in * (2 * self.ssm_state + 2) + d_in * D)
        pf = self.xlstm_proj_factor
        d_x = int(pf * D)
        mlstm = D * 2 * d_x + d_x * D + 3 * d_x * d_x + 4 * d_x
        slstm = 4 * D * D + D * D + 2 * int(2.7 * D) * D
        per_kind = dict(attn=attn + dense_ffn, attn_moe=attn + moe_ffn,
                        mamba=mamba + dense_ffn if F else mamba,
                        mamba_moe=mamba + moe_ffn,
                        mlstm=mlstm, slstm=slstm)
        n_per = self.n_layers // len(self.block_pattern)
        for kind in self.block_pattern:
            total += n_per * per_kind[kind]
        total += 2 * self.n_layers * D  # norms
        if self.is_encdec:
            enc_attn = 4 * D * H * dh
            total += self.encoder_layers * (enc_attn + dense_ffn + 2 * D)
            total += self.n_layers * (attn + 2 * D)  # cross-attn per dec layer
        return total

    @property
    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count
        Fm = self.moe_d_ff or self.d_ff
        unused = (self.n_experts - self.experts_per_token) * 3 * self.d_model * Fm
        n_moe = sum(1 for k in self.block_pattern if k.endswith("_moe"))
        return self.param_count - self.n_periods * n_moe * unused


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ArchConfig:
    import repro.configs.all_archs  # noqa: F401  (populates registry)
    try:
        cfg = _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}") from None
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_archs() -> list[str]:
    import repro.configs.all_archs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (assignment table)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention architecture: 500k-token decode "
                       "requires sub-quadratic attention (skip per assignment)")
    return True, ""
