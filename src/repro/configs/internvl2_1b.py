"""Config for internvl2-1b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "internvl2-1b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
