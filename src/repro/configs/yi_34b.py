"""Config for yi-34b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "yi-34b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
