"""Config for codeqwen1.5-7b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "codeqwen1.5-7b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
