"""Config for starcoder2-3b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "starcoder2-3b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
