"""Config for whisper-tiny (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "whisper-tiny"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
