"""Config for jamba-v0.1-52b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "jamba-v0.1-52b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
