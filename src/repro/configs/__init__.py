from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, get_config, list_archs, shape_applicable  # noqa: F401
from repro.configs.all_archs import smoke_config  # noqa: F401
