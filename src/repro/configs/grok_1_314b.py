"""Config for grok-1-314b (see all_archs.py for the authoritative numbers)."""
from repro.configs.base import get_config

ARCH_ID = "grok-1-314b"


def config(**overrides):
    return get_config(ARCH_ID, **overrides)
