"""The 10 assigned architectures, exact configuration numbers from the
assignment table (sources in brackets), plus reduced smoke variants.

Each entry also exists as ``src/repro/configs/<id>.py`` re-exporting its
config for per-arch discoverability.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, register


@register("internvl2-1b")
def internvl2_1b() -> ArchConfig:
    # [vlm] InternViT frontend (stub) + InternLM2-1B backbone [arXiv:2404.16821]
    return ArchConfig(
        name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
        n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151_655,
        rope_theta=1e6, frontend="vision_stub", n_image_tokens=256)


@register("qwen2-moe-a2.7b")
def qwen2_moe() -> ArchConfig:
    # [moe] 4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]
    return ArchConfig(
        name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=151_936,
        block_pattern=("attn_moe",), n_experts=60, experts_per_token=4,
        n_shared_experts=4, moe_d_ff=1408, qkv_bias=True, rope_theta=1e6)


@register("grok-1-314b")
def grok_1() -> ArchConfig:
    # [moe] 8 experts top-2 [hf:xai-org/grok-1; unverified]
    return ArchConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=32_768, vocab_size=131_072,
        block_pattern=("attn_moe",), n_experts=8, experts_per_token=2,
        moe_d_ff=32_768, rope_theta=1e4)


@register("starcoder2-3b")
def starcoder2() -> ArchConfig:
    # [dense] GQA kv=2, RoPE [arXiv:2402.19173]
    return ArchConfig(
        name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
        n_heads=24, n_kv_heads=2, d_ff=12_288, vocab_size=49_152,
        qkv_bias=True, norm="layernorm", gated_mlp=False, rope_theta=1e5)


@register("codeqwen1.5-7b")
def codeqwen() -> ArchConfig:
    # [dense] qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B]
    return ArchConfig(
        name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=13_440, vocab_size=92_416,
        qkv_bias=True, rope_theta=1e6)


@register("yi-34b")
def yi_34b() -> ArchConfig:
    # [dense] llama-arch GQA [arXiv:2403.04652]
    return ArchConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20_480, vocab_size=64_000,
        rope_theta=5e6)


@register("qwen2.5-3b")
def qwen25_3b() -> ArchConfig:
    # [dense] GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-3B]
    return ArchConfig(
        name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11_008, vocab_size=151_936,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True)


@register("xlstm-1.3b")
def xlstm() -> ArchConfig:
    # [ssm] sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM) [arXiv:2405.04517]
    return ArchConfig(
        name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50_304,
        block_pattern=("mlstm",) * 7 + ("slstm",),
        subquadratic=True, pos_embedding="none")


@register("jamba-v0.1-52b")
def jamba() -> ArchConfig:
    # [hybrid] Mamba+attn 1:7 interleave, MoE every other layer, 16e top-2
    # [arXiv:2403.19887]
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14_336, vocab_size=65_536,
        block_pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
                       "attn", "mamba_moe", "mamba", "mamba_moe"),
        n_experts=16, experts_per_token=2, moe_d_ff=14_336,
        subquadratic=True, pos_embedding="none",
        ssm_expand=2, ssm_state=16, ssm_conv=4)


@register("whisper-tiny")
def whisper_tiny() -> ArchConfig:
    # [audio] enc-dec, conv frontend stub [arXiv:2212.04356]
    return ArchConfig(
        name="whisper-tiny", family="audio", n_layers=4, d_model=384,
        n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51_865,
        encoder_layers=4, norm="layernorm", gated_mlp=False,
        pos_embedding="learned",
        frontend="audio_stub", max_target_len=448)


# ---------------------------------------------------------------------------
# Reduced smoke variants: same family/block structure, tiny dims
# ---------------------------------------------------------------------------
def smoke_config(name: str) -> ArchConfig:
    from repro.configs.base import get_config
    cfg = get_config(name)
    pat_len = len(cfg.block_pattern)
    return dataclasses.replace(
        cfg,
        n_layers=2 * pat_len if cfg.name != "whisper-tiny" else 2,
        d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        moe_d_ff=96 if cfg.moe_d_ff else 0,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        vocab_size=512,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_image_tokens=16 if cfg.frontend == "vision_stub" else cfg.n_image_tokens,
        max_target_len=64 if cfg.is_encdec else cfg.max_target_len)
