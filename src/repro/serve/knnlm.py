"""kNN-LM serving: the SM-forest as a first-class LM-serving datastore.

Khandelwal et al.-style interpolation: the datastore maps hidden states
h_t -> observed next token; at each decode step we retrieve the k nearest
stored states and mix

    p(w) = (1 - lam) * p_LM(w) + lam * p_kNN(w),
    p_kNN(w) ∝ Σ_{(h_i, w_i=w)} exp(-d(h, h_i) / T)

The SM-tree is what makes the datastore *dynamic*: ``evict`` uses the
paper's Delete to drop stale entries online (sliding-window memory) — the
operation the original M-tree family could not support.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.engine import SMTreeEngine
from repro.models import model as M


@dataclasses.dataclass
class KnnLmConfig:
    k: int = 8
    lam: float = 0.25
    temperature: float = 1.0
    metric: str = "l2"
    capacity: int = 32
    max_frontier: int = 128


class KnnLmDatastore:
    """Single-host datastore over the JAX SM-tree engine (the sharded-forest
    variant lives in core/distributed.py and examples/distributed_index.py).
    Keys: hidden states [n, D]; values: next-token ids [n].

    With ``mesh`` set, tree pages are replicated over the mesh and query
    cohorts are sharded over the data axes (``dist.sharding.query_pspecs``),
    so the cohort descent runs data-parallel inside the same GSPMD program
    as the sharded decode step (``launch/serve.py --mesh host --knn``)."""

    def __init__(self, cfg: KnnLmConfig, dim: int, mesh=None):
        self.cfg = cfg
        self.dim = dim
        self.mesh = mesh
        self.keys = np.zeros((0, dim), np.float32)
        self.values = np.zeros((0,), np.int32)
        self.engine: SMTreeEngine | None = None

    def _place(self):
        """Replicate tree pages over the mesh (queries shard, pages don't)."""
        if self.mesh is not None and self.engine is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self.engine.tree = jax.device_put(
                self.engine.tree, NamedSharding(self.mesh, P()))

    def shard_queries(self, h: jax.Array) -> jax.Array:
        """Place a [b, D] query cohort according to ``query_pspecs``."""
        if self.mesh is None:
            return h
        from jax.sharding import NamedSharding
        from repro.dist.sharding import query_pspecs
        return jax.device_put(
            h, NamedSharding(self.mesh, query_pspecs(self.mesh, h.shape[0])))

    def build(self, keys: np.ndarray, values: np.ndarray):
        self.keys = np.asarray(keys, np.float32)
        self.values = np.asarray(values, np.int32)
        self.engine = SMTreeEngine.build(
            self.keys, ids=np.arange(len(values)),
            capacity=self.cfg.capacity, metric=self.cfg.metric)
        self._place()

    def add(self, key: np.ndarray, value: int):
        oid = len(self.values)
        self.keys = np.vstack([self.keys, key[None]])
        self.values = np.append(self.values, np.int32(value))
        self.engine.insert(key, oid)
        self._place()   # host-side split paths rebuild arrays off-mesh

    def evict(self, oid: int) -> bool:
        """Online deletion — the paper's contribution at work."""
        return self.engine.delete(self.keys[oid], oid)

    def evict_before(self, oid_bound: int) -> int:
        """Sliding-window eviction: drop all entries with id < bound."""
        n = 0
        for oid in range(oid_bound):
            if self.evict(oid):
                n += 1
        return n

    def knn_logits(self, h: jax.Array, vocab: int) -> jax.Array:
        """h: [b, D] query hidden states -> kNN log-probs [b, vocab]."""
        res = self.engine.knn(self.shard_queries(h), k=self.cfg.k,
                              max_frontier=self.cfg.max_frontier)
        d = res.dists                                     # [b, k]
        ids = np.asarray(res.ids)                          # [b, k]
        vals = jnp.asarray(np.where(ids >= 0, self.values[np.maximum(ids, 0)],
                                    0))
        w = jax.nn.softmax(jnp.where(jnp.isfinite(d),
                                     -d / self.cfg.temperature, -jnp.inf), -1)
        b = h.shape[0]
        probs = jnp.zeros((b, vocab), jnp.float32)
        probs = probs.at[jnp.arange(b)[:, None], vals].add(
            jnp.where(jnp.isfinite(d), w, 0.0))
        return jnp.log(jnp.maximum(probs, 1e-10))


def mix_logits(lm_logits: jax.Array, knn_logp: jax.Array, lam: float):
    """log((1-lam) p_LM + lam p_kNN) computed stably."""
    lm_logp = jax.nn.log_softmax(lm_logits.astype(jnp.float32), -1)
    return jnp.logaddexp(lm_logp + jnp.log1p(-lam), knn_logp + jnp.log(lam))


def decode_with_knnlm(params, cfg: ArchConfig, store: KnnLmDatastore,
                      prompt: jax.Array, n_steps: int, *, lam=None):
    """Greedy decode with kNN-LM mixing; also streams (h, next_token) pairs
    back into the datastore (online growth).  prompt: [b, s0]."""
    lam = lam if lam is not None else store.cfg.lam
    b, s0 = prompt.shape
    cache = M.init_cache(cfg, b, s0 + n_steps + 1)
    tok = prompt[:, 0]
    h_tap = {}

    # feed the prompt
    for pos in range(s0):
        logits, cache = M.decode_step(params, cfg, prompt[:, pos], cache,
                                      jnp.int32(pos))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(n_steps):
        pos = s0 + step
        logits, cache = M.decode_step(params, cfg, tok, cache, jnp.int32(pos))
        # final hidden state proxy: use logits projected back is costly; we
        # tap the embedding of the argmax as a cheap key in this reference
        # driver (examples/knnlm_serve.py uses the true pre-head hidden)
        h = params["embed"][tok].astype(jnp.float32)
        knn_logp = store.knn_logits(h, logits.shape[-1])
        mixed = mix_logits(logits, knn_logp, lam)
        tok = jnp.argmax(mixed, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
