"""kNN-LM serving: the SM-forest as a first-class LM-serving datastore.

Khandelwal et al.-style interpolation: the datastore maps hidden states
h_t -> observed next token; at each decode step we retrieve the k nearest
stored states and mix

    p(w) = (1 - lam) * p_LM(w) + lam * p_kNN(w),
    p_kNN(w) ∝ Σ_{(h_i, w_i=w)} exp(-d(h, h_i) / T)

The SM-tree is what makes the datastore *dynamic*: ``evict`` uses the
paper's Delete to drop stale entries online (sliding-window memory) — the
operation the original M-tree family could not support.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.engine import SMTreeEngine
from repro.models import model as M


@dataclasses.dataclass
class KnnLmConfig:
    k: int = 8
    lam: float = 0.25
    temperature: float = 1.0
    metric: str = "l2"
    capacity: int = 32
    max_frontier: int = 128


class KnnLmDatastore:
    """Single-host datastore over the JAX SM-tree engine (the sharded-forest
    variant lives in core/distributed.py and examples/distributed_index.py).
    Keys: hidden states [n, D]; values: next-token ids [n].

    With ``mesh`` set, tree pages are replicated over the mesh and query
    cohorts are sharded over the data axes (``dist.sharding.query_pspecs``),
    so the cohort descent runs data-parallel inside the same GSPMD program
    as the sharded decode step (``launch/serve.py --mesh host --knn``)."""

    def __init__(self, cfg: KnnLmConfig, dim: int, mesh=None):
        self.cfg = cfg
        self.dim = dim
        self.mesh = mesh
        self.keys = np.zeros((0, dim), np.float32)
        self.values = np.zeros((0,), np.int32)
        self._keys_buf = self.keys    # growth buffers (_append_history)
        self._vals_buf = self.values
        self.engine: SMTreeEngine | None = None
        self.stream = None   # repro.stream.StreamingEngine when enabled
        self.frontend = None  # serve.frontend.ServeFrontend when enabled
        self.ship_server = None   # stream.transport.WalShipServer
        self.replicas = []        # stream.transport.ShippedReplica
        self.router = None        # serve.router.ReplicaRouter

    def _place(self):
        """Replicate tree pages over the mesh (queries shard, pages don't)."""
        if self.mesh is not None and self.engine is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self.engine.tree = jax.device_put(
                self.engine.tree, NamedSharding(self.mesh, P()))

    def shard_queries(self, h: jax.Array) -> jax.Array:
        """Place a [b, D] query cohort according to ``query_pspecs``."""
        if self.mesh is None:
            return h
        from jax.sharding import NamedSharding
        from repro.dist.sharding import query_pspecs
        return jax.device_put(
            h, NamedSharding(self.mesh, query_pspecs(self.mesh, h.shape[0])))

    def build(self, keys: np.ndarray, values: np.ndarray):
        self.keys = np.asarray(keys, np.float32)
        self.values = np.asarray(values, np.int32)
        # invalidate any growth buffer from a previous build
        self._keys_buf = self.keys
        self._vals_buf = self.values
        self.engine = SMTreeEngine.build(
            self.keys, ids=np.arange(len(values)),
            capacity=self.cfg.capacity, metric=self.cfg.metric)
        self._place()

    def add(self, key: np.ndarray, value: int):
        oid = len(self.values)
        self._append_history(np.asarray(key, np.float32)[None],
                             np.asarray([value], np.int32))
        self.engine.insert(key, oid)
        self._place()   # host-side split paths rebuild arrays off-mesh

    def evict(self, oid: int) -> bool:
        """Online deletion — the paper's contribution at work."""
        return self.engine.delete(self.keys[oid], oid)

    def evict_before(self, oid_bound: int) -> int:
        """Sliding-window eviction: drop all entries with id < bound."""
        n = 0
        for oid in range(oid_bound):
            if self.evict(oid):
                n += 1
        return n

    # -- batched online mutation (repro.stream) -------------------------
    def enable_stream(self, wal_dir: str | None = None, *, shards: int = 0,
                      **kw):
        """Route ``add_batch``/``evict_batch`` through the repro.stream
        write pipeline: conflict-free cohort batching (one device dispatch
        per batch instead of one per entry) with optional WAL durability.
        Call after ``build``.

        With ``shards`` > 1 the store is re-partitioned round-robin into a
        ``StreamingForest`` instead of a single-tree engine: queries merge
        per-shard descents, and ``maintenance()`` (offered by the
        front-end scheduler after every mutation batch) repairs delete
        skew — incrementally when ``rebalance_mode='incremental'`` is
        passed through ``kw``."""
        from repro.stream import (StreamingEngine, StreamingForest,
                                  WriteAheadLog)
        wal = WriteAheadLog(wal_dir) if wal_dir else None
        if shards and shards > 1:
            if self.mesh is not None:
                raise ValueError(
                    "sharded streaming store is host-side; it does not "
                    "compose with the mesh-replicated query path")
            from repro.core.distributed import build_forest_trees
            trees = build_forest_trees(self.keys, int(shards),
                                       capacity=self.cfg.capacity,
                                       metric=self.cfg.metric)
            self.stream = StreamingForest(trees, wal=wal, **kw)
        else:
            self.stream = StreamingEngine(self.engine.tree, wal=wal, **kw)
        return self.stream

    def enable_frontend(self, **cfg):
        """Serve retrieval through the async front-end: queries coalesce
        into epoch-pinned cohorts, ``add_batch``/``evict_batch`` ride the
        mutation scheduler (applied between epoch publishes) instead of
        stalling the decode loop.  Requires ``enable_stream`` first."""
        if self.stream is None:
            raise ValueError("enable_stream() before enable_frontend()")
        from repro.serve.frontend import FrontendConfig, ServeFrontend
        cfg.setdefault("k", self.cfg.k)
        cfg.setdefault("max_frontier", self.cfg.max_frontier)
        self.frontend = ServeFrontend(self.stream,
                                      FrontendConfig(**cfg)).start()
        return self.frontend

    def close_frontend(self) -> None:
        if self.frontend is not None:
            self.frontend.stop()
            self.frontend = None
            self._sync_engine_tree()

    def enable_replication(self, mirror_root: str, *, n_replicas: int = 1,
                           host: str = "127.0.0.1", seed: int = 0):
        """Fan reads out to ``n_replicas`` socket-fed followers: a
        ``WalShipServer`` serves the stream's WAL directory, each replica
        mirrors it locally and replays through the identical pipeline,
        and a ``ReplicaRouter`` in front of the front-end routes queries
        (leader-first; bounded-staleness degraded reads if the leader
        dies).  Requires ``enable_stream(wal_dir=...)`` — replication is
        log shipping, there must be a log — and ``enable_frontend``.
        Followers start from the leader's currently *published* epoch and
        tail from there, so enabling mid-stream is safe."""
        if self.stream is None or self.stream.wal is None:
            raise ValueError("enable_stream(wal_dir=...) before "
                             "enable_replication()")
        if not hasattr(self.stream, "batcher"):
            raise ValueError(
                "socket replication here follows single-tree engines; "
                "forest-sharded stores replicate through "
                "stream.replica.Replica over a StreamingForest follower")
        if self.frontend is None:
            raise ValueError("enable_frontend() before enable_replication()")
        import os

        from repro.serve.router import ReplicaRouter
        from repro.stream import StreamingEngine
        from repro.stream.transport import ShippedReplica, WalShipServer
        wal = self.stream.wal
        self.ship_server = WalShipServer(wal.directory, host=host,
                                         wal=wal).start()
        start_seq = wal.next_seq - 1
        _, tree = self.stream.epochs.current()
        for i in range(n_replicas):
            follower = StreamingEngine(
                tree, wal=None, max_batch=self.stream.batcher.max_batch,
                headroom_frac=self.stream.headroom_frac)
            rep = ShippedReplica(
                follower, self.ship_server.address,
                os.path.join(mirror_root, f"replica_{i:02d}"),
                start_seq=start_seq, seed=seed + i)
            self.replicas.append(rep.start())
        self.router = ReplicaRouter(self.frontend, self.replicas,
                                    k=self.cfg.k,
                                    max_frontier=self.cfg.max_frontier)
        return self.router.start()

    def close_replication(self) -> None:
        if self.router is not None:
            self.router.stop()
            self.router = None
        for rep in self.replicas:
            rep.stop()
        self.replicas = []
        if self.ship_server is not None:
            self.ship_server.stop()
            self.ship_server = None

    def _sync_engine_tree(self) -> None:
        """Resync ``engine.tree`` from the *published* epoch — never from
        ``stream.tree``, which is the batcher's live working reference and
        can be mid-churn (half-applied cohorts of the current batch) when a
        concurrent scheduler thread is applying.  Non-stream readers of
        ``engine.tree`` (engine.knn/validate, ``_place``) must only ever
        observe epoch-published versions, same as the ``knn_logits``
        pinned-read path.  Forest epochs publish shard *tuples* — there is
        no single engine tree to resync, and every read path goes through
        the pinned-epoch merge instead."""
        if self.stream is not None:
            _, tree = self.stream.epochs.current()
            if not isinstance(tree, tuple):
                self.engine.tree = tree

    def _append_history(self, keys: np.ndarray, values: np.ndarray) -> None:
        """Amortised-O(1) append to the oid-indexed key/value history.

        ``self.keys``/``self.values`` stay plain dense arrays (oid indexes
        directly into them — evicted rows keep their slot), but growth goes
        through capacity doubling: a per-step ``np.vstack`` over the full
        history would make sustained ``--knn-mutate`` serving quadratic."""
        n, b = len(self.values), len(values)
        cap = len(self._keys_buf)
        if n + b > cap:
            new_cap = max(2 * cap, n + b, 1024)
            kb = np.zeros((new_cap, self.dim), np.float32)
            vb = np.zeros((new_cap,), np.int32)
            kb[:n] = self.keys
            vb[:n] = self.values
            self._keys_buf, self._vals_buf = kb, vb
        self._keys_buf[n:n + b] = keys
        self._vals_buf[n:n + b] = values
        self.keys = self._keys_buf[:n + b]
        self.values = self._vals_buf[:n + b]

    def add_batch(self, keys: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Insert a batch of (key, next-token) pairs; returns their oids.
        Under serving this is the live-growth path: each decode step's
        [b, D] hidden-state cohort lands in one batched apply."""
        keys = np.asarray(keys, np.float32)
        values = np.asarray(values, np.int32)
        oids = (len(self.values) + np.arange(len(values))).astype(np.int32)
        self._append_history(keys, values)
        if self.frontend is not None:
            from repro.core.smtree import OP_INSERT as _OP_I
            self.frontend.submit_mutations(
                np.full(len(oids), _OP_I, np.int32), keys, oids)
            self._sync_engine_tree()
        elif self.stream is not None:
            self.stream.insert_batch(keys, oids)
            self._sync_engine_tree()
        else:
            for k, o in zip(keys, oids):
                self.engine.insert(k, int(o))
        self._place()
        return oids

    def evict_batch(self, oids: np.ndarray) -> int:
        """Batched online eviction (sliding-window memory); returns the
        number of entries actually removed."""
        from repro.core.smtree import OP_DELETE as _OP_D, ST_APPLIED
        oids = np.asarray(oids, np.int32)
        if self.frontend is not None:
            # async: the scheduler applies between epoch publishes; the
            # count isn't known yet, so report the rows *submitted*
            self.frontend.submit_mutations(
                np.full(len(oids), _OP_D, np.int32), self.keys[oids], oids)
            self._sync_engine_tree()
            n = len(oids)
        elif self.stream is not None:
            res = self.stream.delete_batch(self.keys[oids], oids)
            self._sync_engine_tree()
            n = int((res.statuses == ST_APPLIED).sum())
        else:
            n = sum(self.evict(int(o)) for o in oids)
        self._place()
        return n

    def knn_logits(self, h: jax.Array, vocab: int) -> jax.Array:
        """h: [b, D] query hidden states -> kNN log-probs [b, vocab].

        With streaming enabled the descent runs against a *pinned* epoch
        (``EpochManager.reading``), so a concurrent ``add_batch`` /
        ``evict_batch`` writer can publish and retire versions without ever
        dropping the tree this query is descending."""
        if self.frontend is not None:
            # coalesced path: the decode step's [b, D] block is admitted
            # as b tickets and lands in one epoch-pinned cohort alongside
            # any other concurrent traffic
            d, ids = self.frontend.knn(np.asarray(h, np.float32))
        elif self.stream is not None:
            from repro import obs
            from repro.core import smtree
            with self.stream.epochs.reading() as tree:
                if isinstance(tree, tuple):
                    # forest epoch: per-shard cohort descent + host top-k
                    # merge, shared with the front-end read path
                    from repro.serve.frontend import pinned_knn
                    d, ids = pinned_knn(tree, np.asarray(h, np.float32),
                                        k=self.cfg.k,
                                        max_frontier=self.cfg.max_frontier)
                elif obs.want_level_stats():
                    res, pruned = smtree.knn(
                        tree, self.shard_queries(h), k=self.cfg.k,
                        max_frontier=self.cfg.max_frontier,
                        level_stats=True)
                    obs.observe_query_result(res, pruned)
                    d, ids = res.dists, np.asarray(res.ids)
                else:
                    res = smtree.knn(tree, self.shard_queries(h),
                                     k=self.cfg.k,
                                     max_frontier=self.cfg.max_frontier)
                    d, ids = res.dists, np.asarray(res.ids)
        else:
            from repro import obs
            res = self.engine.knn(self.shard_queries(h), k=self.cfg.k,
                                  max_frontier=self.cfg.max_frontier)
            if obs.want_level_stats():      # sampled, like the tree paths
                obs.observe_query_result(res)
            d, ids = res.dists, np.asarray(res.ids)       # [b, k]
        vals = jnp.asarray(np.where(ids >= 0, self.values[np.maximum(ids, 0)],
                                    0))
        w = jax.nn.softmax(jnp.where(jnp.isfinite(d),
                                     -d / self.cfg.temperature, -jnp.inf), -1)
        b = h.shape[0]
        probs = jnp.zeros((b, vocab), jnp.float32)
        probs = probs.at[jnp.arange(b)[:, None], vals].add(
            jnp.where(jnp.isfinite(d), w, 0.0))
        return jnp.log(jnp.maximum(probs, 1e-10))


def mix_logits(lm_logits: jax.Array, knn_logp: jax.Array, lam: float):
    """log((1-lam) p_LM + lam p_kNN) computed stably."""
    lm_logp = jax.nn.log_softmax(lm_logits.astype(jnp.float32), -1)
    return jnp.logaddexp(lm_logp + jnp.log1p(-lam), knn_logp + jnp.log(lam))


def decode_with_knnlm(params, cfg: ArchConfig, store: KnnLmDatastore,
                      prompt: jax.Array, n_steps: int, *, lam=None):
    """Greedy decode with kNN-LM mixing; also streams (h, next_token) pairs
    back into the datastore (online growth).  prompt: [b, s0]."""
    lam = lam if lam is not None else store.cfg.lam
    b, s0 = prompt.shape
    cache = M.init_cache(cfg, b, s0 + n_steps + 1)
    tok = prompt[:, 0]
    h_tap = {}

    # feed the prompt
    for pos in range(s0):
        logits, cache = M.decode_step(params, cfg, prompt[:, pos], cache,
                                      jnp.int32(pos))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(n_steps):
        pos = s0 + step
        logits, cache = M.decode_step(params, cfg, tok, cache, jnp.int32(pos))
        # final hidden state proxy: use logits projected back is costly; we
        # tap the embedding of the argmax as a cheap key in this reference
        # driver (examples/knnlm_serve.py uses the true pre-head hidden)
        h = params["embed"][tok].astype(jnp.float32)
        knn_logp = store.knn_logits(h, logits.shape[-1])
        mixed = mix_logits(logits, knn_logp, lam)
        tok = jnp.argmax(mixed, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
