"""Async serving front-end: admission queue + cohort scheduler.

The engine's cohort descent is 6-7x faster than per-request dispatch
(BENCH_PR2/PR5), but only a caller that already *has* a [b, dim] batch can
reach it.  This module forms those batches from independent clients:

  * **Admission queue** — clients ``submit()`` single queries (or
    ``submit_many`` a block) and get tickets; a dispatcher thread coalesces
    pending requests into **fixed-geometry cohorts** under a latency SLO.
    The dispatch rule is *deadline-or-batch-full*: a cohort launches the
    moment ``cohort_width`` requests are waiting, or when the oldest
    admitted request has been queued for ``slo_ms`` — whichever comes
    first.  Cohorts are always padded to ``cohort_width`` (pad rows are
    zero queries whose results are discarded), so **one jitted geometry
    serves all traffic** — no per-burst-size recompiles, ever.
  * **Epoch pinning** — each cohort runs under the existing
    ``EpochManager.reading()`` contract: the snapshot is pinned before the
    descent starts and released after results are sliced out, so a
    concurrent writer can publish and retire epochs freely and no query
    ever observes a tree swap mid-cohort.  Every ticket records the epoch
    that answered it.
  * **Cohort scheduler** — mutation batches go through a second queue
    drained by a writer thread that applies them via the engine's
    WAL-first ``apply`` (each apply ends in an epoch publish).  Queries
    never block on a mutation batch: reads come from pinned epochs on the
    dispatcher thread while the writer churns the next version.  This
    replaces the alternating query/mutate loop ``launch/serve.py`` ran
    before: mutations now ride behind serving instead of stalling it.

Works over a ``StreamingEngine`` (single tree) or ``StreamingForest``
(pinned epoch = tuple of shard trees; per-shard descent + host top-k
merge, the same read path ``StreamingForest.knn`` uses).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro import obs
from repro.core import smtree

__all__ = ["FrontendConfig", "FrontendStats", "QueryTicket",
           "MutationTicket", "QueueFull", "ServeFrontend", "pinned_knn"]


class QueueFull(RuntimeError):
    """Admission rejected: the queue is at capacity and the front-end is
    configured to shed rather than block.  ``retry_after_s`` is a hint —
    the time the current backlog needs to drain at the configured cohort
    cadence — suitable for a Retry-After header or client backoff."""

    def __init__(self, msg: str, *, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class FrontendConfig:
    cohort_width: int = 64    # fixed dispatch geometry (pad-to-width)
    slo_ms: float = 5.0       # max queue age before a partial cohort ships
    k: int = 8
    max_frontier: int = 64
    queue_cap: int = 4096     # admission bound (blocks or sheds when full)
    mutation_queue_cap: int = 1024  # mutation backlog bound
    # "block": a full queue stalls the submitter (in-process callers, the
    # historical behaviour).  "shed": raise QueueFull with a retry-after
    # hint — the right shape in front of a network, where a blocked
    # socket just moves the unbounded queue into the kernel.
    overload: str = "block"
    # scheduler slot for background repair: after each mutation batch the
    # daemon offers the engine one bounded ``maintenance()`` call (a
    # forest runs at most one migration step per offer, so the repair
    # work amortizes across the mutation stream instead of cliffing)
    maintenance: bool = True


def pinned_knn(pinned, queries: np.ndarray, *, k: int, max_frontier: int):
    """kNN over one pinned epoch: a single tree, or a tuple of forest
    shards (per-shard cohort descent + host top-k merge — the forest read
    path, shared here so the front-end serves both layouts).

    With observability on, a 1/``obs.LEVEL_STATS_EVERY`` sample of
    dispatches runs the level-stats descent variant (a separate jit
    cache entry — default geometry untouched) and accumulates the paper
    counters: queries, distance evals, nodes visited, pruned-by-bound
    per level.  Sampling the whole counter path — denominator included —
    keeps per-query averages unbiased while the other 15/16 dispatches
    pay nothing (no device fetches for the reduction arrays)."""
    if not isinstance(pinned, (tuple, list)):
        pinned = (pinned,)
    on = obs.enabled()
    ds, ids = [], []
    for t in pinned:
        if on and obs.want_level_stats():
            res, pruned = smtree.knn(t, queries, k=k,
                                     max_frontier=max_frontier,
                                     level_stats=True)
            obs.observe_query_result(res, pruned)
        else:
            res = smtree.knn(t, queries, k=k, max_frontier=max_frontier)
        ds.append(np.asarray(res.dists))
        ids.append(np.asarray(res.ids))
    d = np.concatenate(ds, axis=1)
    i = np.concatenate(ids, axis=1)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(d, order, 1), np.take_along_axis(i, order, 1)


class QueryTicket:
    """One admitted query.  ``result()`` blocks until its cohort ran.

    ``span`` is the ticket's root trace span ("frontend.query"), opened
    at admission and ended when the cohort stamps results; the shared
    no-op span when observability is off or head sampling skipped this
    ticket (``obs.set_trace_sampling``).  ``trace_id`` (None when not
    traced) lets callers correlate the ticket across layers."""
    __slots__ = ("q", "t_submit", "t_done", "epoch", "dists", "ids", "err",
                 "span", "_event")

    def __init__(self, q: np.ndarray, trace_ctx=None):
        self.q = q
        self.t_submit = time.monotonic()
        self.t_done = None
        self.epoch = None        # epoch number the cohort was pinned to
        self.dists = None        # [k] f32
        self.ids = None          # [k] i32
        self.err = None
        # sample_root() decides head sampling without the start_span
        # kwargs call — the unsampled majority of tickets pays one
        # cheap predicate, not a span-construction attempt
        if trace_ctx is not None or obs.sample_root():
            self.span = obs.start_span("frontend.query", parent=trace_ctx)
        else:
            self.span = obs.NULL_SPAN
        self._event = threading.Event()

    @property
    def trace_id(self):
        return self.span.trace_id

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """(dists [k], ids [k]) — raises the cohort's error, if any."""
        if not self._event.wait(timeout):
            raise TimeoutError("query ticket not served within timeout")
        if self.err is not None:
            raise self.err
        return self.dists, self.ids

    @property
    def latency_s(self) -> float:
        return (self.t_done or time.monotonic()) - self.t_submit


class MutationTicket:
    """One queued mutation batch; resolves to its ``BatchResult``."""
    __slots__ = ("ops", "xs", "oids", "res", "err", "span", "_event")

    def __init__(self, ops, xs, oids, trace_ctx=None):
        self.ops, self.xs, self.oids = ops, xs, oids
        self.res = None
        self.err = None
        self.span = obs.start_span("frontend.mutation", parent=trace_ctx)
        self._event = threading.Event()

    @property
    def trace_id(self):
        return self.span.trace_id

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("mutation batch not applied within timeout")
        if self.err is not None:
            raise self.err
        return self.res


@dataclasses.dataclass
class FrontendStats:
    """Serving counters (updated under the front-end lock)."""
    n_queries: int = 0
    n_cohorts: int = 0
    n_full_dispatch: int = 0      # cohorts shipped because width was reached
    n_deadline_dispatch: int = 0  # cohorts shipped by the SLO deadline
    n_mutation_batches: int = 0
    n_maintenance: int = 0        # maintenance slots that did repair work
    n_shed: int = 0               # admissions rejected with QueueFull
    queue_depth: int = 0          # gauges, updated on every queue touch
    mutation_queue_depth: int = 0
    fill_sum: int = 0             # real (unpadded) rows across cohorts
    # fixed-bucket histogram, not a sample list: O(n_buckets) memory
    # forever under sustained load.  Constructed standalone (always-on),
    # because snapshot()/latency_ms feed the bench gate with obs off.
    latency_hist: obs.Histogram = dataclasses.field(
        default_factory=lambda: obs.Histogram(
            "frontend.latency_s", obs.LATENCY_BUCKETS_S))

    def observe_cohort(self, fill: int, full: bool, lats) -> None:
        self.n_cohorts += 1
        self.n_queries += fill
        self.fill_sum += fill
        if full:
            self.n_full_dispatch += 1
        else:
            self.n_deadline_dispatch += 1
        self.latency_hist.observe_many(lats)

    def publish(self, fill: int, full: bool) -> None:
        """Export the cohort's registry metrics.  Split from
        ``observe_cohort`` so the dispatcher can call it *outside* the
        front-end's condition lock — registry work must not extend the
        critical section admitting submitters."""
        if not obs.enabled():
            return
        obs.counter("frontend.queries_total").inc(fill)
        obs.counter("frontend.cohorts_total").inc()
        obs.counter("frontend.full_dispatch_total" if full
                    else "frontend.deadline_dispatch_total").inc()
        obs.gauge("frontend.queue_depth").set(self.queue_depth)
        obs.gauge("frontend.mean_cohort_fill").set(self.mean_fill)
        # the always-on latency_hist already saw every sample; adopting
        # it into the registry exports it without paying a second
        # 64-observe pass per cohort
        obs.REGISTRY.register(self.latency_hist)

    @property
    def mean_fill(self) -> float:
        return self.fill_sum / max(1, self.n_cohorts)

    def latency_ms(self, pct: float) -> float:
        if self.latency_hist.count == 0:
            return float("nan")
        return self.latency_hist.percentile(pct) * 1e3

    def snapshot(self) -> dict:
        return {"n_queries": self.n_queries, "n_cohorts": self.n_cohorts,
                "n_full_dispatch": self.n_full_dispatch,
                "n_deadline_dispatch": self.n_deadline_dispatch,
                "n_mutation_batches": self.n_mutation_batches,
                "n_shed": self.n_shed,
                "queue_depth": self.queue_depth,
                "mutation_queue_depth": self.mutation_queue_depth,
                "mean_cohort_fill": round(self.mean_fill, 2),
                "p50_ms": round(self.latency_ms(50), 3),
                "p99_ms": round(self.latency_ms(99), 3)}


class ServeFrontend:
    """Admission queue + cohort scheduler over a streaming engine/forest.

    ``engine`` must expose ``.epochs`` (an ``EpochManager``) and
    ``.apply(ops, xs, oids)`` (the WAL-first batch apply that publishes an
    epoch) — both ``StreamingEngine`` and ``StreamingForest`` qualify.
    ``knn_fn(pinned, queries) -> (dists [b,k], ids [b,k])`` overrides the
    default pinned descent (``pinned_knn``).

    Use as a context manager, or ``start()``/``stop()`` explicitly::

        with ServeFrontend(eng, FrontendConfig(cohort_width=64)) as fe:
            d, i = fe.knn(queries)            # coalesced, epoch-pinned
            fe.submit_mutations(ops, xs, oids)  # rides behind serving
    """

    def __init__(self, engine, cfg: FrontendConfig | None = None, *,
                 knn_fn=None):
        self.engine = engine
        self.cfg = cfg or FrontendConfig()
        if self.cfg.cohort_width < 1:
            raise ValueError("cohort_width must be >= 1")
        self._knn_fn = knn_fn or (lambda pinned, q: pinned_knn(
            pinned, q, k=self.cfg.k, max_frontier=self.cfg.max_frontier))
        self.stats = FrontendStats()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: list[QueryTicket] = []
        self._mutations: list[MutationTicket] = []
        self._inflight = 0            # queries taken off the queue, not done
        self._mut_inflight = 0
        self._running = False
        self._threads: list[threading.Thread] = []

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ServeFrontend":
        with self._lock:
            if self._running:
                return self
            self._running = True
        self._threads = [
            threading.Thread(target=self._dispatch_loop,
                             name="frontend-dispatch", daemon=True),
            threading.Thread(target=self._mutation_loop,
                             name="frontend-mutate", daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop the worker threads.  ``drain=True`` (default) serves every
        admitted request and applies every queued mutation first; False
        fails the leftovers with a RuntimeError."""
        if drain and self._running:
            self.drain()
        with self._cond:
            self._running = False
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=30)
        self._threads = []
        with self._cond:
            leftovers = self._queue + self._mutations
            self._queue, self._mutations = [], []
        for tk in leftovers:
            tk.err = RuntimeError("front-end stopped before dispatch")
            tk._event.set()

    def __enter__(self) -> "ServeFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    def drain(self, timeout: float | None = None) -> None:
        """Block until both queues are empty and nothing is in flight."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while (self._queue or self._mutations or self._inflight
                   or self._mut_inflight):
                left = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                if left == 0.0:
                    raise TimeoutError("front-end did not drain in time")
                self._cond.wait(left if left is not None else 0.1)

    # -- admission ---------------------------------------------------------
    def _retry_after_s(self, depth: int) -> float:
        """Drain-time hint for a shed client: the backlog in cohorts,
        paced at one SLO window per cohort (the dispatcher's worst-case
        cadence — it runs faster when cohorts fill early)."""
        cohorts = max(1, -(-depth // self.cfg.cohort_width))
        return cohorts * self.cfg.slo_ms / 1e3

    def submit(self, q: np.ndarray, *, trace_ctx=None) -> QueryTicket:
        """Admit one query [dim]; returns its ticket.  At ``queue_cap``
        the configured overload policy applies: ``"block"`` stalls the
        caller until space frees (backpressure), ``"shed"`` raises
        :class:`QueueFull` with a retry-after hint instead of letting the
        backlog — and every admitted request's latency — grow without
        bound.  ``trace_ctx`` parents the ticket's trace span on an
        upstream caller (the router's per-read span)."""
        if not self._running:
            raise RuntimeError("front-end not started")
        tk = QueryTicket(np.asarray(q, np.float32), trace_ctx)
        with self._cond:
            if (self.cfg.overload == "shed"
                    and len(self._queue) >= self.cfg.queue_cap):
                self.stats.n_shed += 1
                if obs.enabled():
                    obs.counter("frontend.shed_total").inc()
                    obs.record_event("frontend.shed", queue="query",
                                     depth=len(self._queue))
                tk.span.end(error="QueueFull")
                raise QueueFull(
                    f"admission queue at cap ({self.cfg.queue_cap})",
                    retry_after_s=self._retry_after_s(len(self._queue)))
            while len(self._queue) >= self.cfg.queue_cap and self._running:
                self._cond.wait(0.05)
            if not self._running:
                raise RuntimeError("front-end stopped")
            self._queue.append(tk)
            self.stats.queue_depth = len(self._queue)
            self._cond.notify_all()
        return tk

    def submit_many(self, qs: np.ndarray) -> list[QueryTicket]:
        """Admit a [b, dim] block as b tickets (they coalesce like any
        other traffic — a b <= width block from one client usually lands
        in a single cohort)."""
        return [self.submit(q) for q in np.asarray(qs, np.float32)]

    def knn(self, qs: np.ndarray, timeout: float | None = 60.0):
        """Synchronous convenience: admit [b, dim], wait, return
        (dists [b, k], ids [b, k])."""
        tickets = self.submit_many(qs)
        out = [t.result(timeout) for t in tickets]
        return (np.stack([d for d, _ in out]),
                np.stack([i for _, i in out]))

    def submit_mutations(self, ops, xs, oids, *,
                         trace_ctx=None) -> MutationTicket:
        """Queue one mutation batch for the scheduler; returns a ticket
        resolving to its ``BatchResult``.  Fire-and-forget callers simply
        drop the ticket — ``drain()``/``stop()`` still applies it.  The
        backlog is bounded by ``mutation_queue_cap`` under the same
        overload policy as queries (an unbounded write queue is the
        classic way a slow apply path eats the heap)."""
        if not self._running:
            raise RuntimeError("front-end not started")
        tk = MutationTicket(np.asarray(ops, np.int32),
                            np.asarray(xs, np.float32),
                            np.asarray(oids, np.int32), trace_ctx)
        with self._cond:
            if (self.cfg.overload == "shed"
                    and len(self._mutations) >= self.cfg.mutation_queue_cap):
                self.stats.n_shed += 1
                if obs.enabled():
                    obs.counter("frontend.shed_total").inc()
                    obs.record_event("frontend.shed", queue="mutation",
                                     depth=len(self._mutations))
                tk.span.end(error="QueueFull")
                raise QueueFull(
                    f"mutation queue at cap "
                    f"({self.cfg.mutation_queue_cap})",
                    retry_after_s=self._retry_after_s(len(self._mutations)))
            while (len(self._mutations) >= self.cfg.mutation_queue_cap
                   and self._running):
                self._cond.wait(0.05)
            if not self._running:
                raise RuntimeError("front-end stopped")
            self._mutations.append(tk)
            self.stats.mutation_queue_depth = len(self._mutations)
            self._cond.notify_all()
        return tk

    # -- dispatcher (query cohorts) ---------------------------------------
    def _dispatch_loop(self) -> None:
        W = self.cfg.cohort_width
        slo_s = self.cfg.slo_ms / 1e3
        while True:
            with self._cond:
                while not self._queue and self._running:
                    self._cond.wait(0.05)
                if not self._queue:
                    return                      # stopped and empty
                # deadline-or-batch-full: wait for a full cohort only
                # until the oldest admitted request hits the SLO
                deadline = self._queue[0].t_submit + slo_s
                while len(self._queue) < W and self._running:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cond.wait(left)
                batch = self._queue[:W]
                del self._queue[:len(batch)]
                self._inflight += len(batch)
                self.stats.queue_depth = len(self._queue)
                self._cond.notify_all()
            self._run_cohort(batch, full=len(batch) == W)

    def _run_cohort(self, batch: list[QueryTicket], *, full: bool) -> None:
        W = self.cfg.cohort_width
        n = len(batch)
        # Cohort fan-in: the cohort span parents on the first *traced*
        # member ticket and *links* every other traced member's
        # trace_id, so each sampled ticket's trace reaches the shared
        # pin/compute spans.  Head sampling means most tickets carry
        # NULL_SPAN; a cohort with no traced member skips the cohort-
        # side spans entirely.
        cspan = obs.NULL_SPAN
        if obs.enabled():
            members = [tk for tk in batch if tk.span is not obs.NULL_SPAN]
            if members:
                cspan = obs.start_span(
                    "frontend.cohort", parent=members[0].span.ctx,
                    links=tuple(tk.span.trace_id for tk in members[1:]),
                    fill=n, width=W, full=full)
        traced = cspan is not obs.NULL_SPAN
        try:
            dim = batch[0].q.shape[-1]
            Q = np.zeros((W, dim), np.float32)   # pad-to-width: one geometry
            for r, tk in enumerate(batch):
                Q[r] = tk.q
            pin = (obs.start_span("frontend.epoch_pin", parent=cspan.ctx)
                   if traced else obs.NULL_SPAN)
            with self.engine.epochs.reading(with_epoch=True) as (e, pinned):
                pin.end(epoch=e)
                comp = (obs.start_span("frontend.device_compute",
                                       parent=cspan.ctx)
                        if traced else obs.NULL_SPAN)
                d, ids = self._knn_fn(pinned, Q)
                comp.end()
            reply = (obs.start_span("frontend.reply", parent=cspan.ctx)
                     if traced else obs.NULL_SPAN)
            d, ids = np.asarray(d)[:n], np.asarray(ids)[:n]
            t_done = time.monotonic()
            for r, tk in enumerate(batch):
                tk.dists, tk.ids, tk.epoch = d[r], ids[r], e
                tk.t_done = t_done
            reply.end()
        except Exception as exc:  # noqa: BLE001 — fail the cohort's tickets
            cspan.set(error=type(exc).__name__)
            for tk in batch:
                tk.err = exc
        finally:
            cspan.end()
            for tk in batch:
                if tk.span is not obs.NULL_SPAN:
                    if tk.err is not None:
                        tk.span.set(error=type(tk.err).__name__)
                    tk.span.end(epoch=tk.epoch)
                tk._event.set()
            with self._cond:
                self._inflight -= n
                self.stats.observe_cohort(
                    n, full,
                    [tk.latency_s for tk in batch if tk.err is None])
                self._cond.notify_all()
            self.stats.publish(n, full)

    # -- scheduler (mutation batches) -------------------------------------
    def _mutation_loop(self) -> None:
        while True:
            with self._cond:
                while not self._mutations and self._running:
                    self._cond.wait(0.05)
                if not self._mutations:
                    return                      # stopped and empty
                tk = self._mutations.pop(0)
                self._mut_inflight += 1
                self.stats.mutation_queue_depth = len(self._mutations)
            try:
                # the engine's WAL-first apply; ends in an epoch publish,
                # so the batch becomes visible to the *next* cohort pin —
                # in-flight cohorts keep their pinned snapshot.  The span
                # becomes the thread-local current, so the engine's
                # wal.append/apply/publish child spans attach to it.
                with obs.span("frontend.mutation_batch",
                              parent=tk.span.ctx, n=len(tk.ops)):
                    tk.res = self.engine.apply(tk.ops, tk.xs, tk.oids)
            except Exception as exc:  # noqa: BLE001 — fail the ticket
                tk.err = exc
                if tk.span is not obs.NULL_SPAN:
                    tk.span.set(error=type(exc).__name__)
            else:
                # scheduler slot: one bounded repair offer per applied
                # batch, on this same single-writer thread (migration
                # steps and mutation batches must serialize — both mutate
                # the trees, and the WAL order is the replay contract).
                # A repair failure is recorded as a fault, not surfaced on
                # the user's ticket — their batch already applied.
                if self.cfg.maintenance:
                    try:
                        maint = getattr(self.engine, "maintenance", None)
                        if maint is not None and maint():
                            with self._cond:
                                self.stats.n_maintenance += 1
                    except Exception as exc:  # noqa: BLE001
                        obs.record_fault("frontend.maintenance", exc)
            finally:
                tk.span.end()
                tk._event.set()
                with self._cond:
                    self._mut_inflight -= 1
                    self.stats.n_mutation_batches += 1
                    if obs.enabled():
                        obs.counter("frontend.mutation_batches_total").inc()
                        obs.gauge("frontend.mutation_queue_depth").set(
                            len(self._mutations))
                    self._cond.notify_all()
