"""Replica-aware query routing with graceful degradation.

``ServeFrontend`` coalesces queries against *one* engine; this router sits
in front of it and decides **which** engine answers — the leader (fresh
reads, the only write path) or a replica (scale-out reads, and the only
reads left when the leader is gone).  Three explicit modes, stamped on
every ticket so clients and dashboards see exactly what they got:

  * ``"leader"``   — routed through the leader's front-end; linearizable
    with the write stream (reads pin the epoch the last apply published).
  * ``"replica"``  — a healthy-leader read served from a follower for
    fan-out; only chosen when the follower satisfies the caller's session
    token, so it is still read-your-writes fresh *for that caller*.
  * ``"degraded"`` — the leader is unreachable (heartbeat misses over the
    limit): reads continue from the best-caught-up replica under an
    explicit **bounded-staleness contract** — the ticket carries
    ``staleness`` (records behind the leader's last acknowledged seq) and
    the router refuses replicas beyond ``max_staleness``.  Writes fail
    fast with ``LeaderUnavailable`` (retryable after failover) instead of
    queueing into a void.

**Read-your-writes** is a session property, not a global one: every
acknowledged write returns an updated :class:`SessionToken` ``(epoch,
wal_seq)``; a read carrying that token is only served by an engine whose
applied seq has reached ``wal_seq`` (the leader trivially qualifies).  A
token is a *floor*, so tokens from different sessions compose by max.

Failure detection is heartbeat-based and injectable: the monitor thread
calls ``ping()`` every interval (``stream.faults.FaultInjector.
drop_heartbeat`` starves deliveries in tests), and ``miss_limit``
consecutive misses flip the leader to down — reads degrade, writes bounce.
A later successful ping (or an explicit ``set_leader`` after
``stream.lease.promote``) flips it back.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro import obs
from repro.serve.frontend import pinned_knn

__all__ = ["SessionToken", "LeaderUnavailable", "StaleReplica",
           "RouterTicket", "ReplicaRouter"]


class LeaderUnavailable(ConnectionError):
    """No leader to write to (heartbeats lapsed, or none configured).
    Retryable: after ``stream.lease.promote`` a new leader is installed
    via ``set_leader`` and the same write succeeds."""


class StaleReplica(RuntimeError):
    """No replica satisfies the read's freshness bound — the session
    token demands records no reachable replica has applied, or every
    replica exceeds ``max_staleness`` while degraded."""


@dataclasses.dataclass(frozen=True)
class SessionToken:
    """Read-your-writes floor: the reader must observe state at least as
    new as ``(epoch, wal_seq)``.  Returned by every acknowledged write;
    pass the latest one to subsequent reads from the same session."""
    epoch: int = -1
    wal_seq: int = -1

    def merge(self, other: "SessionToken") -> "SessionToken":
        return SessionToken(epoch=max(self.epoch, other.epoch),
                            wal_seq=max(self.wal_seq, other.wal_seq))


class RouterTicket:
    """One routed read: result plus the routing facts — ``mode``
    ("leader" | "replica" | "degraded"), ``staleness`` (records behind
    the leader's last acknowledged seq at serve time; 0 on the leader),
    and the ``epoch`` pinned for the answer.  ``trace_id`` correlates the
    read across router/frontend/replica spans (None with obs off)."""
    __slots__ = ("mode", "staleness", "epoch", "dists", "ids", "err",
                 "trace_id", "_inner", "_event")

    def __init__(self, *, mode: str, staleness: int, trace_id=None):
        self.mode = mode
        self.staleness = staleness
        self.epoch = None
        self.dists = None
        self.ids = None
        self.err = None
        self.trace_id = trace_id
        self._inner = None            # leader-mode QueryTicket
        self._event = threading.Event()

    def done(self) -> bool:
        return (self._inner.done() if self._inner is not None
                else self._event.is_set())

    def result(self, timeout: float | None = None):
        """(dists [k], ids [k]) — raises the serve-path error, if any."""
        if self._inner is not None:
            d, i = self._inner.result(timeout)
            self.dists, self.ids, self.epoch = d, i, self._inner.epoch
            return d, i
        if not self._event.wait(timeout):
            raise TimeoutError("routed query not served within timeout")
        if self.err is not None:
            raise self.err
        return self.dists, self.ids


class ReplicaRouter:
    """Routes reads across a leader front-end and a set of replicas.

    ``leader`` is a started ``ServeFrontend`` (or None when leaderless —
    e.g. between a crash and a promotion).  ``replicas`` expose
    ``epochs`` / ``applied_seq`` / ``lag`` — ``stream.replica.Replica``
    and ``stream.transport.ShippedReplica`` both qualify.  ``ping`` is
    the leader liveness probe (default: the front-end reports itself
    running); ``fault`` threads the seeded chaos harness through the
    failure detector.

    ``prefer_replicas=True`` sends session-satisfying reads to replicas
    even while the leader is healthy (read fan-out); default is
    leader-first, replicas only on degradation.
    """

    def __init__(self, leader, replicas=(), *, ping=None,
                 fault=None, heartbeat_interval_s: float = 0.05,
                 miss_limit: int = 3, max_staleness: int | None = None,
                 prefer_replicas: bool = False, k: int = 8,
                 max_frontier: int = 64):
        self._leader = leader
        self.replicas = list(replicas)
        self._ping = ping
        self.fault = fault
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.miss_limit = int(miss_limit)
        self.max_staleness = max_staleness
        self.prefer_replicas = prefer_replicas
        self.k = k
        self.max_frontier = max_frontier
        self._lock = threading.Lock()
        self._misses = 0
        self._leader_up = leader is not None
        # monotonic time of the last successful heartbeat (None = never):
        # the snapshot's time_since_heartbeat_s gauge derives from this,
        # so recovery (a fresh ping / set_leader) resets it naturally
        self._last_ok_t = time.monotonic() if leader is not None else None
        self._running = False
        self._thread: threading.Thread | None = None
        self.n_heartbeats = 0
        self.n_heartbeat_misses = 0
        self.n_degraded_reads = 0
        self.n_replica_reads = 0
        self.n_leader_reads = 0

    # -- leader membership -------------------------------------------------
    @property
    def leader(self):
        with self._lock:
            return self._leader

    @property
    def leader_up(self) -> bool:
        with self._lock:
            return self._leader is not None and self._leader_up

    def set_leader(self, frontend) -> None:
        """Install a (newly promoted) leader front-end; resets the
        failure detector.  ``None`` declares the cluster leaderless."""
        with self._lock:
            was_up = self._leader_up and self._leader is not None
            self._leader = frontend
            self._leader_up = frontend is not None
            self._misses = 0
            if frontend is not None:
                self._last_ok_t = time.monotonic()
        if frontend is not None and not was_up:
            obs.record_event("router.leader_installed")

    def mark_leader_down(self) -> None:
        """Out-of-band failure signal (a write path saw a hard error)."""
        with self._lock:
            self._leader_up = False

    # -- failure detection -------------------------------------------------
    def _default_ping(self) -> bool:
        fe = self._leader
        return bool(fe is not None and getattr(fe, "_running", False))

    def heartbeat(self) -> bool:
        """One detector step; returns the post-step leader_up verdict.
        A starved delivery (fault injection, or a real timeout modelled
        by ``ping`` raising/returning False) counts as a miss; misses
        are consecutive — one success resets."""
        self.n_heartbeats += 1
        delivered = not (self.fault is not None
                         and self.fault.drop_heartbeat())
        ok = False
        if delivered:
            try:
                ok = bool((self._ping or self._default_ping)())
            except Exception:  # noqa: BLE001 — probe failure is a miss
                ok = False
        flip = None
        with self._lock:
            was_up = self._leader_up and self._leader is not None
            if ok:
                self._misses = 0
                self._last_ok_t = time.monotonic()
                if self._leader is not None:
                    self._leader_up = True
            else:
                self._misses += 1
                self.n_heartbeat_misses += 1
                if self._misses >= self.miss_limit:
                    self._leader_up = False
            now_up = self._leader_up and self._leader is not None
            if now_up != was_up:
                flip = ("router.leader_recovered" if now_up
                        else "router.leader_down")
            misses = self._misses
        if obs.enabled():
            obs.counter("router.heartbeats_total").inc()
            if not ok:
                obs.counter("router.heartbeat_misses_total").inc()
            obs.gauge("router.leader_up").set(1.0 if now_up else 0.0)
            obs.gauge("router.consecutive_misses").set(misses)
            if flip is not None:
                obs.record_event(flip, misses=misses)
        return now_up

    def start(self) -> "ReplicaRouter":
        """Run the failure detector on a daemon thread."""
        if self._running:
            return self
        self._running = True

        def monitor():
            while self._running:
                self.heartbeat()
                time.sleep(self.heartbeat_interval_s)

        self._thread = threading.Thread(target=monitor, name="router-hb",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- writes ------------------------------------------------------------
    def mutate(self, ops, xs, oids, *, timeout: float | None = 60.0):
        """Apply one mutation batch through the leader; returns
        ``(BatchResult, SessionToken)`` — the token is the caller's new
        read-your-writes floor.  Raises :class:`LeaderUnavailable` when
        there is no live leader (fail fast; retry after failover)."""
        with self._lock:
            fe = self._leader if self._leader_up else None
        if fe is None:
            raise LeaderUnavailable(
                "no live leader to accept writes (degraded mode serves "
                "reads only) — retry after failover")
        mspan = obs.start_span("router.mutate", n=len(ops))
        try:
            tk = fe.submit_mutations(ops, xs, oids, trace_ctx=mspan.ctx)
            res = tk.result(timeout)
        except LeaderUnavailable:
            mspan.end(error="LeaderUnavailable")
            raise
        except (RuntimeError, ConnectionError) as e:
            # a hard apply error (fenced-out deposed leader, stopped
            # front-end) flips the detector immediately — waiting for
            # heartbeat misses would bounce more writes for no reason
            mspan.end(error=type(e).__name__)
            if type(e).__name__ in ("FencedOut",) \
                    or "stopped" in str(e).lower():
                self.mark_leader_down()
                obs.record_event("router.leader_marked_down",
                                 reason=type(e).__name__)
                raise LeaderUnavailable(f"leader lost mid-write: {e}") from e
            raise
        mspan.end()
        eng = fe.engine
        seq = eng.wal.next_seq - 1 if eng.wal is not None else -1
        with eng.epochs.reading(with_epoch=True) as (epoch, _):
            token = SessionToken(epoch=epoch, wal_seq=seq)
        return res, token

    # -- reads -------------------------------------------------------------
    def _replica_view(self):
        """(replica, applied_seq, staleness) triples, freshest first."""
        views = []
        for r in self.replicas:
            views.append((r, int(r.applied_seq), int(r.lag)))
        views.sort(key=lambda v: v[1], reverse=True)
        return views

    def _serve_from(self, ticket: RouterTicket, replica, q: np.ndarray,
                    parent=None):
        span = obs.start_span("router.replica_serve", parent=parent,
                              sampled=True,
                              mode=ticket.mode, staleness=ticket.staleness)
        try:
            with replica.epochs.reading(with_epoch=True) as (e, pinned):
                d, i = pinned_knn(pinned, q[None, :], k=self.k,
                                  max_frontier=self.max_frontier)
            ticket.dists, ticket.ids, ticket.epoch = d[0], i[0], e
            span.end(epoch=e)
        except Exception as exc:  # noqa: BLE001 — fail the ticket
            ticket.err = exc
            span.end(error=type(exc).__name__)
        finally:
            ticket._event.set()

    def query(self, q: np.ndarray,
              session: SessionToken | None = None) -> RouterTicket:
        """Route one read.  Leader-first unless ``prefer_replicas``;
        degrades to bounded-staleness replica serving when the leader is
        down.  ``session`` (from a prior write) is the freshness floor —
        a replica that hasn't applied ``session.wal_seq`` is skipped, and
        if nothing qualifies the call raises :class:`StaleReplica` rather
        than silently serving older state."""
        q = np.asarray(q, np.float32)
        floor = session.wal_seq if session is not None else -1
        up = self.leader_up
        rspan = obs.start_span("router.query", floor=floor, sampled=True)

        if up and not self.prefer_replicas:
            ticket = RouterTicket(mode="leader", staleness=0,
                                  trace_id=rspan.trace_id)
            ticket._inner = self.leader.submit(q, trace_ctx=rspan.ctx)
            self.n_leader_reads += 1
            self._count_read("leader")
            rspan.end(mode="leader")
            return ticket

        mode = "replica" if up else "degraded"
        for replica, applied, stale in self._replica_view():
            if applied < floor:
                continue
            if (mode == "degraded" and self.max_staleness is not None
                    and stale > self.max_staleness):
                continue
            ticket = RouterTicket(mode=mode, staleness=stale,
                                  trace_id=rspan.trace_id)
            self._serve_from(ticket, replica, q, parent=rspan.ctx)
            if mode == "degraded":
                self.n_degraded_reads += 1
            else:
                self.n_replica_reads += 1
            self._count_read(mode)
            rspan.end(mode=mode, staleness=stale)
            return ticket

        if up:
            # healthy leader is always a valid fallback for fan-out reads
            ticket = RouterTicket(mode="leader", staleness=0,
                                  trace_id=rspan.trace_id)
            ticket._inner = self.leader.submit(q, trace_ctx=rspan.ctx)
            self.n_leader_reads += 1
            self._count_read("leader")
            rspan.end(mode="leader")
            return ticket
        rspan.end(error="StaleReplica")
        raise StaleReplica(
            f"no replica satisfies session floor seq {floor}"
            + (f" within max_staleness {self.max_staleness}"
               if self.max_staleness is not None else "")
            + " and the leader is unreachable")

    def knn(self, qs: np.ndarray, session: SessionToken | None = None,
            timeout: float | None = 60.0):
        """Synchronous convenience over :meth:`query` for a [b, dim]
        block: (dists [b, k], ids [b, k], tickets)."""
        qs = np.asarray(qs, np.float32)
        tickets = [self.query(q, session) for q in qs]
        out = [t.result(timeout) for t in tickets]
        return (np.stack([d for d, _ in out]),
                np.stack([i for _, i in out]), tickets)

    # -- observability -----------------------------------------------------
    @staticmethod
    def _count_read(mode: str) -> None:
        if obs.enabled():
            obs.counter(f"router.{mode}_reads_total").inc()

    def snapshot(self) -> dict:
        with self._lock:
            up = self._leader_up and self._leader is not None
            misses = self._misses
            last_ok = self._last_ok_t
        lags = [int(r.lag) for r in self.replicas]
        # gauges, not mode strings: how long since the detector last saw
        # the leader (-1 = never), and the staleness a read served *now*
        # would carry — 0 on a live leader, the freshest qualifying
        # replica's lag when degraded (-1 = degraded with no replicas).
        since_hb = (time.monotonic() - last_ok) if last_ok is not None \
            else -1.0
        staleness = 0 if up else (min(lags) if lags else -1)
        if obs.enabled():
            obs.gauge("router.time_since_heartbeat_s").set(since_hb)
            obs.gauge("router.staleness").set(float(staleness))
            obs.gauge("router.max_replica_lag").set(
                float(max(lags, default=0)))
        return {"leader_up": up, "consecutive_misses": misses,
                "time_since_heartbeat_s": since_hb,
                "staleness": staleness,
                "n_heartbeats": self.n_heartbeats,
                "n_heartbeat_misses": self.n_heartbeat_misses,
                "n_leader_reads": self.n_leader_reads,
                "n_replica_reads": self.n_replica_reads,
                "n_degraded_reads": self.n_degraded_reads,
                "replica_lags": lags,
                "max_replica_lag": max(lags, default=0)}
