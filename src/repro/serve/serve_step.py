"""Serving step builders: prefill and cached decode, with GSPMD shardings.

``make_decode_step`` / ``make_prefill_step`` mirror train_step's builder
pattern; the dry-run lowers these for the decode_*/prefill_* shape cells.
The kNN-LM datastore mixing (core SM-tree feature) hooks in via
serve/knnlm.py and is exercised by examples/knnlm_serve.py.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist import sharding as shd
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ServeSettings:
    attn_impl: str | None = None
    temperature: float = 1.0
    greedy: bool = True
    seq_shard_cache: bool = False   # long-context: shard KV cache over seq


def make_decode_step(cfg: ArchConfig, mesh, shape: ShapeSpec,
                     settings: ServeSettings = ServeSettings()):
    """Returns (decode_fn, shardings).  decode_fn(params, token, cache, pos)
    -> (next_token, logits, cache)."""

    def decode_fn(params, token, cache, pos):
        logits, cache = M.decode_step(params, cfg, token, cache, pos)
        if settings.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits / settings.temperature, -1).astype(jnp.int32)
        return nxt, logits, cache

    pspecs = shd.param_pspecs(cfg, M.param_specs(cfg), mesh)
    param_sh = shd.to_named(pspecs, mesh)
    cache_tree = M.cache_specs(cfg, shape)
    cache_specs_tree = shd.cache_pspecs(cfg, cache_tree, mesh,
                                        seq_shard=settings.seq_shard_cache)
    cache_sh = shd.to_named(cache_specs_tree, mesh)
    dp = shd.batch_dp(mesh)
    import numpy as np
    dsize = int(np.prod([mesh.shape[a] for a in
                         (dp if isinstance(dp, tuple) else (dp,))]))
    tok_spec = P(dp) if shape.global_batch % dsize == 0 \
        and shape.global_batch >= dsize else P(None)
    token_sh = NamedSharding(mesh, tok_spec)
    logits_sh = NamedSharding(mesh, P(tok_spec[0] if tok_spec else None,
                                      "model"))
    shardings = dict(params=param_sh, cache=cache_sh, token=token_sh,
                     logits=logits_sh, pos=NamedSharding(mesh, P()),
                     pspecs=pspecs)
    return decode_fn, shardings


def make_knnlm_mixer(cfg: ArchConfig, mesh, shape: ShapeSpec, store,
                     lam: float | None = None):
    """Returns (mix_fn, query_sharding) wiring an SM-tree kNN-LM datastore
    into the sharded decode loop.

    ``mix_fn(logits, h)`` runs the [b, D] hidden-state cohort through the
    datastore's kNN — the store itself shards queries over the data axes
    (``KnnLmDatastore.shard_queries`` / ``shd.query_pspecs``, the same
    sharding the token batch carries) against replicated tree pages — and
    returns the interpolated logits.  Pairs with ``make_decode_step``; the
    returned query sharding is for wiring into jit in/out shardings."""
    from repro.serve.knnlm import mix_logits

    query_sh = NamedSharding(mesh, shd.query_pspecs(mesh, shape.global_batch))
    store.mesh = mesh          # ensure the store shards its query cohorts
    store._place()             # ...and replicates tree pages on this mesh
    lam = store.cfg.lam if lam is None else lam

    def mix_fn(logits, h):
        knn_logp = store.knn_logits(h.astype(jnp.float32), logits.shape[-1])
        return mix_logits(logits, knn_logp, lam)

    return mix_fn, query_sh


def make_prefill_step(cfg: ArchConfig, mesh, shape: ShapeSpec,
                      settings: ServeSettings = ServeSettings()):
    """Full-sequence forward producing logits (inference, no labels)."""

    def prefill_fn(params, batch):
        logits, _ = M.forward(params, cfg, batch, remat=False,
                              attn_impl=settings.attn_impl)
        return logits

    pspecs = shd.param_pspecs(cfg, M.param_specs(cfg), mesh)
    inputs = M.input_specs(cfg, shape)
    shardings = dict(
        params=shd.to_named(pspecs, mesh),
        batch=shd.to_named(shd.input_pspecs(cfg, "prefill", inputs, mesh), mesh),
        logits=NamedSharding(mesh, shd.logits_pspec(mesh)),
        pspecs=pspecs)
    return prefill_fn, shardings
