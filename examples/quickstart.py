"""Quickstart: the SM-tree public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows: bulk build -> batched kNN/range queries (jitted) -> incremental
insert -> DELETE (the paper's contribution) -> invariant validation, and the
same workload on the paper-faithful reference implementation with page-hit
(IO) accounting.
"""
import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.ref_impl import SMTree
from repro.data.datagen import clustered

# --- data: the paper's clustered distribution -------------------------------
X = clustered(5000, dims=8, seed=0)
queries = X[:8] + np.float32(0.01)

# --- JAX engine: bulk build + jitted batched queries -------------------------
eng = SMTreeEngine.build(X, capacity=32)
res = eng.knn(queries, k=3, max_frontier=256)
print("kNN dists[0]:", np.asarray(res.dists)[0])
print("kNN ids[0]:  ", np.asarray(res.ids)[0])
print("page hits/query:", float(np.asarray(res.page_hits).mean()))

rres = eng.range_search(queries, 0.05, max_results=64)
print("range hits[0]:", sorted(i for i in np.asarray(rres.ids)[0] if i >= 0))

# --- dynamic updates: insert AND delete (the paper's contribution) ----------
new_pt = np.full(8, 0.5, np.float32)
eng.insert(new_pt, obj_id=99_999)
assert 99_999 in np.asarray(eng.range_search(new_pt[None], 0.0).ids)[0]
assert eng.delete(new_pt, obj_id=99_999)
assert 99_999 not in np.asarray(eng.range_search(new_pt[None], 0.0).ids)[0]
eng.validate()   # SM radius invariant, balance, parent pointers, min-fill
print("insert/delete round-trip OK; invariants hold")

# --- paper-faithful reference with IO accounting ------------------------------
ref = SMTree(dim=8, capacity=32, n_dims=8)
for i, x in enumerate(X[:2000]):
    ref.insert(x, i)
ref.reset_counters()
nn = ref.knn_query(queries[0], 3)
print(f"ref kNN (paper DFS order): {[(round(d, 4), i) for d, i in nn]} "
      f"in {ref.ios} page hits, {ref.dist_calcs} distance evals")
ref.reset_counters()
r0 = ref.range_query(X[0], 0.0)
print(f"ref R-0 exact-match: {r0} in {ref.ios} page hits "
      f"(paper Fig. 7: far cheaper than NN-1)")
for i in range(100):
    assert ref.delete(X[i], i)
ref.validate(check_sm_invariant=True, check_min_fill=True)
print("ref: 100 deletes, SM invariant + min-fill verified")
