"""kNN-LM serving: the SM-tree datastore as a first-class LM feature.

    PYTHONPATH=src python examples/knnlm_serve.py

1. Trains a small LM briefly on the synthetic stream.
2. Builds a kNN datastore of (hidden state -> next token) pairs from the
   training data (bulk build).
3. Serves batched requests with kNN-LM mixing p = (1-l)*p_LM + l*p_kNN and
   shows retrieval changes predictions.
4. Evicts the oldest half of the datastore ONLINE with the paper's Delete —
   no rebuild — and keeps serving.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.all_archs import smoke_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import model as M
from repro.serve.knnlm import KnnLmConfig, KnnLmDatastore, mix_logits
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainSettings, init_all, make_train_step
from repro.dist.sharding import use_mesh as _use_mesh


cfg = dataclasses.replace(smoke_config("qwen2.5-3b"), n_layers=2,
                          block_pattern=("attn",))
mesh = jax.make_mesh((1, 1), ("data", "model"))
dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)

# --- 1. brief training -------------------------------------------------------
batch0 = synth_batch(dc, 0)
inputs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()}
with _use_mesh(mesh):
    step_fn, sh = make_train_step(
        cfg, mesh, inputs,
        TrainSettings(opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)))
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    params, opt = init_all(cfg, jax.random.PRNGKey(0))
    for step in range(60):
        params, opt, metrics = jitted(params, opt, synth_batch(dc, step))
    print(f"trained 60 steps, loss {float(metrics['loss']):.3f}")

# --- 2. datastore of (hidden, next_token) from held-out batches ---------------
def hidden_states(params, cfg, tokens):
    """Final pre-head hidden states [b, s, D]."""
    from repro.models.transformer import embed_inputs, _block_apply
    from repro.models.layers import apply_norm
    x, pos = embed_inputs(params, cfg, {"tokens": tokens})
    def period_fn(x, pp):
        for j, kind in enumerate(cfg.block_pattern):
            x, _ = _block_apply(kind, pp[j], cfg, x, pos, None)
        return x, None
    x, _ = jax.lax.scan(period_fn, x, params["blocks"])
    return apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)

keys, vals = [], []
for step in range(100, 104):
    b = synth_batch(dc, step)
    h = hidden_states(params, cfg, jnp.asarray(b["tokens"]))
    keys.append(np.asarray(h[:, :-1].reshape(-1, cfg.d_model)))
    vals.append(np.asarray(b["tokens"][:, 1:]).reshape(-1))
keys = np.concatenate(keys); vals = np.concatenate(vals)
store = KnnLmDatastore(KnnLmConfig(k=8, lam=0.3, metric="l2"), cfg.d_model)
store.build(keys, vals)
print(f"datastore: {len(vals)} entries, "
      f"{int(np.asarray(store.engine.tree.alive).sum())} tree nodes")

# --- 3. batched serving with retrieval mixing ---------------------------------
req = synth_batch(dc, 200)["tokens"][:, :16]
b, s0 = req.shape
cache = M.init_cache(cfg, b, s0 + 8)
for pos in range(s0):
    logits, cache = M.decode_step(params, cfg, jnp.asarray(req[:, pos]),
                                  cache, jnp.int32(pos))
h_last = hidden_states(params, cfg, jnp.asarray(req))[:, -1]
knn_logp = store.knn_logits(h_last, cfg.padded_vocab)
mixed = mix_logits(logits, knn_logp, lam=0.3)
base_tok = np.asarray(jnp.argmax(logits, -1))
mixed_tok = np.asarray(jnp.argmax(mixed, -1))
print("LM argmax:    ", base_tok)
print("kNN-LM argmax:", mixed_tok)
print(f"retrieval changed {int((base_tok != mixed_tok).sum())}/{b} predictions")

# --- 4. ONLINE eviction via the paper's Delete --------------------------------
n_before = store.engine.n_objects
evicted = store.evict_before(len(vals) // 2)
store.engine.validate()
print(f"evicted {evicted} of {n_before} entries online "
      f"(SM-tree Delete; invariants still hold)")
knn_logp2 = store.knn_logits(h_last, cfg.padded_vocab)
mixed2 = np.asarray(jnp.argmax(mix_logits(logits, knn_logp2, 0.3), -1))
print("post-eviction kNN-LM argmax:", mixed2)
