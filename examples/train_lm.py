"""End-to-end training driver example: a few hundred steps with checkpoints
and a kill/resume demonstration.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the production driver (repro.launch.train) on a reduced qwen2.5-family
config; pass --arch/--no-smoke to scale up to the real configs on hardware
(e.g. ``--arch yi-34b`` on a TPU pod with the 16x16 mesh).
"""
import shutil
import sys
import tempfile

from repro.launch import train

steps = 200
if "--steps" in sys.argv:
    steps = int(sys.argv[sys.argv.index("--steps") + 1])

ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
try:
    # 1. train with an injected failure half-way
    try:
        train.main(["--smoke", "--steps", str(steps), "--ckpt-dir", ckpt,
                    "--ckpt-every", str(max(10, steps // 4)),
                    "--fail-at", str(steps // 2), "--log-every", "20"])
        raise AssertionError("expected injected failure")
    except SystemExit as e:
        print(f"-> {e}")

    # 2. resume from the latest checkpoint and finish
    loss = train.main(["--smoke", "--steps", str(steps), "--ckpt-dir", ckpt,
                       "--resume", "--log-every", "20"])
    print(f"resumed run finished with loss {loss:.4f}")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
