"""Distributed SM-forest on an 8-device mesh: build, fan-out query, online
delete — the multi-device form of the paper's structure.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (brute_force_knn, build_forest,
                                    forest_delete, forest_knn)
from repro.core.metric import pairwise
from repro.data.datagen import clustered
from repro.dist.sharding import use_mesh as _use_mesh


mesh = jax.make_mesh((2, 4), ("data", "model"))
X = clustered(20_000, dims=12, seed=0)[:, :12].copy()
Q = X[:32] + np.float32(0.005)

t0 = time.time()
forest, _ = build_forest(X, mesh, capacity=32)
print(f"forest build over {mesh.shape['model']} shards: "
      f"{time.time() - t0:.2f}s ({X.shape[0]} objects)")

with _use_mesh(mesh):
    t0 = time.time()
    d, ids = forest_knn(forest, mesh, jnp.asarray(Q), k=5, max_frontier=256)
    jax.block_until_ready(d)
    print(f"forest kNN batch of {len(Q)}: {(time.time()-t0)*1e3:.1f}ms "
          f"(includes compile)")

    # exactness vs global brute force
    D = pairwise("d_inf", Q, X)
    np.testing.assert_allclose(np.asarray(d), np.sort(D, 1)[:, :5], atol=1e-5)
    print("exact vs brute force: OK")

    # the sequential-scan baseline (the paper's horizontal line), sharded
    Xs = jax.device_put(jnp.asarray(X), jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("model")))
    t0 = time.time()
    d2, _ = brute_force_knn(Xs, mesh, jnp.asarray(Q), k=5)
    jax.block_until_ready(d2)
    print(f"sharded brute-force scan: {(time.time()-t0)*1e3:.1f}ms")

    # online distributed delete (the paper's contribution, fleet form)
    victims = np.arange(0, 512)
    forest, found = forest_delete(forest, mesh, jnp.asarray(X[victims]),
                                  jnp.asarray(victims, jnp.int32))
    print(f"distributed delete: {int(np.asarray(found).sum())}/512 "
          f"applied via the jitted fast path")
    d3, ids3 = forest_knn(forest, mesh, jnp.asarray(X[:8]), k=1,
                          max_frontier=256)
    hit = (np.asarray(ids3)[:, 0] == np.arange(8))
    print(f"victims still self-matching: {int(hit.sum())}/8 "
          f"(expected ~0 for fast-path deletes)")
