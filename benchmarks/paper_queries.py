"""Paper Figures 5-8: query IO costs vs dimensionality, M-tree vs SM-tree.

Methodology mirrors §4: trees on 4kB-equivalent pages (capacity 42), MinMax
split, d_inf metric over 20-d vectors with dimensionality varied in the
metric, queries averaged over query objects drawn from the database,
performance in page hits (IOs).  Defaults are scaled down for CI
(REPRO_BENCH_FULL=1 restores the paper's 25k objects / 100 queries).

Beyond-paper columns: best-first kNN (optimal-IO traversal, collapses the
paper's NN-1 vs R-0 gap) and the 'central' split policy (paper §5 suggests
SM-trees want tightly-centred subtrees).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.ref_impl import MTree, SMTree
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
N_OBJ = 25_000 if FULL else 8_000
N_Q = 100 if FULL else 40
DIMS = [2, 4, 6, 8, 10, 15, 20] if FULL else [2, 6, 10, 20]


def build_pair(X, n_dims, split="minmax"):
    m = MTree(dim=20, capacity=42, n_dims=n_dims, split_policy=split)
    s = SMTree(dim=20, capacity=42, n_dims=n_dims, split_policy=split)
    for i, x in enumerate(X):
        m.insert(x, i)
        s.insert(x, i)
    return m, s


def avg_ios(tree, fn, queries):
    tot = 0
    for q in queries:
        tree.reset_counters()
        fn(tree, q)
        tot += tree.ios
    return tot / len(queries)


def run(report):
    X = make_dataset("clustered", N_OBJ, seed=0)
    rng = np.random.default_rng(1)
    queries = X[rng.integers(0, N_OBJ, N_Q)]

    for nd in DIMS:
        t0 = time.time()
        m, s = build_pair(X, nd)
        build_s = time.time() - t0
        rows = {
            # Fig 5: NN-1
            "fig5_nn1_mtree": avg_ios(m, lambda t, q: t.knn_query(q, 1), queries),
            "fig5_nn1_smtree": avg_ios(s, lambda t, q: t.knn_query(q, 1), queries),
            # Fig 6: NN-50
            "fig6_nn50_mtree": avg_ios(m, lambda t, q: t.knn_query(q, 50), queries),
            "fig6_nn50_smtree": avg_ios(s, lambda t, q: t.knn_query(q, 50), queries),
            # Fig 7: R-0
            "fig7_r0_mtree": avg_ios(m, lambda t, q: t.range_query(q, 0.0), queries),
            "fig7_r0_smtree": avg_ios(s, lambda t, q: t.range_query(q, 0.0), queries),
            # beyond paper: optimal-IO best-first kNN
            "bp_nn1_bestfirst_smtree": avg_ios(
                s, lambda t, q: t.knn_query_bestfirst(q, 1), queries),
            # the sequential-scan efficiency limit (horizontal lines)
            "leafscan_mtree": m.leaf_io_count(),
            "leafscan_smtree": s.leaf_io_count(),
        }
        for k, v in rows.items():
            report(f"{k}[dim={nd}]", v)
        report(f"build_seconds[dim={nd}]", round(build_s, 2))

        # paper claims (checked on every run):
        assert rows["fig7_r0_smtree"] <= rows["fig5_nn1_smtree"] + 1e-9, \
            "R-0 must not exceed NN-1 (paper Fig.5 vs Fig.7)"
        assert rows["fig5_nn1_smtree"] < rows["leafscan_smtree"] * 1.5, \
            "tree search must be competitive with a sequential scan"
        # SM-tree pays a bounded penalty over the M-tree (Fig. 5)
        assert rows["fig5_nn1_smtree"] <= rows["fig5_nn1_mtree"] * 2.0 + 5, \
            f"SM penalty too large at dim={nd}: {rows}"

    # Fig 8: distributions (fixed dim=10)
    for dist in ("clustered", "nonuniform", "uniform"):
        Xd = make_dataset(dist, N_OBJ, seed=2)
        qd = Xd[rng.integers(0, N_OBJ, N_Q)]
        m, s = build_pair(Xd, 10)
        report(f"fig8_nn1_mtree[{dist}]",
               avg_ios(m, lambda t, q: t.knn_query(q, 1), qd))
        report(f"fig8_nn1_smtree[{dist}]",
               avg_ios(s, lambda t, q: t.knn_query(q, 1), qd))

    # beyond paper (§5 'further work'): centred split policy for the SM-tree
    Xc = make_dataset("clustered", N_OBJ, seed=0)
    qc = Xc[rng.integers(0, N_OBJ, N_Q)]
    _, s_mm = build_pair(Xc, 10, split="minmax")
    _, s_ct = build_pair(Xc, 10, split="central")
    report("bp_split_minmax_nn1", avg_ios(s_mm, lambda t, q: t.knn_query(q, 1), qc))
    report("bp_split_central_nn1", avg_ios(s_ct, lambda t, q: t.knn_query(q, 1), qc))

    # beyond paper: the JAX engine's descent cost through the obs plane's
    # paper-level counters — distance computations and nodes visited per
    # query are the device-side analogue of the ref impl's page-hit IO
    # columns above, and pruned-by-parent (from the level-stats descent
    # variant) is the eval count the parent-distance pre-filter saves
    # (DESIGN.md §17).  Counters accumulate from the QueryResult
    # reductions the serving paths already materialise; no extra device
    # sync.
    import jax

    from repro import obs
    from repro.core import smtree
    Xe = Xc[:, :10].astype(np.float32).copy()
    tree = smtree.bulk_build(Xe, capacity=42)
    Qe = (Xe[rng.integers(0, N_OBJ, 256)] + 0.01).astype(np.float32)
    B = 64
    obs.reset()
    obs.enable()
    try:
        res, pruned = smtree.knn(tree, Qe[:B], k=1, max_frontier=64,
                                 level_stats=True)     # warm the jit entry
        jax.block_until_ready(res.dists)
        obs.reset()                                    # drop warmup counts
        t0 = time.time()
        for j in range(0, len(Qe), B):
            res, pruned = smtree.knn(tree, Qe[j:j + B], k=1,
                                     max_frontier=64, level_stats=True)
            obs.observe_query_result(res, pruned, prefix="engine")
        jax.block_until_ready(res.dists)
        dt = time.time() - t0
        m = obs.REGISTRY.snapshot()
        nq = m["engine.queries_total"]
        report("engine_nn1_qps", round(nq / dt, 0))
        report("engine_dist_evals_per_query",
               round(m["engine.dist_evals_total"] / nq, 1))
        report("engine_nodes_visited_per_query",
               round(m["engine.nodes_visited_total"] / nq, 1))
        report("engine_pruned_per_query",
               round(m.get("engine.pruned_by_bound_total", 0) / nq, 1))
        # entries the parent-distance pre-filter dropped *before* any
        # metric eval (DESIGN.md §17) — these are the evals saved;
        # dist_evals_per_query above already excludes them
        report("engine_pruned_parent_per_query",
               round(m.get("engine.pruned_by_parent_total", 0) / nq, 1))
    finally:
        obs.disable()
        obs.reset()
