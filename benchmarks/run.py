"""Benchmark orchestrator: one module per paper table/figure + engine,
kernel and roofline benches.  Prints ``name,value`` CSV lines (plus readable
tables at the end).  REPRO_BENCH_FULL=1 restores full paper scale."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_engine, bench_kernels, paper_delete,
                            paper_queries, roofline_table)
    results: list[tuple[str, object]] = []

    def report(name, value):
        results.append((name, value))
        print(f"{name},{value}", flush=True)

    suites = [
        ("paper_queries", paper_queries.run),     # Figs. 5-8
        ("paper_delete", paper_delete.run),       # Fig. 10 + occupancy
        ("bench_engine", bench_engine.run),       # JAX engine throughput
        ("bench_kernels", bench_kernels.run),     # kernel validation/baseline
        ("roofline", roofline_table.run),         # 40-cell dry-run table
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in suites:
        if only and only != name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        fn(report)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"# total rows: {len(results)}")


if __name__ == "__main__":
    main()
