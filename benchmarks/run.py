"""Benchmark orchestrator: one module per paper table/figure + engine,
kernel and roofline benches.  Prints ``name,value`` CSV lines and, with
``--tag``, writes a machine-readable ``benchmarks/BENCH_<tag>.json``
artifact (suite -> name -> value plus host/backend metadata) — the bench
trajectory the repo tracks across PRs.  REPRO_BENCH_FULL=1 restores full
paper scale; REPRO_BENCH_SMOKE=1 is the tiny CI preset."""
from __future__ import annotations

import argparse
import importlib
import json
import os
import platform
import sys
import time

if __package__ in (None, ""):   # script invocation: make repo root importable
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# (suite name, module) — modules import lazily and individually so one
# missing dependency (e.g. the distributed stack on a minimal single-host
# CPU image) skips its suite instead of killing the whole entrypoint
_SUITES = [
    ("paper_queries", "paper_queries"),   # Figs. 5-8
    ("paper_delete", "paper_delete"),     # Fig. 10 + occupancy
    ("bench_engine", "bench_engine"),     # JAX engine throughput
    ("bench_stream", "bench_stream"),     # mutation-stream throughput
    ("bench_serve", "bench_serve"),       # serving front-end + replicas
    ("bench_kernels", "bench_kernels"),   # kernel validation/baseline
    ("roofline", "roofline_table"),       # 40-cell dry-run table
]


def _meta() -> dict:
    meta = {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "env": {k: v for k, v in os.environ.items()
                if k.startswith("REPRO_")},
    }
    try:
        import jax
        meta["jax"] = jax.__version__
        meta["backend"] = jax.default_backend()
        meta["device_count"] = jax.device_count()
    except Exception as e:  # noqa: BLE001 — metadata only
        meta["jax"] = f"unavailable ({e})"
    return meta


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suite", nargs="?", default=None,
                    help="run a single suite (default: all importable)")
    ap.add_argument("--tag", default=None,
                    help="write benchmarks/BENCH_<tag>.json")
    args = ap.parse_args(argv)

    results: dict[str, dict[str, object]] = {}
    current = {"suite": None}
    n_rows = 0

    def report(name, value):
        nonlocal n_rows
        results.setdefault(current["suite"], {})[name] = value
        n_rows += 1
        print(f"{name},{value}", flush=True)

    only = args.suite
    suites = []
    for name, mod_name in _SUITES:
        try:
            suites.append(
                (name, importlib.import_module(f"benchmarks.{mod_name}").run))
        except ImportError as e:
            if only == name:
                # an explicitly requested suite must not skip silently
                raise SystemExit(f"suite {name!r} failed to import: {e}")
            print(f"# skip {name}: unavailable on this host ({e})",
                  flush=True)
    if only and only not in [n for n, _ in suites]:
        raise SystemExit(f"unknown suite {only!r}; "
                         f"have {[n for n, _ in _SUITES]}")
    for name, fn in suites:
        if only and only != name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        current["suite"] = name
        fn(report)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"# total rows: {n_rows}")

    if args.tag:
        def _jsonable(v):
            # bare NaN/inf tokens are not valid JSON; null keeps the
            # artifact strict and still trips check_bench.py
            if isinstance(v, float) and (v != v or v in (float("inf"),
                                                         float("-inf"))):
                return None
            return v

        clean = {s: {k: _jsonable(v) for k, v in rows.items()}
                 for s, rows in results.items()}
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            f"BENCH_{args.tag}.json")
        with open(path, "w") as f:
            json.dump({"meta": _meta(), "suites": clean}, f, indent=2,
                      sort_keys=True, allow_nan=False)
            f.write("\n")
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
