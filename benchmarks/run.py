"""Benchmark orchestrator: one module per paper table/figure + engine,
kernel and roofline benches.  Prints ``name,value`` CSV lines (plus readable
tables at the end).  REPRO_BENCH_FULL=1 restores full paper scale."""
from __future__ import annotations

import importlib
import os
import sys
import time

if __package__ in (None, ""):   # script invocation: make repo root importable
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# (suite name, module) — modules import lazily and individually so one
# missing dependency (e.g. the distributed stack on a minimal single-host
# CPU image) skips its suite instead of killing the whole entrypoint
_SUITES = [
    ("paper_queries", "paper_queries"),   # Figs. 5-8
    ("paper_delete", "paper_delete"),     # Fig. 10 + occupancy
    ("bench_engine", "bench_engine"),     # JAX engine throughput
    ("bench_kernels", "bench_kernels"),   # kernel validation/baseline
    ("roofline", "roofline_table"),       # 40-cell dry-run table
]


def main() -> None:
    results: list[tuple[str, object]] = []

    def report(name, value):
        results.append((name, value))
        print(f"{name},{value}", flush=True)

    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = []
    for name, mod_name in _SUITES:
        try:
            suites.append(
                (name, importlib.import_module(f"benchmarks.{mod_name}").run))
        except ImportError as e:
            if only == name:
                # an explicitly requested suite must not skip silently
                raise SystemExit(f"suite {name!r} failed to import: {e}")
            print(f"# skip {name}: unavailable on this host ({e})",
                  flush=True)
    if only and only not in [n for n, _ in suites]:
        raise SystemExit(f"unknown suite {only!r}; "
                         f"have {[n for n, _ in _SUITES]}")
    for name, fn in suites:
        if only and only != name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        fn(report)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"# total rows: {len(results)}")


if __name__ == "__main__":
    main()
