"""Kernel-level benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness)
— wall-clock numbers for them are NOT TPU-representative; we benchmark the
XLA reference paths (what the dry-run lowers) and validate kernel outputs.
The TPU-side performance claims live in the roofline analysis."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.attention_xla import chunked_attention


def _time(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(report):
    # pairwise distance: XLA path throughput + interpret-mode equivalence
    q = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    e = jax.random.normal(jax.random.PRNGKey(1), (4096, 128))
    f_x = jax.jit(lambda a, b: ops.pairwise_distance(a, b, impl="xla"))
    dt = _time(f_x, q, e)
    gflops = 2 * 256 * 4096 * 128 / dt / 1e9
    report("dist_xla_us", round(dt * 1e6, 1))
    report("dist_xla_gflops_cpu", round(gflops, 2))
    got = ops.pairwise_distance(q[:32], e[:128], impl="interpret")
    want = ref.pairwise_distance_ref(q[:32], e[:128])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    report("dist_pallas_interpret_allclose", 1)

    # attention: chunked flash-style scan vs naive, bytes advantage
    b, h, s, d = 1, 4, 2048, 64
    qq = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d))
    kk = jax.random.normal(jax.random.PRNGKey(3), (b, h, s, d))
    vv = jax.random.normal(jax.random.PRNGKey(4), (b, h, s, d))
    f_naive = jax.jit(lambda a, b_, c: ref.flash_attention_ref(a, b_, c))
    f_chunk = jax.jit(lambda a, b_, c: chunked_attention(a, b_, c, chunk=256))
    report("attn_naive_ms", round(_time(f_naive, qq, kk, vv) * 1e3, 2))
    report("attn_chunked_ms", round(_time(f_chunk, qq, kk, vv) * 1e3, 2))
    got = ops.attention(qq[:, :2, :256], kk[:, :2, :256], vv[:, :2, :256],
                        impl="interpret")
    want = ref.flash_attention_ref(qq[:, :2, :256], kk[:, :2, :256],
                                   vv[:, :2, :256])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    report("attn_pallas_interpret_allclose", 1)
