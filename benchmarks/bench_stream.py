"""Stream-subsystem benchmarks: sustained mutation throughput.

The matrix the PR-3 acceptance tracks: ops/sec through the WAL-backed
cohort batcher for insert-only, delete-only and 90/10-skewed streams at
batch >= 256, against the one-at-a-time ``insert_fast``/``delete_fast``
Python loop (the pre-stream write path, kept as the baseline).  PR 4/5
add the structure-edit rows: split-heavy (device split pass vs host
escalation), delete-heavy (device merge pass vs host escalation),
mixed churn, and the mesh-resident forest collectives with absorption
counters.  Also records WAL append cost (buffered, fsync'd, and
group-commit under concurrent appenders), the checkpoint ``fsync_dir``
durability premium (ROADMAP/DESIGN.md §9 satellite), the rebalance
pass, and the evict-while-serving composite (queries against a pinned
epoch while the writer streams mutations).

Scale envs: REPRO_BENCH_SMOKE=1 (tiny, CI) / REPRO_BENCH_FULL=1.
"""
from __future__ import annotations

import os
import re
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.smtree import OP_DELETE, OP_INSERT, bulk_build
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def jnp_copy(a):
    import jax.numpy as jnp
    return jnp.array(a, copy=True)

if SMOKE:
    N = 2_000
    N_OPS = 1_024
    BATCHES = [256]
    N_LOOP = 192
elif FULL:
    N = 100_000
    N_OPS = 16_384
    BATCHES = [256, 1024, 4096]
    N_LOOP = 2_048
else:
    N = 20_000
    N_OPS = 8_192
    BATCHES = [256, 1024]
    N_LOOP = 1_024
DIM = 10
CAPACITY = 32


def _make_stream(rng, kind: str, n_ops: int, n_live: int, base_id: int):
    """(ops, xs, oids) with unique ids per stream (single cohort)."""
    X = make_dataset("clustered", n_live, seed=7)[:, :DIM].copy()
    if kind == "insert":
        ops = np.full(n_ops, OP_INSERT, np.int32)
        oids = base_id + np.arange(n_ops)
        xs = make_dataset("uniform", n_ops, seed=11)[:, :DIM].copy()
    elif kind == "delete":
        ops = np.full(n_ops, OP_DELETE, np.int32)
        oids = rng.permutation(n_live)[:n_ops]
        xs = X[oids]
    else:   # mixed: frac deletes, rest inserts
        frac = float(kind)
        n_del = int(n_ops * frac)
        victims = rng.permutation(n_live)[:n_del]
        ins_ids = base_id + np.arange(n_ops - n_del)
        ops = np.concatenate([np.full(n_del, OP_DELETE, np.int32),
                              np.full(n_ops - n_del, OP_INSERT, np.int32)])
        oids = np.concatenate([victims, ins_ids])
        xs = np.concatenate([X[victims],
                             make_dataset("uniform", n_ops - n_del,
                                          seed=13)[:, :DIM]])
        perm = rng.permutation(n_ops)
        ops, oids, xs = ops[perm], oids[perm], xs[perm]
    return (ops.astype(np.int32), np.asarray(xs, np.float32),
            oids.astype(np.int32))


def _fresh_tree():
    X = make_dataset("clustered", N, seed=7)[:, :DIM].copy()
    return bulk_build(X, capacity=CAPACITY)


def _time_stream(tree, ops, xs, oids, batch: int,
                 device_splits: bool = True,
                 device_merges: bool = True) -> float:
    """ops/sec through the batched pipeline (first batch warms the jit).

    Headroom growth is disabled for the timed rows: a mid-run doubling
    recompiles every jit entry for the new geometry, which at smoke scale
    swamps the op window — the same once-per-resize cost the split-heavy
    row already provisions slack to keep out of the measurement (and the
    pre-growth behaviour, host ``_grow`` on exhaustion, paid identically).
    Growth itself is covered by tests/test_device_merge.py."""
    from repro.core import smtree
    from repro.stream import StreamingEngine
    import jax
    eng = StreamingEngine(tree, device_splits=device_splits,
                          device_merges=device_merges,
                          headroom_frac=None)
    if device_merges:
        # warm the merge-scan compiles (both ladder widths) for this tree
        # geometry (donate=True matches resolve_underflows' jit entry)
        for w in (smtree.MERGE_CHUNK, smtree.MERGE_CHUNK_MAX):
            scratch = jax.tree.map(lambda a: jnp_copy(a), eng.tree)
            smtree.apply_merges(scratch,
                                np.full(w, smtree.OP_NOP, np.int32),
                                np.full(w, -1, np.int32), donate=True)
    if device_splits:
        # warm the split-scan compile for this tree geometry (the warm
        # batch below only reaches it when it happens to overflow a leaf).
        # donate=True matches the hot path's jit entry (resolve_overflows
        # always donates its intermediates), so feed it a throwaway copy
        scratch = jax.tree.map(lambda a: jnp_copy(a), eng.tree)
        smtree.apply_splits(scratch,
                            np.full(smtree.SPLIT_CHUNK, smtree.OP_NOP,
                                    np.int32),
                            np.zeros((smtree.SPLIT_CHUNK, xs.shape[1]),
                                     np.float32),
                            np.full(smtree.SPLIT_CHUNK, -1, np.int32),
                            donate=True)
    eng.apply(ops[:batch], xs[:batch], oids[:batch])   # compile + warm
    n = (len(ops) - batch) // batch * batch
    t0 = time.perf_counter()
    for s in range(batch, batch + n, batch):
        eng.apply(ops[s:s + batch], xs[s:s + batch], oids[s:s + batch])
    dt = time.perf_counter() - t0
    return n / dt


def _split_rows(report, rng):
    """Split-heavy workload: a near-capacity bulk build (fill 0.9, with
    free-ring headroom as a mutation-heavy deployment would provision —
    without it every few batches exhaust the node table, and the host
    ``_grow`` resize forces a full recompile that swamps both paths) makes
    insert streams overflow leaves constantly — the device split pass vs
    the PR-3 host-escalation path, plus the split count actually exercised
    (PR-4 acceptance row)."""
    from repro.stream.batcher import MutationBatcher

    def _tree():
        return bulk_build(X, capacity=CAPACITY, fill_frac=0.9, slack=4.0)

    n = min(N, 20_000)
    X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
    ops, xs, oids = _make_stream(rng, "insert", N_OPS, n, base_id=8 * n)
    rates = {}
    for dev, name in ((True, "stream_split_heavy_b256_ops_per_s"),
                      (False, "stream_split_heavy_host_b256_ops_per_s")):
        rates[dev] = _time_stream(_tree(), ops, xs, oids, 256,
                                  device_splits=dev)
        report(name, round(rates[dev], 0))
    report("split_device_vs_host_speedup",
           round(rates[True] / rates[False], 2))
    # observability: how many rows the device pass actually absorbed
    b = MutationBatcher(_tree())
    r = b.apply(ops[:1024], xs[:1024], oids[:1024])
    report("split_heavy_n_device_splits_per_1k", int(r.n_split))
    report("split_heavy_n_host_escalations_per_1k", int(r.n_escalated))


def _merge_rows(report, rng):
    """Delete-heavy workload (the PR-5 acceptance row): sustained deletes
    on a near-min-fill build underflow leaves steadily — the device merge
    pass vs the PR-4 escalate-to-host path, plus a mixed-churn row (60/40
    delete/insert on the same build: eviction pressure with concurrent
    ingest) and the absorption counters."""
    from repro.stream.batcher import MutationBatcher

    n = min(N, 20_000)
    X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()

    def _tree():
        # leaves a couple of entries above min-fill so sustained
        # deletes underflow steadily (~8% of ops) — the long-lived
        # steady state of a delete-heavy deployment
        return bulk_build(X, capacity=CAPACITY, fill_frac=0.48)

    ops, xs, oids = _make_stream(rng, "delete", min(N_OPS, n - 256), n,
                                 base_id=0)
    rates = {}
    for dev, name in ((True, "stream_merge_heavy_b256_ops_per_s"),
                      (False, "stream_merge_heavy_host_b256_ops_per_s")):
        rates[dev] = _time_stream(_tree(), ops, xs, oids, 256,
                                  device_merges=dev)
        report(name, round(rates[dev], 0))
    report("merge_device_vs_host_speedup",
           round(rates[True] / rates[False], 2))
    # absorption counters: every underflow must resolve on device
    b = MutationBatcher(_tree())
    r = b.apply(ops[:1024], xs[:1024], oids[:1024])
    report("merge_heavy_n_device_merges_per_1k", int(r.n_merge))
    report("merge_heavy_n_host_escalations_per_1k", int(r.n_escalated))

    # mixed churn: 60/40 delete/insert on the same near-min-fill build —
    # eviction pressure with concurrent ingest, the sliding-window shape
    ops, xs, oids = _make_stream(rng, "0.6", N_OPS, n, base_id=16 * n)
    churn = _time_stream(_tree(), ops, xs, oids, 256)
    report("stream_churn60d_b256_ops_per_s", round(churn, 0))
    b = MutationBatcher(_tree())
    r = b.apply(ops[:1024], xs[:1024], oids[:1024])
    report("churn_n_device_splits_per_1k", int(r.n_split))
    report("churn_n_device_merges_per_1k", int(r.n_merge))
    report("churn_n_host_escalations_per_1k", int(r.n_escalated))


def _time_loop(tree, ops, xs, oids) -> float:
    """ops/sec through the pre-stream write path: one jitted fast-path call
    + host sync per mutation, engine escalation on overflow/underflow."""
    eng = SMTreeEngine(tree)
    n = min(N_LOOP, len(ops))
    # warm both fast-path compilations outside the timed window
    eng.insert(xs[0] + 17.0, 1 << 30)
    eng.delete(xs[0] + 17.0, 1 << 30)
    t0 = time.perf_counter()
    for i in range(n):
        if ops[i] == OP_INSERT:
            eng.insert(xs[i], int(oids[i]))
        else:
            eng.delete(xs[i], int(oids[i]))
    return n / (time.perf_counter() - t0)


# Both legs (device collectives vs escalate-to-host) run INTERLEAVED in
# one subprocess — dev/host/dev/host, best-of-2 per leg — because on a
# shared CI/container host, separate minute-apart processes see ±30%
# machine drift, which is larger than the effect under test.
_MESH_WORKER = r"""
import os, time
import numpy as np
import jax
from repro.core.smtree import bulk_build
from repro.core.smtree import OP_DELETE, OP_INSERT
from repro.data.datagen import make_dataset
from repro.stream import StreamingForest

S = 4
n = int(os.environ["BSF_N"])
n_ops = int(os.environ["BSF_OPS"])
batch = 256
kind = os.environ.get("BSF_KIND", "insert")
mesh = jax.make_mesh((S,), ("model",))
X = make_dataset("clustered", n, seed=7)[:, :10].copy()
# insert streams need near-full leaves (split pressure) and free-ring
# slack for sustained splits; delete streams need leaves near min-fill
# (underflow pressure) and never allocate
fill = 0.9 if kind == "insert" else 0.48
slack = 4.0 if kind == "insert" else 1.5
trees0 = [bulk_build(X[np.arange(s, n, S)], ids=np.arange(s, n, S),
                     capacity=32, fill_frac=fill, slack=slack)
          for s in range(S)]
if kind == "insert":
    xs = make_dataset("uniform", n_ops + batch, seed=11)[:, :10].copy()
    oids = (10 * n + np.arange(n_ops + batch)).astype(np.int32)
    ops_all = np.full(n_ops + batch, OP_INSERT, np.int32)
else:   # delete-heavy mix: 90% deletes of live ids, 10% fresh inserts
    rng = np.random.default_rng(13)
    victims = rng.permutation(n)[:int((n_ops + batch) * 0.9)]
    n_ins = n_ops + batch - len(victims)
    ops_all = np.concatenate([np.full(len(victims), OP_DELETE, np.int32),
                              np.full(n_ins, OP_INSERT, np.int32)])
    oids = np.concatenate([victims,
                           10 * n + np.arange(n_ins)]).astype(np.int32)
    xs = np.concatenate([X[victims],
                         make_dataset("uniform", n_ins,
                                      seed=11)[:, :10]]).astype(np.float32)
    perm = rng.permutation(n_ops + batch)
    ops_all, oids, xs = ops_all[perm], oids[perm], xs[perm]


def run_leg(dev):
    trees = [jax.tree.map(lambda a: a.copy(), t) for t in trees0]
    sf = StreamingForest(trees, mesh=mesh, device_splits=dev,
                         device_merges=dev)
    stats = {"esc": 0, "dev": 0}

    def step(s0):
        r = sf.apply(ops_all[s0:s0 + batch],
                     xs[s0:s0 + batch].astype(np.float32),
                     oids[s0:s0 + batch])
        stats["esc"] += r.n_escalated
        stats["dev"] += r.n_split + r.n_merge

    step(0)   # warm the apply collective (and stack the forest)
    if dev:
        # warm the split/merge collectives explicitly: the warm batch
        # only reaches them when it happens to over/underflow a leaf,
        # and their seconds-scale scan compile must not land in the
        # timed loop.  NOP chunks compile the exact jit entries the hot
        # path dispatches; the returned (unchanged) forest is discarded.
        from repro.core import distributed as dist
        from repro.core import smtree as smt
        w = smt.SPLIT_CHUNK
        dist.forest_apply_splits(
            sf._stacked, mesh, np.full(w, smt.OP_NOP, np.int32),
            np.zeros((w, 10), np.float32), np.full(w, -1, np.int32),
            np.zeros(w, np.int32))
        for w in (smt.MERGE_CHUNK, smt.MERGE_CHUNK_MAX):
            dist.forest_apply_merges(
                sf._stacked, mesh, np.full(w, smt.OP_NOP, np.int32),
                np.full(w, -1, np.int32), np.zeros(w, np.int32))
    stats["esc"] = stats["dev"] = 0
    t0 = time.perf_counter()
    for s0 in range(batch, batch + n_ops, batch):
        step(s0)
    return n_ops / (time.perf_counter() - t0), stats


best = {True: 0.0, False: 0.0}
counts = {}
for rep in range(2):
    for dev in (True, False):
        rate, stats = run_leg(dev)
        best[dev] = max(best[dev], rate)
        if dev:
            counts = stats
print(f"RESULT dev {best[True]:.1f} host {best[False]:.1f} ops/s "
      f"ESC {counts['esc']} DEV {counts['dev']}")
"""


def _mesh_forest_rows(report):
    """The tentpole measurements: a mesh-resident 4-shard StreamingForest,
    device structure-edit collectives vs the escalate-to-host path (which
    must unstack + restack the whole stacked forest around every host
    edit).  Two workloads: the PR-4 split-heavy insert stream, and the
    PR-5 delete-heavy mix (90% deletes) whose underflows run the
    forest_apply_merges collective — with the absorption counters proving
    zero host escalations on the device path.  Subprocesses: each needs
    its own XLA_FLAGS device-count override before jax import."""
    # shards must be big enough that the host path's whole-forest
    # unstack/restack cost is visible over collective dispatch overhead
    n, n_ops = (2_000, 768) if SMOKE else (32_000, 2_048)
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["BSF_N"] = str(n)
    env["BSF_OPS"] = str(n_ops)
    for kind, label in (("insert", "split"), ("delete", "merge")):
        e = dict(env, BSF_KIND=kind)
        d_rate = h_rate = float("nan")
        try:
            proc = subprocess.run([sys.executable, "-c", _MESH_WORKER],
                                  capture_output=True, text=True, env=e,
                                  timeout=3600)
            m = re.search(
                r"RESULT dev ([\d.]+) host ([\d.]+) ops/s "
                r"ESC (\d+) DEV (\d+)", proc.stdout)
            if m is None:
                print(f"# mesh forest case {label}: no result "
                      f"(rc={proc.returncode})\n"
                      f"# stderr tail: {proc.stderr[-2000:]}", flush=True)
            else:
                d_rate, h_rate = float(m.group(1)), float(m.group(2))
                report(f"mesh_forest_{label}_heavy_host_escalations",
                       int(m.group(3)))
                report(f"mesh_forest_{label}_heavy_device_edits",
                       int(m.group(4)))
        except Exception as exc:  # noqa: BLE001 — a bench row
            print(f"# mesh forest case {label} failed: {exc}", flush=True)
        report(f"mesh_forest_{label}_heavy_ops_per_s", d_rate)
        report(f"mesh_forest_{label}_heavy_host_ops_per_s", h_rate)
        if np.isfinite(d_rate) and np.isfinite(h_rate):
            report(f"mesh_forest_{label}_device_vs_host_speedup",
                   round(d_rate / h_rate, 2))


def _wal_rows(report):
    from repro.stream import WriteAheadLog
    rng = np.random.default_rng(3)
    ops, xs, oids = _make_stream(rng, "0.5", 2048, N, base_id=10 * N)
    for sync, name in ((False, "wal_append_us_per_batch_b256"),
                       (True, "wal_fsync_append_us_per_batch_b256")):
        d = tempfile.mkdtemp(prefix="walbench")
        try:
            wal = WriteAheadLog(d, segment_max_records=256, sync=sync)
            t0 = time.perf_counter()
            n_batches = len(ops) // 256
            for s in range(0, n_batches * 256, 256):
                wal.append_batch(ops[s:s + 256].astype(np.int8),
                                 xs[s:s + 256], oids[s:s + 256])
            dt = time.perf_counter() - t0
            wal.close()
            report(name, round(dt / n_batches * 1e6, 1))
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # group commit under concurrent appenders: the fsync amortises across
    # the burst (the ROADMAP ~14x fsync-vs-buffered gap, recovered)
    import threading
    T = 4
    per = max(1, len(ops) // 256 // T)
    for group, name in (
            (False, "wal_fsync_4thread_us_per_batch_b256"),
            (True, "wal_group_fsync_4thread_us_per_batch_b256")):
        d = tempfile.mkdtemp(prefix="walbench")
        try:
            wal = WriteAheadLog(d, segment_max_records=1024, sync=True,
                                group_commit=group)

            def worker():
                for s in range(0, per * 256, 256):
                    wal.append_batch(ops[s:s + 256].astype(np.int8),
                                     xs[s:s + 256], oids[s:s + 256])

            threads = [threading.Thread(target=worker) for _ in range(T)]
            t0 = time.perf_counter()
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            dt = time.perf_counter() - t0
            wal.close()
            report(name, round(dt / (T * per) * 1e6, 1))
        finally:
            shutil.rmtree(d, ignore_errors=True)


def _ckpt_rows(report, tree):
    """The fsync_dir durability premium (DESIGN.md §9)."""
    from repro.dist.checkpoint import save_checkpoint
    for fsync, name in ((False, "ckpt_ms"), (True, "ckpt_fsync_dir_ms")):
        d = tempfile.mkdtemp(prefix="ckbench")
        try:
            save_checkpoint(d, 0, {"tree": tree}, fsync_dir=fsync)  # warm fs
            iters = 3
            t0 = time.perf_counter()
            for i in range(1, 1 + iters):
                save_checkpoint(d, i, {"tree": tree}, fsync_dir=fsync)
            report(name,
                   round((time.perf_counter() - t0) / iters * 1e3, 2))
        finally:
            shutil.rmtree(d, ignore_errors=True)


def _rebalance_rows(report):
    from repro.core.distributed import build_forest_trees
    from repro.stream import StreamingForest, collect_stats
    n = min(N, 8_192)
    X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
    sf = StreamingForest(build_forest_trees(X, 4, capacity=CAPACITY),
                         min_objects=64)
    # drain shards 0/1: the heavily-skewed delete stream (80% of their
    # objects — skew lands well above the 1.5x trigger)
    victims = np.array([o for o in range(n) if o % 4 < 2][:2 * n // 5])
    sf.delete_batch(X[victims], victims)
    before = collect_stats(sf.trees).skew
    t0 = time.perf_counter()
    fired = sf.maintenance()
    dt = time.perf_counter() - t0
    after = collect_stats(sf.trees).skew
    report("rebalance_skew_before", round(before, 3))
    report("rebalance_fired", int(fired))
    report("rebalance_skew_after", round(after, 3))
    report("rebalance_ms", round(dt * 1e3, 1))


def _skew_drain_rows(report):
    """Skew-drain drill, incremental vs stop-the-world (PR 9): worst and
    p99 publish-time pause per maintenance call, plus steady-state
    mutation ops/s sustained *while the drain is in progress*.  Legs are
    interleaved and run twice (PR 5 methodology): the first pair pays
    one-time jit compilation, only the second pair is reported."""
    from repro.core.distributed import build_forest_trees
    from repro.stream import StreamingForest, collect_stats
    n = min(N, 8_192)
    X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
    trees = build_forest_trees(X, 4, capacity=CAPACITY)
    victims = np.array([o for o in range(n) if o % 4 < 2][:2 * n // 5])
    B = 64
    fresh = make_dataset("uniform", 200 * B, seed=41)[:, :DIM].copy()

    def leg(mode, base_id):
        sf = StreamingForest([t for t in trees], max_skew=1.3,
                             min_objects=64, rebalance_mode=mode,
                             migration_step_objects=B)
        sf.delete_batch(X[victims], victims)
        skew0 = collect_stats(sf.trees).skew
        pauses, mut_ops, mut_t, nid = [], 0, 0.0, base_id
        for r in range(200):
            t0 = time.perf_counter()
            fired = sf.maintenance()
            dt = time.perf_counter() - t0
            if not fired:
                break
            pauses.append(dt)
            oids = nid + np.arange(B)
            t0 = time.perf_counter()
            sf.insert_batch(fresh[(r % 200) * B:(r % 200) * B + B], oids)
            mut_t += time.perf_counter() - t0
            mut_ops += B
            nid += B
        return {"skew0": skew0, "pauses": pauses, "steps": len(pauses),
                "ops_per_s": mut_ops / mut_t if mut_t else 0.0,
                "skew1": collect_stats(sf.trees).skew}

    out = {}
    for rep in range(2):
        for mode in ("incremental", "stop_world"):
            out[mode] = leg(mode, base_id=(10 + 4 * rep) * n)
    report("skew_drain_skew_before", round(out["incremental"]["skew0"], 3))
    report("skew_drain_steps_incremental", out["incremental"]["steps"])
    for mode, r in out.items():
        p = np.asarray(r["pauses"]) * 1e3
        report(f"rebalance_p99_pause_ms_{mode}",
               round(float(np.percentile(p, 99)), 2))
        report(f"rebalance_max_pause_ms_{mode}", round(float(p.max()), 2))
        report(f"skew_drain_ops_per_s_{mode}", round(r["ops_per_s"], 0))
        report(f"skew_drain_final_skew_{mode}", round(r["skew1"], 3))


def _serve_rows(report):
    """Evict-while-serving: queries pinned to an epoch while the writer
    applies sliding-window add/evict batches."""
    from repro.core import smtree
    from repro.stream import StreamingEngine
    import jax
    rng = np.random.default_rng(5)
    n = min(N, 8_192)
    X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
    eng = StreamingEngine(bulk_build(X, capacity=CAPACITY))
    Q = X[rng.integers(0, n, 64)] + 0.01
    B = 128
    rounds = 4 if SMOKE else 12
    # warm compiles
    jax.block_until_ready(smtree.knn(eng.tree, Q, k=8).dists)
    cursor, nid = 0, n
    t_q = t_m = 0.0
    fresh = make_dataset("uniform", rounds * B, seed=100)[:, :DIM].copy()
    for r in range(rounds):
        e, tree = eng.epochs.acquire()
        t0 = time.perf_counter()
        res = smtree.knn(tree, Q, k=8, max_frontier=64)
        jax.block_until_ready(res.dists)
        t_q += time.perf_counter() - t0
        t0 = time.perf_counter()
        eng.insert_batch(fresh[r * B:(r + 1) * B], nid + np.arange(B))
        eng.delete_batch(X[cursor:cursor + B],
                         np.arange(cursor, cursor + B))
        t_m += time.perf_counter() - t0
        cursor += B
        nid += B
        eng.epochs.release(e)
    report("serve_knn_qps_under_mutation", round(rounds * 64 / t_q, 0))
    report("serve_mutation_ops_per_s", round(rounds * 2 * B / t_m, 0))


def run(report):
    import gc
    rng = np.random.default_rng(1)
    tree = _fresh_tree()

    # -- headline speedup first, in a clean process state: timing the loop
    # after the stream stages understates it ~3x (allocator/cache pressure
    # from the earlier stages' buffers), which would flatter the speedup
    ops, xs, oids = _make_stream(rng, "0.5", N_OPS, N, base_id=4 * N)
    loop_rate = _time_loop(tree, ops, xs, oids)
    report("loop_mixed_ops_per_s", round(loop_rate, 0))
    gc.collect()
    mixed_rate = _time_stream(tree, ops, xs, oids, 256)
    report("stream_mixed50_b256_ops_per_s", round(mixed_rate, 0))
    report("speedup_batched_vs_loop_b256", round(mixed_rate / loop_rate, 2))

    # -- mutation-throughput matrix --------------------------------------
    for kind, label in (("insert", "insert"), ("delete", "delete"),
                        ("0.9", "mixed90d")):
        ops, xs, oids = _make_stream(rng, kind, N_OPS, N, base_id=2 * N)
        for b in BATCHES:
            gc.collect()
            rate = _time_stream(tree, ops, xs, oids, b)
            report(f"stream_{label}_b{b}_ops_per_s", round(rate, 0))

    _split_rows(report, rng)
    _merge_rows(report, rng)
    _mesh_forest_rows(report)
    _wal_rows(report)
    _ckpt_rows(report, tree)
    _rebalance_rows(report)
    _skew_drain_rows(report)
    _serve_rows(report)
