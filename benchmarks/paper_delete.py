"""Paper Figure 10 + §4.2 occupancy claim: the post-delete SM-tree.

Build three trees over the same 20-d clustered data:
  * M-tree with N objects (baseline)
  * SM-tree with N objects (fresh)
  * SM-tree built by inserting 2N objects and deleting N of them (the
    operation no other M-tree variant supports)
then compare NN-1 IOs, the sequential-scan limit, and node occupancy.
Paper: post-delete tree is bigger and ~40% full (the underflow limit) vs
~60% for the fresh trees — 'exactly analogous to B-trees'.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.ref_impl import MTree, SMTree
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
N = 25_000 if FULL else 6_000
N_Q = 100 if FULL else 40


def run(report):
    X = make_dataset("clustered", 2 * N, seed=3)
    keep = np.arange(0, 2 * N, 2)       # survivors
    drop = np.arange(1, 2 * N, 2)       # deleted
    nd = 10

    m = MTree(dim=20, capacity=42, n_dims=nd)
    s_fresh = SMTree(dim=20, capacity=42, n_dims=nd)
    for i in keep:
        m.insert(X[i], int(i))
        s_fresh.insert(X[i], int(i))

    s_del = SMTree(dim=20, capacity=42, n_dims=nd)
    for i in range(2 * N):
        s_del.insert(X[i], i)
    for i in drop:
        assert s_del.delete(X[i], int(i)), f"delete failed for {i}"
    s_del.validate(check_sm_invariant=True, check_min_fill=True)
    assert s_del.n_objects == N

    rng = np.random.default_rng(5)
    queries = X[keep[rng.integers(0, N, N_Q)]]

    def nn1(t):
        tot = 0
        for q in queries:
            t.reset_counters()
            t.knn_query(q, 1)
            tot += t.ios
        return tot / len(queries)

    rows = {
        "fig10_nn1_mtree": nn1(m),
        "fig10_nn1_smtree": nn1(s_fresh),
        "fig10_nn1_smtree_postdelete": nn1(s_del),
        "fig10_leafscan_mtree": m.leaf_io_count(),
        "fig10_leafscan_smtree": s_fresh.leaf_io_count(),
        "fig10_leafscan_postdelete": s_del.leaf_io_count(),
        "occupancy_mtree": round(m.stats().occupancy, 3),
        "occupancy_smtree": round(s_fresh.stats().occupancy, 3),
        "occupancy_postdelete": round(s_del.stats().occupancy, 3),
    }
    for k, v in rows.items():
        report(k, v)

    # paper claims
    assert rows["fig10_nn1_smtree_postdelete"] >= rows["fig10_nn1_smtree"], \
        "post-delete tree should be no cheaper (it is bigger, less occupied)"
    assert rows["fig10_leafscan_postdelete"] > rows["fig10_leafscan_smtree"], \
        "post-delete tree must have more leaves (lower occupancy)"
    assert rows["occupancy_postdelete"] < rows["occupancy_smtree"], \
        "post-delete occupancy must drop toward the underflow limit"
    assert rows["occupancy_postdelete"] > 0.38, \
        "occupancy must stay above the 40% underflow limit (minus slack)"
