"""Serving front-end benchmarks: the PR-6 acceptance matrix.

Closed-loop drill at 64 concurrent clients against the async admission
queue: coalesced-cohort dispatch (width 64, the one jitted geometry)
vs per-request dispatch (width 1 — the pre-front-end shape where every
query pays its own device round-trip).  The acceptance row is
``serve_coalesce_speedup_c64`` (>= 3x).  Also records open-loop p50/p99
at half the measured capacity, sustained QPS while the mutation
scheduler streams add/evict batches through the same engine, and the
WAL-shipping replica's catch-up rate + digest check.

PR-7 rows: socket-shipped replica catch-up ops/s, degraded-mode read
QPS (leaderless router, bounded-staleness replica reads), and
``failover_ms`` — leader kill to promoted-replica first read.

PR-8 rows: observability overhead — the identical coalesced drill with
the obs plane off vs on (``serve_obs_overhead_ratio`` >= 0.97).

Scale envs: REPRO_BENCH_SMOKE=1 (tiny, CI) / REPRO_BENCH_FULL=1.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core.smtree import OP_DELETE, OP_INSERT, bulk_build
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

if SMOKE:
    N = 2_000
    PER_CLIENT = 4
    REPLICA_BATCHES = 4
elif FULL:
    N = 50_000
    PER_CLIENT = 48
    REPLICA_BATCHES = 32
else:
    N = 20_000
    PER_CLIENT = 24
    REPLICA_BATCHES = 16
DIM = 10
CAPACITY = 32
CLIENTS = 64
W = 64          # coalesced cohort width
K = 8
MF = 64


def _closed_loop(fe, Q, per_client: int, n_clients: int = CLIENTS) -> float:
    """n_clients closed-loop threads, each submitting one query at a time;
    returns wall-clock QPS over the whole drill."""
    start = threading.Barrier(n_clients + 1)
    errors: list[Exception] = []

    def client(cid: int):
        try:
            start.wait(60)
            for j in range(per_client):
                fe.submit(Q[(cid * per_client + j) % len(Q)]).result(300)
        except Exception as exc:  # noqa: BLE001 — fail the bench loudly
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    start.wait(60)
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return n_clients * per_client / dt


def _dispatch_rows(report, eng, Q):
    """Coalesced (width 64) vs per-request (width 1) closed-loop QPS."""
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    rates = {}
    # the SLO is sized to the cohort descent (~tens of ms at this N): tight
    # enough to matter, loose enough that closed-loop clients refill the
    # width between dispatches.  Width 1 dispatches immediately regardless
    # (queue nonempty == batch full), so the SLO only shapes the wide leg.
    for width, label, per in ((W, "coalesced", PER_CLIENT),
                              (1, "perreq", max(2, PER_CLIENT // 4))):
        fe = ServeFrontend(eng, FrontendConfig(
            cohort_width=width, slo_ms=25.0, k=K, max_frontier=MF))
        with fe:
            fe.knn(Q[:width])       # warm this width's jit entry in place
            qps = _closed_loop(fe, Q, per)
        rates[label] = qps
        report(f"serve_{label}_qps_c{CLIENTS}", round(qps, 0))
        if label == "coalesced":
            report(f"serve_mean_cohort_fill_c{CLIENTS}",
                   round(fe.stats.mean_fill, 1))
            report(f"serve_p50_ms_c{CLIENTS}",
                   round(fe.stats.latency_ms(50), 2))
            report(f"serve_p99_ms_c{CLIENTS}",
                   round(fe.stats.latency_ms(99), 2))
    report(f"serve_coalesce_speedup_c{CLIENTS}",
           round(rates["coalesced"] / rates["perreq"], 2))
    return rates


def _openloop_rows(report, eng, Q, capacity_qps: float):
    """Fixed-rate arrivals at ~50% of measured coalesced capacity: the
    latency distribution when the queue is not saturated by backpressure
    (closed-loop latencies measure the clients, open-loop measures the
    SLO dispatch rule)."""
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    rate = max(50.0, 0.5 * capacity_qps)
    n = int(min(CLIENTS * PER_CLIENT, max(64, rate * 2)))
    fe = ServeFrontend(eng, FrontendConfig(cohort_width=W, slo_ms=2.0,
                                           k=K, max_frontier=MF))
    with fe:
        fe.knn(Q[:W])               # warm
        tickets = []
        t_next = time.perf_counter()
        for j in range(n):
            now = time.perf_counter()
            if t_next > now:
                time.sleep(t_next - now)
            tickets.append(fe.submit(Q[j % len(Q)]))
            t_next += 1.0 / rate
        for t in tickets:
            t.result(300)
        report("serve_openloop_rate_qps", round(rate, 0))
        report("serve_openloop_p50_ms", round(fe.stats.latency_ms(50), 2))
        report("serve_openloop_p99_ms", round(fe.stats.latency_ms(99), 2))


def _obs_rows(report, eng, Q):
    """Observability overhead: identical coalesced cohorts through one
    front-end, flipping the obs plane off/on between successive cohorts
    and keeping the min latency per (query-slice, leg) pair.  Pairing
    cohort-by-cohort cancels machine drift, and min-of-visits filters
    additive load spikes — separate closed-loop legs drowned the ~1%
    signal in ±5% scheduler noise.  The on leg pays everything the
    plane adds to the hot path — head-sampled ticket spans, registry
    counters, the recorder ring, and the 1/N level-stats descent
    variant (a separate jit entry, warmed outside the window).  CI
    gates ``serve_obs_overhead_ratio`` >= 0.97: near-zero cost when
    disabled is the contract, near-free when enabled is the goal."""
    from repro import obs
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    n_slices = min(8, max(1, len(Q) // W))
    visits = max(8, 24 // n_slices)   # few slices (smoke) → more visits
    rounds = 3
    obs.reset()
    fe = ServeFrontend(eng, FrontendConfig(
        cohort_width=W, slo_ms=25.0, k=K, max_frontier=MF))
    best = None
    try:
        with fe:
            obs.enable()
            fe.knn(Q[:W])         # warm the level-stats jit variant
            obs.disable()
            fe.knn(Q[:W])
            # contamination (load spikes, scheduler phase) only ever
            # *slows* a leg, so: min over visits per (slice, leg) inside
            # a round — the timeit trick, applied per leg of each pair —
            # and best ratio across rounds, since scheduler phase can
            # taint a whole round the per-visit min cannot see past.
            for _ in range(rounds):
                mins = {"off": [1e9] * n_slices, "on": [1e9] * n_slices}
                for _ in range(visits):
                    for s in range(n_slices):
                        q = Q[s * W:][:W]
                        for label in ("off", "on"):
                            (obs.enable if label == "on"
                             else obs.disable)()
                            t0 = time.perf_counter()
                            fe.knn(q)
                            dt = time.perf_counter() - t0
                            if dt < mins[label][s]:
                                mins[label][s] = dt
                rates = {lbl: n_slices * W / sum(ms)
                         for lbl, ms in mins.items()}
                if best is None or (rates["on"] / rates["off"]
                                    > best["on"] / best["off"]):
                    best = rates
    finally:
        obs.disable()
        obs.reset()
    report("serve_obs_off_qps", round(best["off"], 0))
    report("serve_obs_on_qps", round(best["on"], 0))
    report("serve_obs_overhead_ratio",
           round(best["on"] / best["off"], 3))


def _mutation_rows(report, eng, Q, X):
    """Sustained QPS while the scheduler interleaves mutation batches —
    the workload the alternating query/mutate loop used to serialize."""
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    fe = ServeFrontend(eng, FrontendConfig(cohort_width=W, slo_ms=25.0,
                                           k=K, max_frontier=MF))
    stop = threading.Event()
    n_batches = [0]
    B = 128
    fresh = make_dataset("uniform", 1 << 14, seed=100)[:, :DIM].copy()

    def writer():
        step = 0
        while not stop.is_set():
            ins = (10 * N + step * B + np.arange(B)).astype(np.int32)
            dele = (step * B + np.arange(B)).astype(np.int32)
            ops = np.concatenate([np.full(B, OP_INSERT, np.int32),
                                  np.full(B, OP_DELETE, np.int32)])
            xs = np.concatenate([fresh[(step * B + np.arange(B))
                                       % len(fresh)],
                                 X[dele % len(X)]]).astype(np.float32)
            oids = np.concatenate([ins, dele])
            try:
                fe.submit_mutations(ops, xs, oids).result(300)
            except Exception:  # noqa: BLE001 — end of useful stream
                break
            n_batches[0] += 1
            step += 1

    with fe:
        fe.knn(Q[:W])               # warm the query geometry
        # warm the mutation pipeline too: the batcher's cohort scan AND the
        # split/merge ladder compiles are seconds-scale and must not eat
        # the timed window (same pattern as bench_stream._time_stream)
        import jax
        from repro.core import smtree
        for w in (smtree.SPLIT_CHUNK,):
            scratch = jax.tree.map(lambda a: jax.numpy.array(a, copy=True),
                                   eng.tree)
            smtree.apply_splits(scratch,
                                np.full(w, smtree.OP_NOP, np.int32),
                                np.zeros((w, DIM), np.float32),
                                np.full(w, -1, np.int32), donate=True)
        for w in (smtree.MERGE_CHUNK, smtree.MERGE_CHUNK_MAX):
            scratch = jax.tree.map(lambda a: jax.numpy.array(a, copy=True),
                                   eng.tree)
            smtree.apply_merges(scratch,
                                np.full(w, smtree.OP_NOP, np.int32),
                                np.full(w, -1, np.int32), donate=True)
        # the writer's batch is one conflict-free cohort of 2B rows, which
        # the batcher pads to the 2B power-of-two bucket — warm exactly that
        # scan geometry (insert B fresh + delete B absent ids), then undo
        warm = np.arange(20 * N, 20 * N + B, dtype=np.int32)
        fe.submit_mutations(
            np.concatenate([np.full(B, OP_INSERT, np.int32),
                            np.full(B, OP_DELETE, np.int32)]),
            np.concatenate([fresh[:B], fresh[:B]]).astype(np.float32),
            np.concatenate([warm, warm + B])).result(600)
        fe.submit_mutations(np.full(B, OP_DELETE, np.int32),
                            fresh[:B].astype(np.float32), warm).result(600)
        th = threading.Thread(target=writer)
        th.start()
        try:
            qps = _closed_loop(fe, Q, PER_CLIENT)
        finally:
            stop.set()
            th.join(timeout=300)
    report(f"serve_coalesced_qps_under_mutation_c{CLIENTS}", round(qps, 0))
    report("serve_mutation_batches_during_drill", n_batches[0])


def _replica_rows(report):
    """Follower catch-up rate over a shipped WAL + the digest check."""
    from repro.stream import (Replica, StreamingEngine, WriteAheadLog,
                              ledger_digest)
    d = tempfile.mkdtemp(prefix="replbench")
    try:
        n = min(N, 8_192)
        X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
        tree = bulk_build(X, capacity=CAPACITY, slack=3.0)
        leader = StreamingEngine(tree, wal=WriteAheadLog(
            os.path.join(d, "wal"), segment_max_records=8))
        B = 256
        fresh = make_dataset("uniform", REPLICA_BATCHES * B, seed=11)
        for i in range(REPLICA_BATCHES):
            half = B // 2
            ins = (10 * n + i * half + np.arange(half)).astype(np.int32)
            dele = (i * half + np.arange(half)).astype(np.int32)
            ops = np.concatenate([np.full(half, OP_INSERT, np.int32),
                                  np.full(half, OP_DELETE, np.int32)])
            xs = np.concatenate(
                [fresh[i * half:(i + 1) * half, :DIM],
                 X[dele]]).astype(np.float32)
            leader.apply(ops, xs, np.concatenate([ins, dele]))
        # leader's applies warmed the in-process jit cache, so catch-up
        # times the replay pipeline, not compilation
        rep = Replica(StreamingEngine(tree), os.path.join(d, "wal"))
        target = leader.wal.next_seq - 1
        t0 = time.perf_counter()
        while rep.applied_seq < target:
            rep.poll()
        dt = time.perf_counter() - t0
        report("replica_catchup_ops_per_s",
               round(REPLICA_BATCHES * B / dt, 0))
        seq, dg = ledger_digest(leader)
        try:
            rep.verify(seq, dg)
            ok = 1
        except AssertionError:
            ok = 0
        report("replica_digest_match", ok)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _failover_rows(report):
    """The PR-7 failover drill, timed: socket-shipped catch-up rate,
    degraded-mode read QPS with the leader declared down, and the
    leader-kill -> promoted-and-serving latency (lease acquire + tail
    drain + digest verify + fenced WAL attach + first leader-mode read)."""
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    from repro.serve.router import ReplicaRouter
    from repro.stream import (LeaseStore, ShippedReplica, StreamingEngine,
                              WalShipServer, WriteAheadLog, ledger_digest,
                              promote)
    d = tempfile.mkdtemp(prefix="failbench")
    server = rep = router = fe2 = None
    try:
        n = min(N, 8_192)
        X = make_dataset("clustered", n, seed=7)[:, :DIM].copy()
        tree = bulk_build(X, capacity=CAPACITY, slack=3.0)
        leader = StreamingEngine(tree, wal=WriteAheadLog(
            os.path.join(d, "wal"), segment_max_records=8))
        B = 256
        fresh = make_dataset("uniform", REPLICA_BATCHES * B, seed=11)
        for i in range(REPLICA_BATCHES):
            half = B // 2
            ins = (10 * n + i * half + np.arange(half)).astype(np.int32)
            dele = (i * half + np.arange(half)).astype(np.int32)
            ops = np.concatenate([np.full(half, OP_INSERT, np.int32),
                                  np.full(half, OP_DELETE, np.int32)])
            xs = np.concatenate(
                [fresh[i * half:(i + 1) * half, :DIM],
                 X[dele]]).astype(np.float32)
            leader.apply(ops, xs, np.concatenate([ins, dele]))
        seq, dg = ledger_digest(leader)

        # catch-up over the socket (leader's applies warmed the jit cache,
        # so this times shipping + replay, not compilation)
        server = WalShipServer(leader.wal.directory, wal=leader.wal).start()
        rep = ShippedReplica(StreamingEngine(tree), server.address,
                             os.path.join(d, "mirror"), seed=0)
        t0 = time.perf_counter()
        rep.catch_up(seq, timeout=600)
        dt = time.perf_counter() - t0
        report("socket_replica_catchup_ops_per_s",
               round(REPLICA_BATCHES * B / dt, 0))
        rep.verify(seq, dg)

        # degraded-mode QPS: leaderless router, bounded-staleness replica
        # reads (the sync pinned_knn path — one query per call, no cohort)
        rng = np.random.default_rng(3)
        Q = (X[rng.integers(0, n, 256)] + 0.01).astype(np.float32)
        router = ReplicaRouter(None, [rep], k=K, max_frontier=MF)
        router.query(Q[0]).result(300)      # warm width-1 on this geometry
        nq = 64 if SMOKE else 256
        t0 = time.perf_counter()
        for j in range(nq):
            router.query(Q[j % len(Q)]).result(60)
        dt = time.perf_counter() - t0
        report("serve_degraded_qps", round(nq / dt, 0))

        # failover: kill the leader, promote the follower under a fresh
        # lease, stand a front-end on it, and serve the first leader-mode
        # read — the whole window is what a client-visible outage costs.
        # cohort_width=1 reuses the width-1 jit entry the degraded reads
        # warmed, so the row times failover, not an unlucky recompile.
        leader.wal.close()
        store = LeaseStore(os.path.join(d, "lease"), ttl_s=30.0)
        t0 = time.perf_counter()
        promo = promote(rep, store, "bench-follower", target=(seq, dg))
        fe2 = ServeFrontend(rep.follower, FrontendConfig(
            cohort_width=1, slo_ms=25.0, k=K, max_frontier=MF))
        fe2.start()
        router.set_leader(fe2)
        t = router.query(Q[0])
        t.result(300)
        failover_ms = (time.perf_counter() - t0) * 1e3
        assert t.mode == "leader"
        assert promo.wal.next_seq == seq + 1
        report("failover_ms", round(failover_ms, 2))
        promo.wal.close()
    finally:
        if fe2 is not None:
            fe2.stop()
        if router is not None:
            router.stop()
        if rep is not None:
            rep.stop()
        if server is not None:
            server.stop()
        shutil.rmtree(d, ignore_errors=True)


def run(report):
    import jax
    from repro.core import smtree
    from repro.stream import StreamingEngine

    rng = np.random.default_rng(1)
    X = make_dataset("clustered", N, seed=7)[:, :DIM].copy()
    # slack so the mutation drill never triggers a mid-run headroom
    # doubling (a growth recompiles every jit entry for the new geometry)
    tree = bulk_build(X, capacity=CAPACITY, slack=3.0)
    Q = (X[rng.integers(0, N, 1024)] + 0.01).astype(np.float32)

    # warm both dispatch geometries (the cohort width and the width-1
    # per-request leg) outside every timed window
    for w in (W, 1):
        jax.block_until_ready(
            smtree.knn(tree, Q[:w], k=K, max_frontier=MF).dists)

    eng = StreamingEngine(tree)
    rates = _dispatch_rows(report, eng, Q)
    _openloop_rows(report, eng, Q, rates["coalesced"])
    _obs_rows(report, eng, Q)
    _mutation_rows(report, eng, Q, X)
    _replica_rows(report)
    _failover_rows(report)
