"""Validate a BENCH_<tag>.json artifact (CI bench-smoke gate).

Fails (exit 1) when the file is missing/unreadable, a ``--require``'d suite
is absent or empty, or any recorded value is missing/NaN/inf — so the perf
plumbing cannot silently rot into a benchmark that "runs" but records
nothing.

With ``--baseline`` it also gates against a committed artifact: each
``--min-ratio suite:row:ratio`` spec fails when
``new < ratio * baseline`` for a higher-is-better row (ops/sec).  Ratios
should be loose (CI machines differ from the one that recorded the
baseline) — the gate exists to catch order-of-magnitude regressions in the
mutate hot path, not percent-level noise.

    python benchmarks/check_bench.py benchmarks/BENCH_ci.json \
        --require bench_engine \
        [--require-row bench_engine:serve_single_ms_per_step] \
        [--baseline benchmarks/BENCH_PR3.json \
         --min-ratio bench_stream:stream_mixed50_b256_ops_per_s:0.35]
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def check(path: str, require: list[str], require_rows: list[str],
          baseline: str | None = None,
          min_ratios: list[str] | None = None) -> list[str]:
    problems: list[str] = []
    try:
        data = _load(path)
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    except json.JSONDecodeError as e:
        return [f"invalid JSON in {path}: {e}"]

    suites = data.get("suites", {})
    for s in require:
        if s not in suites or not suites[s]:
            problems.append(f"required suite {s!r} missing or empty")
    for spec in require_rows:
        s, _, row = spec.partition(":")
        if row not in suites.get(s, {}):
            problems.append(f"required row {spec!r} missing")
    for s, rows in suites.items():
        for name, v in rows.items():
            if v is None:
                problems.append(f"{s}:{name} is null")
            elif isinstance(v, float) and not math.isfinite(v):
                problems.append(f"{s}:{name} is {v}")

    base_suites = None
    for spec in (min_ratios or []):
        if baseline is None:
            problems.append("--min-ratio given without --baseline")
            break
        if base_suites is None:
            try:
                base_suites = _load(baseline).get("suites", {})
            except (OSError, json.JSONDecodeError) as e:
                problems.append(f"cannot read baseline {baseline}: {e}")
                break
        try:
            head, ratio_s = spec.rsplit(":", 1)
            s, _, row = head.partition(":")
            ratio = float(ratio_s)
            if not row:
                raise ValueError(spec)
        except ValueError:
            problems.append(f"malformed --min-ratio spec {spec!r} "
                            "(want suite:row:ratio)")
            continue
        base = base_suites.get(s, {}).get(row)
        new = suites.get(s, {}).get(row)
        if base is None:
            problems.append(f"baseline row {s}:{row} missing in {baseline}")
        elif new is None:
            problems.append(f"row {s}:{row} missing in {path}")
        elif float(new) < ratio * float(base):
            problems.append(
                f"{s}:{row} regressed: {new} < {ratio} * baseline {base}")
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--require", action="append", default=[],
                    help="suite that must be present and non-empty")
    ap.add_argument("--require-row", action="append", default=[],
                    help="suite:row that must be present")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_<tag>.json to gate regressions "
                         "against")
    ap.add_argument("--min-ratio", action="append", default=[],
                    help="suite:row:ratio — fail when new < ratio * "
                         "baseline (higher-is-better rows)")
    args = ap.parse_args(argv)
    problems = check(args.path, args.require, args.require_row,
                     args.baseline, args.min_ratio)
    if problems:
        for p in problems:
            print(f"BENCH CHECK FAIL: {p}", file=sys.stderr)
        raise SystemExit(1)
    print(f"bench artifact ok: {args.path}")


if __name__ == "__main__":
    main()
