"""Validate a BENCH_<tag>.json artifact (CI bench-smoke gate).

Fails (exit 1) when the file is missing/unreadable, a ``--require``'d suite
is absent or empty, or any recorded value is missing/NaN/inf — so the perf
plumbing cannot silently rot into a benchmark that "runs" but records
nothing.

    python benchmarks/check_bench.py benchmarks/BENCH_ci.json \
        --require bench_engine [--require-row bench_engine:serve_single_ms_per_step]
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def check(path: str, require: list[str], require_rows: list[str]) -> list[str]:
    problems: list[str] = []
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    except json.JSONDecodeError as e:
        return [f"invalid JSON in {path}: {e}"]

    suites = data.get("suites", {})
    for s in require:
        if s not in suites or not suites[s]:
            problems.append(f"required suite {s!r} missing or empty")
    for spec in require_rows:
        s, _, row = spec.partition(":")
        if row not in suites.get(s, {}):
            problems.append(f"required row {spec!r} missing")
    for s, rows in suites.items():
        for name, v in rows.items():
            if v is None:
                problems.append(f"{s}:{name} is null")
            elif isinstance(v, float) and not math.isfinite(v):
                problems.append(f"{s}:{name} is {v}")
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--require", action="append", default=[],
                    help="suite that must be present and non-empty")
    ap.add_argument("--require-row", action="append", default=[],
                    help="suite:row that must be present")
    args = ap.parse_args(argv)
    problems = check(args.path, args.require, args.require_row)
    if problems:
        for p in problems:
            print(f"BENCH CHECK FAIL: {p}", file=sys.stderr)
        raise SystemExit(1)
    print(f"bench artifact ok: {args.path}")


if __name__ == "__main__":
    main()
