"""§Roofline table: read dry-run artifacts, derive the three terms and the
achieved-fraction metric, print the 40-cell table.

Fraction metric: decode steps are *bandwidth*-bound by construction (one
token against all params + cache), so the honest yardstick is
    ideal_s  = max( MODEL_FLOPS_chip / peak,  must_bytes_chip / HBM_bw )
    frac     = ideal_s / step_s,   step_s = max(compute, memory, collective)
with must_bytes = params(+cache) for inference, 2x(params+opt moments) for
training (read+write of the update is irreducible traffic).
"""
from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_rows(mesh: str = "pod"):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skipped",
                         "reason": r["reason"][:60]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "error"})
            continue
        roof = r["roofline"]
        static = r.get("static_memory", {})
        must = static.get("params_bytes_dev", 0) + \
            static.get("cache_bytes_dev", 0)
        if r["shape"].startswith("train"):
            must = 2 * (static.get("params_bytes_dev", 0)
                        + static.get("opt_bytes_dev", 0))
        ideal = max(roof["model_flops"] / PEAK_FLOPS, must / HBM_BW)
        step = roof["step_s"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"],
            "bottleneck": roof["bottleneck"],
            "useful_ratio": roof["useful_ratio"],
            "frac": ideal / step if step else 0.0,
            "step_s": step,
            "params_gib_dev": static.get("params_bytes_dev", 0) / 2**30,
            "opt_gib_dev": static.get("opt_bytes_dev", 0) / 2**30,
            "cache_gib_dev": static.get("cache_bytes_dev", 0) / 2**30,
            "compile_s": r.get("compile_s"),
        })
    return rows


def run(report):
    for mesh in ("pod", "multipod"):
        rows = load_rows(mesh)
        ok = [r for r in rows if r["status"] == "ok"]
        report(f"dryrun_cells_ok[{mesh}]", len(ok))
        report(f"dryrun_cells_skipped[{mesh}]",
               sum(1 for r in rows if r["status"] == "skipped"))
        report(f"dryrun_cells_error[{mesh}]",
               sum(1 for r in rows if r["status"] == "error"))
        if mesh == "pod":
            for r in ok:
                report(f"roofline_frac[{r['arch']}|{r['shape']}]",
                       round(r["frac"], 4))
    print_table("pod")


def print_table(mesh: str = "pod"):
    rows = load_rows(mesh)
    hdr = (f"{'arch':18s} {'shape':12s} {'cmp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'bneck':>10s} {'frac':>7s} {'useful':>7s} "
           f"{'par/dev':>8s}")
    print("\n== Roofline:", mesh, "==")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:18s} {r['shape']:12s} "
                  f"{'(' + r['status'] + ')':>8s}")
            continue
        print(f"{r['arch']:18s} {r['shape']:12s} {r['compute_s']:8.3f} "
              f"{r['memory_s']:8.3f} {r['collective_s']:8.3f} "
              f"{r['bottleneck']:>10s} {r['frac']:7.4f} "
              f"{r['useful_ratio']:7.3f} {r['params_gib_dev']:7.2f}G")


if __name__ == "__main__":
    print_table("pod")
    print_table("multipod")
