"""JAX SM-tree engine benchmarks: jitted batched-query throughput, bulk
build, engine-vs-ref page-hit comparison, insert/delete fast-path rates."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.ref_impl import SMTree
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
N = 50_000 if FULL else 10_000
BATCH = 64


def run(report):
    X = make_dataset("clustered", N, seed=7)[:, :10].copy()
    t0 = time.time()
    eng = SMTreeEngine.build(X, capacity=32)
    report("bulk_build_seconds", round(time.time() - t0, 2))
    report("bulk_build_objects_per_s", int(N / (time.time() - t0)))

    rng = np.random.default_rng(8)
    Q = X[rng.integers(0, N, BATCH)] + rng.normal(0, 0.01, (BATCH, 10)) \
        .astype(np.float32)
    Qj = jnp.asarray(Q)

    # jitted batched kNN throughput
    res = eng.knn(Qj, k=10, max_frontier=256)      # compile + warm
    jax.block_until_ready(res.dists)
    t0 = time.time()
    iters = 20
    for _ in range(iters):
        res = eng.knn(Qj, k=10, max_frontier=256)
    jax.block_until_ready(res.dists)
    dt = (time.time() - t0) / iters
    report("engine_knn10_us_per_query", round(dt / BATCH * 1e6, 1))
    report("engine_knn10_batch_ms", round(dt * 1e3, 2))
    report("engine_knn10_mean_page_hits",
           round(float(np.asarray(res.page_hits).mean()), 1))
    report("engine_knn10_mean_dist_evals",
           round(float(np.asarray(res.dist_evals).mean()), 1))

    # ref-impl page hits on the same workload (paper-faithful DFS order)
    ref = SMTree(dim=10, capacity=32, n_dims=10)
    for i, x in enumerate(X[:N // 4]):              # smaller ref for time
        ref.insert(x, i)
    tot = 0
    for q in Q[:16]:
        ref.reset_counters()
        ref.knn_query(q, 10)
        tot += ref.ios
    report("ref_knn10_mean_page_hits_quarter_tree", round(tot / 16, 1))

    # insert/delete fast-path hit rates (amortised split/merge frequency)
    extra = make_dataset("uniform", 1000, seed=9)[:, :10].copy()
    n_split = 0
    t0 = time.time()
    from repro.core.smtree import insert_fast
    tree = eng.tree
    for i, x in enumerate(extra):
        new_tree, fits, _ = insert_fast(tree, jnp.asarray(x), jnp.int32(N + i))
        if bool(fits):
            tree = new_tree
        else:
            n_split += 1
            eng.tree = tree
            eng.insert(x, N + i)
            tree = eng.tree
    eng.tree = tree
    report("insert_fastpath_rate", round(1 - n_split / len(extra), 3))
    report("insert_us_per_op", round((time.time() - t0) / len(extra) * 1e6, 0))

    n_under = 0
    t0 = time.time()
    from repro.core.smtree import delete_fast
    for i, x in enumerate(extra[:500]):
        new_tree, found, underflow, _ = delete_fast(
            eng.tree, jnp.asarray(x), jnp.int32(N + i))
        assert bool(found)
        if bool(underflow):
            n_under += 1
            eng.delete(x, N + i)
        else:
            eng.tree = new_tree
    report("delete_fastpath_rate", round(1 - n_under / 500, 3))
    report("delete_us_per_op", round((time.time() - t0) / 500 * 1e6, 0))
