"""JAX SM-tree engine benchmarks.

The centrepiece is the query-matrix bench: batched kNN throughput over
b x n x metric x impl, where impl toggles the frontier-scoring engine
(``REPRO_FRONTIER_IMPL`` semantics — 'perquery' is the legacy
vmap(per-query) baseline, the cohort path runs as 'pallas' on TPU / 'xla'
elsewhere).  ``speedup_cohort_vs_perquery_*`` rows record the headline
number; the Pallas interpret path is correctness-only and excluded from
timing off-TPU.

The parent-distance pre-filter matrix (DESIGN.md §17) compares the cohort
descent with the filter on vs off — wall time and metric evals per query —
and emits the ``frontier_parent_prune_*`` gate rows CI checks.

Also: bulk build, engine-vs-ref page hits, insert/delete fast-path rates,
and the sharded-serve-vs-single-device decode comparison (ROADMAP item) run
as subprocesses over ``repro.launch.serve``.

Scale envs: REPRO_BENCH_SMOKE=1 (tiny, CI) / REPRO_BENCH_FULL=1 (paper
scale); default is the PR-acceptance matrix (b up to 1024, n up to 100k).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.ref_impl import SMTree
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

if SMOKE:
    NS = [2_000]
    BATCHES = [1, 8]
elif FULL:
    NS = [10_000, 100_000, 500_000]
    BATCHES = [1, 64, 1024, 4096]
else:
    NS = [10_000, 100_000]
    BATCHES = [1, 64, 1024]
METRICS = ["d_inf", "l2"]
# the parent-distance pre-filter comparison covers every metric the
# descent supports, at the largest dataset of the run
PRUNE_METRICS = ["d_inf", "l2", "l1"]
# eval-ratio the gate row demands (pruned/unpruned metric evals): the PR
# acceptance number, >= 25% of evals eliminated.  Holds at every scale —
# at smoke scale the pre-eval parent upper bound leaves an even larger
# margin (~0.35) than at b=1024 / n=100k (~0.74).
PRUNE_EVAL_TARGET = 0.75
K = 10
MAX_FRONTIER = 64
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cohort_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _time_knn(eng, Q, impl, **kw) -> float:
    """Warm (compile) then time; iteration count adapts to per-call cost."""
    res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl, **kw)
    jax.block_until_ready(res.dists)
    t0 = time.perf_counter()
    res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl, **kw)
    jax.block_until_ready(res.dists)
    warm = time.perf_counter() - t0
    iters = max(3, min(20, int(2.0 / max(warm, 1e-4))))
    t0 = time.perf_counter()
    for _ in range(iters):
        res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl, **kw)
    jax.block_until_ready(res.dists)
    return (time.perf_counter() - t0) / iters


def _query_matrix(report):
    """knn throughput: b x n x metric x {perquery, cohort}, plus speedups."""
    rng = np.random.default_rng(8)
    cohort = _cohort_impl()
    for n in NS:
        X = make_dataset("clustered", n, seed=7)[:, :10].copy()
        for metric in METRICS:
            t0 = time.perf_counter()
            eng = SMTreeEngine.build(X, capacity=32, metric=metric)
            report(f"bulk_build_n{n}_{metric}_s", round(time.perf_counter() - t0, 2))
            for b in BATCHES:
                Q = jnp.asarray(
                    X[rng.integers(0, n, b)]
                    + rng.normal(0, 0.01, (b, 10)).astype(np.float32),
                    jnp.float32)
                times = {}
                for impl in ("perquery", cohort):
                    dt = _time_knn(eng, Q, impl)
                    times[impl] = dt
                    report(f"knn_b{b}_n{n}_{metric}_{impl}_ms",
                           round(dt * 1e3, 2))
                report(f"speedup_cohort_vs_perquery_b{b}_n{n}_{metric}",
                       round(times["perquery"] / times[cohort], 2))


def _prune_matrix(report):
    """Parent-distance pre-filter (DESIGN.md §17): pruned vs unpruned
    cohort descent at the largest dataset of this run, per metric and
    batch — wall time plus metric evals per query straight off the
    ``QueryResult.dist_evals`` reduction (which counts evaluations
    *performed*, so the filter's savings show up directly).  Emits the
    scale-independent gate rows CI checks:

    * ``frontier_parent_prune_eval_ratio`` — pruned/unpruned evals at the
      largest batch, summed over metrics (lower is better; informational).
    * ``frontier_parent_prune_qps_ratio`` — unpruned/pruned wall time at
      the same config, >= 1 when the mask's overhead doesn't eat the win.
    * ``frontier_parent_prune_ok`` — 1.0 iff the eval ratio meets
      PRUNE_EVAL_TARGET; the row check_bench gates at min-ratio 1.0
      (min-ratio is higher-is-better, so the <=-bound is encoded as a
      boolean row).
    """
    rng = np.random.default_rng(21)
    cohort = _cohort_impl()
    n = NS[-1]
    bs = [b for b in BATCHES if b >= 64] or BATCHES[-1:]
    X = make_dataset("clustered", n, seed=7)[:, :10].copy()
    agg = {"ev_on": 0.0, "ev_off": 0.0, "t_on": 0.0, "t_off": 0.0}
    for metric in PRUNE_METRICS:
        eng = SMTreeEngine.build(X, capacity=32, metric=metric)
        for b in bs:
            Q = jnp.asarray(
                X[rng.integers(0, n, b)]
                + rng.normal(0, 0.01, (b, 10)).astype(np.float32),
                jnp.float32)
            row = {}
            for tag, pp in (("prune", True), ("noprune", False)):
                dt = _time_knn(eng, Q, cohort, parent_prune=pp)
                res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER,
                              impl=cohort, parent_prune=pp)
                ev = float(np.sum(np.asarray(res.dist_evals))) / b
                report(f"knn_b{b}_n{n}_{metric}_{cohort}_{tag}_ms",
                       round(dt * 1e3, 2))
                report(f"dist_evals_per_query_b{b}_n{n}_{metric}_{tag}",
                       round(ev, 1))
                row[tag] = (dt, ev)
            report(f"prune_eval_ratio_b{b}_n{n}_{metric}",
                   round(row["prune"][1] / row["noprune"][1], 3))
            if b == bs[-1]:
                agg["t_on"] += row["prune"][0]
                agg["t_off"] += row["noprune"][0]
                agg["ev_on"] += row["prune"][1]
                agg["ev_off"] += row["noprune"][1]
    ratio = agg["ev_on"] / agg["ev_off"]
    report("frontier_parent_prune_eval_ratio", round(ratio, 3))
    report("frontier_parent_prune_qps_ratio",
           round(agg["t_off"] / agg["t_on"], 3))
    report("frontier_parent_prune_ok",
           1.0 if ratio <= PRUNE_EVAL_TARGET else 0.0)


def _serve_case(report):
    """ROADMAP item: sharded serve (--mesh host over forced host devices) vs
    single-device decode, measured in ms/step via subprocesses (each needs
    its own XLA_FLAGS before jax import)."""
    steps = 4 if SMOKE else 8
    base = [sys.executable, "-m", "repro.launch.serve", "--arch",
            "qwen2.5-3b", "--smoke", "--batch", "8", "--prompt-len", "4",
            "--steps", str(steps)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")

    def run_case(name, cmd, extra_env):
        e = dict(env, **extra_env)
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  env=e, timeout=900)
            m = re.search(r"\(([\d.]+) ms/step", proc.stdout)
            if m is None:
                # surface the failure so a NaN row in CI is diagnosable
                print(f"# serve case {name}: no ms/step in output "
                      f"(rc={proc.returncode})\n"
                      f"# stderr tail: {proc.stderr[-2000:]}", flush=True)
            report(name, float(m.group(1)) if m else float("nan"))
            return float(m.group(1)) if m else float("nan")
        except Exception as exc:  # noqa: BLE001 — a bench row, not control flow
            print(f"# serve case {name} failed: {exc}", flush=True)
            report(name, float("nan"))
            return float("nan")

    single = run_case("serve_single_ms_per_step", base, {})
    sharded = run_case(
        "serve_sharded_ms_per_step", base + ["--mesh", "host"],
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    if np.isfinite(single) and np.isfinite(sharded) and sharded > 0:
        report("serve_sharded_vs_single_ratio", round(single / sharded, 3))


def run(report):
    _query_matrix(report)
    _prune_matrix(report)

    # ref-impl page hits on a comparable workload (paper-faithful DFS order)
    n_ref = 500 if SMOKE else 2_500
    X = make_dataset("clustered", n_ref * 4, seed=7)[:, :10].copy()
    rng = np.random.default_rng(8)
    ref = SMTree(dim=10, capacity=32, n_dims=10)
    for i, x in enumerate(X[:n_ref]):
        ref.insert(x, i)
    tot = 0
    for q in X[:16]:
        ref.reset_counters()
        ref.knn_query(q, K)
        tot += ref.ios
    report("ref_knn10_mean_page_hits", round(tot / 16, 1))

    # insert/delete fast-path hit rates (amortised split/merge frequency)
    eng = SMTreeEngine.build(X, capacity=32)
    n_base = len(X)
    extra = make_dataset("uniform", 200 if SMOKE else 1000, seed=9)[:, :10].copy()
    from repro.core.smtree import delete_fast, insert_fast
    n_split = 0
    t0 = time.time()
    tree = eng.tree
    for i, x in enumerate(extra):
        new_tree, fits, _ = insert_fast(tree, jnp.asarray(x),
                                        jnp.int32(n_base + i))
        if bool(fits):
            tree = new_tree
        else:
            n_split += 1
            eng.tree = tree
            eng.insert(x, n_base + i)
            tree = eng.tree
    eng.tree = tree
    report("insert_fastpath_rate", round(1 - n_split / len(extra), 3))
    report("insert_us_per_op",
           round((time.time() - t0) / len(extra) * 1e6, 0))

    n_del = len(extra) // 2
    n_under = 0
    t0 = time.time()
    for i, x in enumerate(extra[:n_del]):
        new_tree, found, underflow, _ = delete_fast(
            eng.tree, jnp.asarray(x), jnp.int32(n_base + i))
        assert bool(found)
        if bool(underflow):
            n_under += 1
            eng.delete(x, n_base + i)
        else:
            eng.tree = new_tree
    report("delete_fastpath_rate", round(1 - n_under / n_del, 3))
    report("delete_us_per_op", round((time.time() - t0) / n_del * 1e6, 0))

    _serve_case(report)
