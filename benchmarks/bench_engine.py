"""JAX SM-tree engine benchmarks.

The centrepiece is the query-matrix bench: batched kNN throughput over
b x n x metric x impl, where impl toggles the frontier-scoring engine
(``REPRO_FRONTIER_IMPL`` semantics — 'perquery' is the legacy
vmap(per-query) baseline, the cohort path runs as 'pallas' on TPU / 'xla'
elsewhere).  ``speedup_cohort_vs_perquery_*`` rows record the headline
number; the Pallas interpret path is correctness-only and excluded from
timing off-TPU.

Also: bulk build, engine-vs-ref page hits, insert/delete fast-path rates,
and the sharded-serve-vs-single-device decode comparison (ROADMAP item) run
as subprocesses over ``repro.launch.serve``.

Scale envs: REPRO_BENCH_SMOKE=1 (tiny, CI) / REPRO_BENCH_FULL=1 (paper
scale); default is the PR-acceptance matrix (b up to 1024, n up to 100k).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.ref_impl import SMTree
from repro.data.datagen import make_dataset

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

if SMOKE:
    NS = [2_000]
    BATCHES = [1, 8]
elif FULL:
    NS = [10_000, 100_000, 500_000]
    BATCHES = [1, 64, 1024, 4096]
else:
    NS = [10_000, 100_000]
    BATCHES = [1, 64, 1024]
METRICS = ["d_inf", "l2"]
K = 10
MAX_FRONTIER = 64
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cohort_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _time_knn(eng, Q, impl) -> float:
    """Warm (compile) then time; iteration count adapts to per-call cost."""
    res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl)
    jax.block_until_ready(res.dists)
    t0 = time.perf_counter()
    res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl)
    jax.block_until_ready(res.dists)
    warm = time.perf_counter() - t0
    iters = max(3, min(20, int(2.0 / max(warm, 1e-4))))
    t0 = time.perf_counter()
    for _ in range(iters):
        res = eng.knn(Q, k=K, max_frontier=MAX_FRONTIER, impl=impl)
    jax.block_until_ready(res.dists)
    return (time.perf_counter() - t0) / iters


def _query_matrix(report):
    """knn throughput: b x n x metric x {perquery, cohort}, plus speedups."""
    rng = np.random.default_rng(8)
    cohort = _cohort_impl()
    for n in NS:
        X = make_dataset("clustered", n, seed=7)[:, :10].copy()
        for metric in METRICS:
            t0 = time.perf_counter()
            eng = SMTreeEngine.build(X, capacity=32, metric=metric)
            report(f"bulk_build_n{n}_{metric}_s", round(time.perf_counter() - t0, 2))
            for b in BATCHES:
                Q = jnp.asarray(
                    X[rng.integers(0, n, b)]
                    + rng.normal(0, 0.01, (b, 10)).astype(np.float32),
                    jnp.float32)
                times = {}
                for impl in ("perquery", cohort):
                    dt = _time_knn(eng, Q, impl)
                    times[impl] = dt
                    report(f"knn_b{b}_n{n}_{metric}_{impl}_ms",
                           round(dt * 1e3, 2))
                report(f"speedup_cohort_vs_perquery_b{b}_n{n}_{metric}",
                       round(times["perquery"] / times[cohort], 2))


def _serve_case(report):
    """ROADMAP item: sharded serve (--mesh host over forced host devices) vs
    single-device decode, measured in ms/step via subprocesses (each needs
    its own XLA_FLAGS before jax import)."""
    steps = 4 if SMOKE else 8
    base = [sys.executable, "-m", "repro.launch.serve", "--arch",
            "qwen2.5-3b", "--smoke", "--batch", "8", "--prompt-len", "4",
            "--steps", str(steps)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")

    def run_case(name, cmd, extra_env):
        e = dict(env, **extra_env)
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  env=e, timeout=900)
            m = re.search(r"\(([\d.]+) ms/step", proc.stdout)
            if m is None:
                # surface the failure so a NaN row in CI is diagnosable
                print(f"# serve case {name}: no ms/step in output "
                      f"(rc={proc.returncode})\n"
                      f"# stderr tail: {proc.stderr[-2000:]}", flush=True)
            report(name, float(m.group(1)) if m else float("nan"))
            return float(m.group(1)) if m else float("nan")
        except Exception as exc:  # noqa: BLE001 — a bench row, not control flow
            print(f"# serve case {name} failed: {exc}", flush=True)
            report(name, float("nan"))
            return float("nan")

    single = run_case("serve_single_ms_per_step", base, {})
    sharded = run_case(
        "serve_sharded_ms_per_step", base + ["--mesh", "host"],
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    if np.isfinite(single) and np.isfinite(sharded) and sharded > 0:
        report("serve_sharded_vs_single_ratio", round(single / sharded, 3))


def run(report):
    _query_matrix(report)

    # ref-impl page hits on a comparable workload (paper-faithful DFS order)
    n_ref = 500 if SMOKE else 2_500
    X = make_dataset("clustered", n_ref * 4, seed=7)[:, :10].copy()
    rng = np.random.default_rng(8)
    ref = SMTree(dim=10, capacity=32, n_dims=10)
    for i, x in enumerate(X[:n_ref]):
        ref.insert(x, i)
    tot = 0
    for q in X[:16]:
        ref.reset_counters()
        ref.knn_query(q, K)
        tot += ref.ios
    report("ref_knn10_mean_page_hits", round(tot / 16, 1))

    # insert/delete fast-path hit rates (amortised split/merge frequency)
    eng = SMTreeEngine.build(X, capacity=32)
    n_base = len(X)
    extra = make_dataset("uniform", 200 if SMOKE else 1000, seed=9)[:, :10].copy()
    from repro.core.smtree import delete_fast, insert_fast
    n_split = 0
    t0 = time.time()
    tree = eng.tree
    for i, x in enumerate(extra):
        new_tree, fits, _ = insert_fast(tree, jnp.asarray(x),
                                        jnp.int32(n_base + i))
        if bool(fits):
            tree = new_tree
        else:
            n_split += 1
            eng.tree = tree
            eng.insert(x, n_base + i)
            tree = eng.tree
    eng.tree = tree
    report("insert_fastpath_rate", round(1 - n_split / len(extra), 3))
    report("insert_us_per_op",
           round((time.time() - t0) / len(extra) * 1e6, 0))

    n_del = len(extra) // 2
    n_under = 0
    t0 = time.time()
    for i, x in enumerate(extra[:n_del]):
        new_tree, found, underflow, _ = delete_fast(
            eng.tree, jnp.asarray(x), jnp.int32(n_base + i))
        assert bool(found)
        if bool(underflow):
            n_under += 1
            eng.delete(x, n_base + i)
        else:
            eng.tree = new_tree
    report("delete_fastpath_rate", round(1 - n_under / n_del, 3))
    report("delete_us_per_op", round((time.time() - t0) / n_del * 1e6, 0))

    _serve_case(report)
