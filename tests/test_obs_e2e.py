"""Observability end-to-end: one query ticket's spans form a single
connected trace across router -> frontend dispatch (and the replica-serve
leg), the mutation trace reaches the WAL/apply/publish spans plus the
replica's replay leg, the metrics snapshot covers every serving layer,
metrics exposition works over the ship-server socket, and the router's
degraded -> leader recovery resets the staleness gauges."""
import numpy as np
import pytest

from repro import obs
from repro.core.smtree import bulk_build
from repro.obs.export import fetch_metrics, metrics_snapshot, missing_rows
from repro.serve.frontend import FrontendConfig, ServeFrontend
from repro.serve.router import ReplicaRouter
from repro.stream import Replica, StreamingEngine, WriteAheadLog
from repro.stream.faults import FaultInjector, FaultPlan
from repro.stream.transport import WalShipServer

N, DIM = 300, 6


@pytest.fixture
def obs_on(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS_DUMP_DIR", str(tmp_path))
    obs.reset()
    obs.enable()
    obs.set_trace_sampling(1)        # trace every root: tests need them all
    yield
    obs.disable()
    obs.set_trace_sampling(obs.TRACE_SAMPLE_EVERY)
    obs.reset()


def _stack(tmp_path, seed=0):
    """Leader engine + front-end + one filesystem replica."""
    X = np.random.default_rng(seed).random((N, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"))
    leader = StreamingEngine(tree0, wal=wal)
    fe = ServeFrontend(leader, FrontendConfig(cohort_width=4, slo_ms=5.0,
                                              k=3, max_frontier=256)).start()
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))
    return X, leader, fe, rep


def _mutation(n=4, start=900):
    return (np.full(n, 1, np.int32),
            np.full((n, DIM), 0.5, np.float32),
            np.arange(start, start + n, dtype=np.int32))


# ------------------------------------------------------------ query traces

def test_leader_query_trace_is_connected(tmp_path, obs_on):
    X, leader, fe, rep = _stack(tmp_path)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)
    q = np.random.default_rng(1).random(DIM).astype(np.float32)
    tk = router.query(q)
    tk.result(30)
    records = obs.RECORDER.records()
    assert tk.trace_id is not None
    spans = obs.assemble_trace(records, tk.trace_id)
    names = {s["name"] for s in spans}
    # admit -> cohort assembly -> epoch pin -> device compute -> reply,
    # all under the router's root span
    assert {"router.query", "frontend.query", "frontend.cohort",
            "frontend.epoch_pin", "frontend.device_compute",
            "frontend.reply"} <= names
    assert obs.trace_connected(records, tk.trace_id)
    by_name = {s["name"]: s for s in spans}
    assert by_name["frontend.query"]["parent_id"] == \
        by_name["router.query"]["span_id"]
    assert by_name["frontend.query"]["attrs"]["epoch"] == tk.epoch
    fe.stop()


def test_replica_query_trace_is_connected(tmp_path, obs_on):
    X, leader, fe, rep = _stack(tmp_path, seed=2)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256,
                           prefer_replicas=True)
    tk = router.query(np.random.default_rng(3).random(DIM)
                      .astype(np.float32))
    tk.result(30)
    assert tk.mode == "replica"
    records = obs.RECORDER.records()
    spans = obs.assemble_trace(records, tk.trace_id)
    names = {s["name"] for s in spans}
    assert {"router.query", "router.replica_serve"} <= names
    assert obs.trace_connected(records, tk.trace_id)
    fe.stop()


def test_cohort_links_join_coalesced_tickets(tmp_path, obs_on):
    """Two tickets coalesced into one cohort: the non-primary ticket's
    trace still reaches the shared cohort span through the link."""
    X, leader, fe, rep = _stack(tmp_path, seed=4)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)
    qs = np.random.default_rng(5).random((4, DIM)).astype(np.float32)
    tickets = [router.query(q) for q in qs]
    [t.result(30) for t in tickets]
    records = obs.RECORDER.records()
    cohorts = [s for s in obs.RECORDER.spans()
               if s["name"] == "frontend.cohort"]
    assert cohorts
    for tk in tickets:
        names = {s["name"] for s in obs.assemble_trace(records, tk.trace_id)}
        assert "frontend.cohort" in names       # direct child or via link
        assert obs.trace_connected(records, tk.trace_id)
    fe.stop()


# ---------------------------------------------------------- mutation trace

def test_mutation_trace_reaches_wal_apply_publish(tmp_path, obs_on):
    X, leader, fe, rep = _stack(tmp_path, seed=6)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)
    router.mutate(*_mutation())
    records = obs.RECORDER.records()
    (root,) = [s for s in obs.RECORDER.spans()
               if s["name"] == "router.mutate"]
    spans = obs.assemble_trace(records, root["trace_id"])
    names = {s["name"] for s in spans}
    assert {"router.mutate", "frontend.mutation",
            "frontend.mutation_batch", "mutation.wal_append",
            "mutation.apply", "mutation.publish"} <= names
    assert obs.trace_connected(records, root["trace_id"])
    # the replica's replay leg: its own span, carrying the leader seqs
    assert rep.poll() == 1
    (replay,) = [s for s in obs.RECORDER.spans()
                 if s["name"] == "replica.replay"]
    assert replay["attrs"]["first_seq"] == 0
    assert replay["attrs"]["last_seq"] == 0
    fe.stop()


# --------------------------------------------------------- snapshot + wire

def test_snapshot_covers_every_layer(tmp_path, obs_on):
    X, leader, fe, rep = _stack(tmp_path, seed=7)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)
    router.mutate(*_mutation())
    tk = router.query(np.random.default_rng(8).random(DIM)
                      .astype(np.float32))
    tk.result(30)
    rep.poll()
    router.heartbeat()
    router.snapshot()
    snap = metrics_snapshot()
    assert missing_rows(snap, ["frontend.", "router.", "wal.",
                               "replica.", "descent.", "epoch."]) == []
    # paper-level counters moved: every admitted query pays dist evals
    m = snap["metrics"]
    assert m["descent.queries_total"] >= 1
    assert m["descent.dist_evals_total"] > 0
    assert m["descent.nodes_visited_total"] > 0
    assert m["frontend.latency_s.count"] >= 1
    fe.stop()


def test_fetch_metrics_over_ship_socket(tmp_path, obs_on):
    obs.counter("wal.appends_total").inc(2)
    wal = WriteAheadLog(str(tmp_path / "wal"))
    wal.append_batch(*_mutation(n=2))
    with WalShipServer(str(tmp_path / "wal"), wal=wal) as srv:
        snap = fetch_metrics(srv.address)
    assert snap["enabled"] is True
    assert snap["metrics"]["wal.appends_total"] == 3
    wal.close()


# ----------------------------------------------------- recovery regression

def test_recovery_resets_staleness_gauges(tmp_path, obs_on):
    """Degraded -> leader recovery must reset the router's staleness
    gauges: time_since_heartbeat_s starts counting from the healing
    heartbeat and staleness drops back to 0 (leader reads are fresh)."""
    X, leader, fe, rep = _stack(tmp_path, seed=9)
    rep.poll()
    fault = FaultInjector(FaultPlan(seed=0, heartbeat_drop_p=1.0))
    router = ReplicaRouter(fe, [rep], fault=fault, miss_limit=3,
                           k=3, max_frontier=256)
    for _ in range(3):
        router.heartbeat()            # every delivery starved
    assert not router.leader_up
    s_down = router.snapshot()
    assert s_down["staleness"] >= 0   # degraded: replica lag, not 0
    g = obs.REGISTRY.snapshot()
    assert g["router.leader_up"] == 0.0
    assert g["router.consecutive_misses"] == 3
    # recovery: one healthy heartbeat heals the detector
    router.fault = FaultInjector(FaultPlan())
    assert router.heartbeat()
    s_up = router.snapshot()
    assert s_up["leader_up"]
    assert s_up["staleness"] == 0
    assert 0.0 <= s_up["time_since_heartbeat_s"] < 5.0
    g = obs.REGISTRY.snapshot()
    assert g["router.leader_up"] == 1.0
    assert g["router.consecutive_misses"] == 0
    assert g["router.staleness"] == 0.0
    assert 0.0 <= g["router.time_since_heartbeat_s"] < 5.0
    # the flip left breadcrumbs in the flight recorder
    events = [e["name"] for e in obs.RECORDER.events()]
    assert "router.leader_down" in events
    assert "router.leader_recovered" in events
    fe.stop()
