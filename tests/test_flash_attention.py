"""Flash attention kernel: interpret-mode sweeps vs the naive oracle,
chunked-XLA equivalence, GQA, causal offsets (decode), gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.attention_xla import chunked_attention, decode_attention


def make_qkv(key, b, h, hk, sq, sk, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, sq, d), dtype)
    k = jax.random.normal(k2, (b, hk, sk, d), dtype)
    v = jax.random.normal(k3, (b, hk, sk, d), dtype)
    return q, k, v


CASES = [
    # b, h, hk, sq, sk, d
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),     # GQA g=2, sk > sq (prefix/causal offset)
    (1, 8, 1, 100, 100, 32),     # MQA, non-block-multiple lengths
    (1, 2, 2, 257, 257, 128),
]


@pytest.mark.parametrize("b,h,hk,sq,sk,d", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_pallas_matches_oracle(b, h, hk, sq, sk, d, causal):
    q, k, v = make_qkv(jax.random.PRNGKey(0), b, h, hk, sq, sk, d)
    got = ops.attention(q, k, v, causal=causal, impl="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,h,hk,sq,sk,d", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_xla_matches_oracle(b, h, hk, sq, sk, d, causal):
    q, k, v = make_qkv(jax.random.PRNGKey(1), b, h, hk, sq, sk, d)
    got = chunked_attention(q, k, v, causal=causal, chunk=96)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = make_qkv(jax.random.PRNGKey(2), 1, 4, 2, 128, 128, 64, jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, impl="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


def test_pallas_grads_match_naive():
    q, k, v = make_qkv(jax.random.PRNGKey(3), 1, 2, 1, 64, 64, 32)

    def loss_pallas(q, k, v):
        return jnp.sum(ops.attention(q, k, v, causal=True, impl="interpret") ** 2)

    def loss_naive(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_decode_attention_matches_full():
    """One-token decode vs full attention last row, with ragged kv_len."""
    b, h, hk, S, d = 2, 4, 2, 64, 32
    q, _, _ = make_qkv(jax.random.PRNGKey(4), b, h, hk, 1, S, d)
    _, k, v = make_qkv(jax.random.PRNGKey(40), b, h, hk, 1, S, d)
    kv_len = jnp.array([40, 64])
    got = decode_attention(q, k, v, kv_len=kv_len)
    # oracle: full causal attention over the valid prefix, take last position
    outs = []
    for i in range(b):
        L = int(kv_len[i])
        qi = q[i:i+1, :, :1, :]
        want = ref.flash_attention_ref(qi, k[i:i+1, :, :L], v[i:i+1, :, :L],
                                       causal=False)
        outs.append(want)
    want = jnp.concatenate(outs, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_seq_shard_combine():
    """The safe-softmax (m, l, acc) decomposition combines across cache
    shards: computing decode attention over two halves and merging must match
    the unsharded result — this is the correctness basis for the
    sequence-sharded KV decode path used for long_500k."""
    b, h, hk, S, d = 1, 4, 4, 128, 32
    q, k, v = make_qkv(jax.random.PRNGKey(5), b, h, hk, 1, S, d)
    full = decode_attention(q, k, v)

    def partial_stats(ks, vs):
        s = jnp.einsum("bhgd,bhkd->bhgk",
                       q.reshape(b, hk, h // hk, d) * d ** -0.5, ks)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhgk,bhkd->bhgd", p, vs)
        return m, l, acc

    m1, l1, a1 = partial_stats(k[:, :, :64], v[:, :, :64])
    m2, l2, a2 = partial_stats(k[:, :, 64:], v[:, :, 64:])
    m = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    acc = a1 * jnp.exp(m1 - m) + a2 * jnp.exp(m2 - m)
    merged = (acc / l).reshape(b, h, 1, d)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
