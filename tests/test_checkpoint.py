"""Fault tolerance: checkpoint atomicity, rotation, and bitwise-deterministic
kill/resume (the core large-scale-runnability contract)."""
import os
import tempfile

import numpy as np
import pytest

from repro.dist.checkpoint import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)


def test_save_restore_roundtrip():
    import jax
    import jax.numpy as jnp
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, {"state": tree}, extra={"note": "x"})
        out, manifest = restore_checkpoint(d, {"state": tree})
        assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out["state"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rotation_keeps_latest():
    import jax.numpy as jnp
    tree = {"x": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_write=False)
        for s in [1, 2, 3, 4, 5]:
            mgr.save(s, {"state": tree})
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                       if p.startswith("step_"))
        assert steps == [4, 5]
        assert latest_step(d) == 5


def test_kill_resume_is_deterministic():
    """Uninterrupted N-step run == (run to k, crash, resume) bitwise."""
    from repro.launch import train
    N = 24
    with tempfile.TemporaryDirectory() as d1:
        loss_straight = train.main([
            "--smoke", "--steps", str(N), "--seq-len", "32",
            "--global-batch", "4", "--log-every", "100"])
    with tempfile.TemporaryDirectory() as d2:
        with pytest.raises(SystemExit):
            train.main(["--smoke", "--steps", str(N), "--seq-len", "32",
                        "--global-batch", "4", "--ckpt-dir", d2,
                        "--ckpt-every", "8", "--fail-at", "13",
                        "--log-every", "100"])
        loss_resumed = train.main(["--smoke", "--steps", str(N),
                                   "--seq-len", "32", "--global-batch", "4",
                                   "--ckpt-dir", d2, "--resume",
                                   "--log-every", "100"])
    assert loss_straight == loss_resumed, \
        f"non-deterministic restart: {loss_straight} vs {loss_resumed}"


def test_atomic_write_never_partial():
    """A checkpoint directory either exists completely or not at all."""
    import jax.numpy as jnp
    with tempfile.TemporaryDirectory() as d:
        try:
            save_checkpoint(d, 1, {"state": {"x": jnp.zeros((2,))},
                                   "bad": (lambda: None)})  # unpicklable -> raises
        except Exception:
            pass
        assert latest_step(d) in (None,), "partial checkpoint leaked"


def test_data_pipeline_stateless_deterministic():
    from repro.data.pipeline import DataConfig, synth_batch
    dc = DataConfig(seed=3, vocab_size=1000, seq_len=16, global_batch=4)
    a = synth_batch(dc, 5)
    b = synth_batch(dc, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(dc, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard slices tile the global batch
    full = synth_batch(dc, 5)["tokens"]
    parts = [synth_batch(dc, 5, shard=s, n_shards=2)["tokens"]
             for s in range(2)]
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=0))
