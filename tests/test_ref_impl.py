"""Paper-faithful reference implementation tests: M-tree + SM-tree vs brute
force, structural invariants, and the SM-tree's delete contract."""
import numpy as np
import pytest

from repro.core.metric import pairwise
from repro.core.ref_impl import MTree, SMTree
from repro.data.datagen import clustered, uniform


def brute_knn(metric, X, q, k, n_dims=None):
    d = pairwise(metric, q[None, :], X, n_dims=n_dims)[0]
    idx = np.argsort(d, kind="stable")[:k]
    return [(float(d[i]), int(i)) for i in idx]


def brute_range(metric, X, q, r, n_dims=None):
    d = pairwise(metric, q[None, :], X, n_dims=n_dims)[0]
    return sorted(int(i) for i in np.nonzero(d <= r)[0])


def build(cls, X, **kw):
    t = cls(dim=X.shape[1], **kw)
    for i, x in enumerate(X):
        t.insert(x, i)
    return t


@pytest.mark.parametrize("cls", [MTree, SMTree])
@pytest.mark.parametrize("n_dims", [2, 8, 20])
def test_range_query_matches_brute_force(cls, n_dims):
    X = clustered(600, seed=3)
    t = build(cls, X, capacity=10, n_dims=n_dims)
    t.validate(check_sm_invariant=cls is SMTree)
    rng = np.random.default_rng(0)
    for _ in range(20):
        q = X[rng.integers(len(X))] + rng.normal(0, 0.05, X.shape[1]).astype(np.float32)
        r = float(rng.uniform(0.01, 0.3))
        got = sorted(t.range_query(q, r))
        want = brute_range("d_inf", X, q, r, n_dims=n_dims)
        assert got == want


@pytest.mark.parametrize("cls", [MTree, SMTree])
@pytest.mark.parametrize("k", [1, 10])
def test_knn_matches_brute_force(cls, k):
    X = uniform(500, seed=7)
    t = build(cls, X, capacity=8, n_dims=6)
    rng = np.random.default_rng(1)
    for _ in range(15):
        q = rng.random(X.shape[1]).astype(np.float32)
        got = t.knn_query(q, k)
        want = brute_knn("d_inf", X, q, k, n_dims=6)
        got_d = np.array([d for d, _ in got])
        want_d = np.array([d for d, _ in want])
        np.testing.assert_allclose(got_d, want_d, atol=1e-5)


def test_zero_radius_query_finds_exact_object():
    X = clustered(300, seed=11)
    t = build(SMTree, X, capacity=8, n_dims=20)
    for i in [0, 57, 299]:
        res = t.range_query(X[i], 0.0)
        assert i in res


def test_r0_cheaper_than_nn1():
    """Paper Fig. 7 vs Fig. 5: zero-radius query visits far fewer pages than
    NN-1 (which starts with an infinite search radius)."""
    X = clustered(2000, seed=5)
    t = build(SMTree, X, capacity=16, n_dims=10)
    ios_r0, ios_nn = 0, 0
    for i in range(30):
        t.reset_counters(); t.range_query(X[i], 0.0); ios_r0 += t.ios
        t.reset_counters(); t.knn_query(X[i], 1); ios_nn += t.ios
    assert ios_r0 < ios_nn


def test_sm_insert_maintains_invariant_incrementally():
    X = uniform(400, dims=6, seed=2)
    t = SMTree(dim=6, capacity=6, n_dims=6)
    for i, x in enumerate(X):
        t.insert(x, i)
        if i % 97 == 0:
            t.validate(check_sm_invariant=True)
    t.validate(check_sm_invariant=True)


def test_delete_removes_and_contracts():
    X = clustered(500, dims=8, seed=9)
    t = build(SMTree, X, capacity=8, n_dims=8)
    # delete the outermost object under the root's first entry and check that
    # some covering radius contracted
    radii_before = t.root.radii.copy()
    victims = list(range(0, 500, 3))
    for i in victims:
        assert t.delete(X[i], i), f"object {i} not found"
        assert t.range_query(X[i], 0.0).count(i) == 0
    t.validate(check_sm_invariant=True, check_min_fill=True)
    assert t.n_objects == 500 - len(victims)
    # survivors still all findable
    for i in range(1, 500, 51):
        if i % 3 != 0:
            assert i in t.range_query(X[i], 0.0)
    assert t.root.radii.max() <= radii_before.max() + 1e-6
    # radii really do contract vs a freshly stale tree (erratum fix active):
    # after deleting 1/3 of objects the mean root radius should not be
    # identical to before in a clustered set
    if len(t.root.radii) == len(radii_before):
        assert not np.allclose(t.root.radii, radii_before)


def test_delete_not_found_returns_false():
    X = uniform(100, dims=4, seed=4)
    t = build(SMTree, X, capacity=8, n_dims=4)
    fake = np.full(4, 7.7, dtype=np.float32)
    assert not t.delete(fake, 9999)
    assert t.n_objects == 100


def test_delete_to_empty_and_reinsert():
    X = uniform(120, dims=4, seed=13)
    t = build(SMTree, X, capacity=6, n_dims=4)
    for i in range(120):
        assert t.delete(X[i], i)
    assert t.n_objects == 0
    assert t.height == 1 and t.root.is_leaf
    for i, x in enumerate(X):
        t.insert(x, i)
    t.validate(check_sm_invariant=True)
    assert sorted(t.range_query(X[5], 0.0)).count(5) == 1


def test_insert_delete_interleaved_invariant():
    rng = np.random.default_rng(21)
    X = uniform(300, dims=5, seed=21)
    t = SMTree(dim=5, capacity=6, n_dims=5)
    live = {}
    nid = 0
    for step in range(600):
        if not live or rng.random() < 0.6:
            t.insert(X[nid % 300], nid); live[nid] = nid % 300; nid += 1
        else:
            oid = int(rng.choice(list(live)))
            assert t.delete(X[live.pop(oid)], oid)
        if step % 150 == 0:
            t.validate(check_sm_invariant=True)
    t.validate(check_sm_invariant=True)
    assert t.n_objects == len(live)


def test_trees_are_balanced_and_paged():
    X = clustered(3000, seed=1)
    for cls in (MTree, SMTree):
        t = build(cls, X, capacity=42, n_dims=20)
        t.validate(check_sm_invariant=cls is SMTree)
        st = t.stats()
        assert st.height >= 2
        assert st.n_objects == 3000


def test_sm_radius_upper_bounds_mtree():
    """SM-tree radii are triangle-inequality upper bounds >= the lazily
    expanded M-tree radii for the same data — the paper's stated trade-off."""
    X = clustered(1500, seed=8)
    m = build(MTree, X, capacity=16, n_dims=10)
    s = build(SMTree, X, capacity=16, n_dims=10)
    # compare mean root-level covering radius
    assert s.root.radii.mean() >= m.root.radii.mean() * 0.8  # sanity, not strict
