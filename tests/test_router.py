"""Replica-aware routing: mode stamping (leader/replica/degraded),
read-your-writes session tokens, heartbeat failure detection with
injected starvation, write fail-fast when leaderless, and recovery via
set_leader after promotion."""
import numpy as np
import pytest

from repro.core.metric import pairwise
from repro.core.smtree import bulk_build
from repro.serve.frontend import FrontendConfig, ServeFrontend
from repro.serve.router import (LeaderUnavailable, ReplicaRouter,
                                SessionToken, StaleReplica)
from repro.stream import Replica, StreamingEngine, WriteAheadLog
from repro.stream.faults import FaultInjector, FaultPlan

N, DIM = 300, 6


def _stack(tmp_path, seed=0):
    """Leader engine + front-end + one filesystem replica."""
    X = np.random.default_rng(seed).random((N, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"))
    leader = StreamingEngine(tree0, wal=wal)
    fe = ServeFrontend(leader, FrontendConfig(cohort_width=4, slo_ms=5.0,
                                              k=3, max_frontier=256)).start()
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))
    return X, leader, fe, rep


def test_leader_reads_and_session_tokens(tmp_path):
    X, leader, fe, rep = _stack(tmp_path)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)
    q = np.random.default_rng(1).random(DIM).astype(np.float32)
    tk = router.query(q)
    d, _ = tk.result(30)
    assert tk.mode == "leader" and tk.staleness == 0
    want = np.sort(pairwise(leader.tree.metric, q[None], X), axis=1)[0, :3]
    np.testing.assert_allclose(d, want, atol=1e-5)
    # a write returns a session floor the replica does not yet satisfy
    res, token = router.mutate(
        np.full(4, 1, np.int32),
        np.full((4, DIM), 0.5, np.float32),
        np.arange(900, 904, dtype=np.int32))
    assert token.wal_seq == 0
    assert SessionToken().merge(token) == token
    fe.stop()


def test_replica_mode_respects_session_floor(tmp_path):
    X, leader, fe, rep = _stack(tmp_path, seed=2)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256,
                           prefer_replicas=True)
    _, token = router.mutate(
        np.full(4, 1, np.int32),
        np.full((4, DIM), 0.25, np.float32),
        np.arange(900, 904, dtype=np.int32))
    q = np.full(DIM, 0.25, np.float32)
    # replica has not applied the write: the session floor forces the
    # read back to the leader
    tk = router.query(q, session=token)
    tk.result(30)
    assert tk.mode == "leader"
    # fresh session (no floor): replica serves
    tk2 = router.query(q)
    tk2.result(30)
    assert tk2.mode == "replica"
    # once the replica catches up it satisfies the floor
    rep.poll()
    tk3 = router.query(q, session=token)
    d3, i3 = tk3.result(30)
    assert tk3.mode == "replica"
    assert 900 in i3          # read-your-writes: the insert is visible
    fe.stop()


def test_heartbeat_starvation_degrades_reads(tmp_path):
    X, leader, fe, rep = _stack(tmp_path, seed=3)
    rep.poll()
    fault = FaultInjector(FaultPlan(seed=0, heartbeat_drop_p=1.0))
    router = ReplicaRouter(fe, [rep], fault=fault, miss_limit=3,
                           k=3, max_frontier=256)
    assert router.leader_up
    for _ in range(3):
        router.heartbeat()        # every delivery starved
    assert not router.leader_up
    q = np.random.default_rng(4).random(DIM).astype(np.float32)
    tk = router.query(q)
    d, _ = tk.result(30)
    assert tk.mode == "degraded"
    assert tk.staleness == 0      # caught up before the leader "died"
    want = np.sort(pairwise(leader.tree.metric, q[None], X), axis=1)[0, :3]
    np.testing.assert_allclose(d, want, atol=1e-5)
    with pytest.raises(LeaderUnavailable):
        router.mutate(np.full(1, 1, np.int32),
                      np.zeros((1, DIM), np.float32),
                      np.array([999], np.int32))
    assert router.snapshot()["n_degraded_reads"] == 1
    # one healthy heartbeat heals the detector
    fault2 = FaultInjector(FaultPlan())
    router.fault = fault2
    assert router.heartbeat()
    assert router.leader_up
    fe.stop()


def test_degraded_respects_max_staleness_and_session(tmp_path):
    X, leader, fe, rep = _stack(tmp_path, seed=5)
    rep.poll()
    _, token = None, None
    res, token = ReplicaRouter(fe, [rep]).mutate(
        np.full(4, 1, np.int32), np.full((4, DIM), 0.75, np.float32),
        np.arange(900, 904, dtype=np.int32))
    router = ReplicaRouter(fe, [rep], max_staleness=0, k=3,
                           max_frontier=256)
    router.mark_leader_down()
    # replica is 1 record behind an acknowledged write -> session floor
    # unmet and the leader is gone: explicit error, not silent staleness
    with pytest.raises(StaleReplica):
        router.query(np.zeros(DIM, np.float32), session=token)
    rep.note_leader_seq(token.wal_seq)
    assert rep.lag == 1
    rep.poll()                    # catch up; lag drops to 0
    assert rep.lag == 0
    tk = router.query(np.zeros(DIM, np.float32), session=token)
    tk.result(30)
    assert tk.mode == "degraded" and tk.staleness == 0
    fe.stop()


def test_set_leader_restores_writes(tmp_path):
    X, leader, fe, rep = _stack(tmp_path, seed=6)
    router = ReplicaRouter(fe, [rep])
    router.mark_leader_down()
    with pytest.raises(LeaderUnavailable):
        router.mutate(np.full(1, 1, np.int32),
                      np.zeros((1, DIM), np.float32),
                      np.array([999], np.int32))
    router.set_leader(fe)         # promotion installed a (new) front-end
    res, token = router.mutate(np.full(1, 1, np.int32),
                               np.zeros((1, DIM), np.float32),
                               np.array([999], np.int32))
    assert token.wal_seq == 0
    fe.stop()
