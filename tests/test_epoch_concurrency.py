"""Epoch pinning under concurrent publish.

The contracts the serving front-end leans on:

  * a reader inside ``EpochManager.reading()`` never observes the tree
    swap mid-cohort — the pinned version stays resident (its buffers are
    not retired) no matter how many epochs the writer publishes;
  * release-after-publish frees the superseded snapshot **exactly once**
    (verified with ``weakref.finalize`` — the version object is collected
    after the last release, never before, never twice).
"""
import gc
import threading
import weakref

import numpy as np
import pytest

from repro.stream.epoch import EpochManager


class _Snap:
    """Weakref-able stand-in for a published tree version."""

    def __init__(self, n: int = 0):
        self.n = n


def test_pin_survives_concurrent_publishes():
    mgr = EpochManager(_Snap(0))
    with mgr.reading(with_epoch=True) as (e, t):
        for i in range(1, 6):
            mgr.publish(_Snap(i))
        assert mgr.refs(e) == 1
        assert t.n == 0                      # still the pinned version
        assert e in mgr.resident             # not retired while pinned
        with mgr.reading(with_epoch=True) as (e2, t2):
            assert e2 == e + 5 and t2.n == 5  # new readers get the latest
    assert e not in mgr.resident             # released -> retired


def test_release_after_publish_frees_exactly_once():
    mgr = EpochManager(_Snap())
    freed = []
    e, t = mgr.acquire()
    weakref.finalize(t, freed.append, e)
    del t
    mgr.publish(_Snap())
    gc.collect()
    assert freed == []          # superseded but pinned: must stay resident
    mgr.release(e)
    gc.collect()
    assert freed == [e]         # freed on release — and only once
    with pytest.raises(KeyError):
        mgr.release(e)          # retired epochs cannot be double-released


def test_double_release_rejected():
    mgr = EpochManager(_Snap())
    e, _ = mgr.acquire()
    mgr.release(e)
    with pytest.raises((KeyError, ValueError)):
        mgr.release(e)


def test_pin_hammer_many_readers_one_writer():
    """4 readers pin/verify/release in a tight loop while the writer
    publishes 300 epochs; no pinned version is ever retired early, and
    the steady state is clean (refs 0, only the latest resident)."""
    mgr = EpochManager(np.full(4, 0))
    stop = threading.Event()
    errors = []

    def reader():
        try:
            while not stop.is_set():
                with mgr.reading(with_epoch=True) as (e, t):
                    a = np.asarray(t).copy()
                    assert mgr.refs(e) >= 1
                    assert e in mgr.resident
                    np.testing.assert_array_equal(np.asarray(t), a)
        except Exception as exc:  # noqa: BLE001 — surface to main thread
            errors.append(exc)

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for th in threads:
        th.start()
    for i in range(1, 301):
        mgr.publish(np.full(4, i))
    stop.set()
    for th in threads:
        th.join(timeout=30)
    assert not errors, errors[0]
    assert mgr.refs(mgr.epoch) == 0
    assert mgr.resident == [mgr.epoch]
    assert mgr.epoch == 300


def test_frontend_cohort_never_observes_swap():
    """End-to-end pin check through the front-end: a cohort that pins
    epoch 0 and then stalls mid-descent while the writer publishes epoch 1
    must answer from epoch 0 — the freshly inserted exact-duplicate point
    (distance 0) is invisible to it, and visible to the next cohort."""
    from repro.core.smtree import bulk_build
    from repro.serve.frontend import (FrontendConfig, ServeFrontend,
                                      pinned_knn)
    from repro.stream import StreamingEngine

    n, dim = 256, 5
    X = np.random.default_rng(11).random((n, dim)).astype(np.float32)
    eng = StreamingEngine(bulk_build(X, capacity=8))
    pinned_evt, gate = threading.Event(), threading.Event()

    def stalling_knn(pinned, q):
        pinned_evt.set()            # cohort has its pin
        assert gate.wait(30)        # ...while the writer publishes
        return pinned_knn(pinned, q, k=1, max_frontier=256)

    newpt = np.full((1, dim), 0.5, np.float32)
    fe = ServeFrontend(eng, FrontendConfig(cohort_width=1, slo_ms=1.0, k=1),
                       knn_fn=stalling_knn).start()
    try:
        tk = fe.submit(newpt[0])
        assert pinned_evt.wait(30)
        eng.insert_batch(newpt, np.array([n], np.int32))  # publish epoch 1
        gate.set()
        d, ids = tk.result(30)
        assert tk.epoch == 0
        assert ids[0] != n, "cohort observed a tree swap mid-descent"
        tk2 = fe.submit(newpt[0])
        d2, ids2 = tk2.result(30)
        assert tk2.epoch == 1
        assert ids2[0] == n and d2[0] <= 1e-6
    finally:
        fe.stop()
