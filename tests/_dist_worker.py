"""Multi-device scenarios run in a subprocess with 8 host CPU devices.
Invoked by tests/test_distributed.py: python _dist_worker.py <scenario>.
Prints 'PASS <scenario>' on success."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import use_mesh as _use_mesh  # noqa: E402


def scenario_forest_knn():
    from repro.core.distributed import build_forest, brute_force_knn, forest_knn
    from repro.core.metric import pairwise
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    X = np.random.default_rng(0).random((4000, 8)).astype(np.float32)
    Q = np.random.default_rng(1).random((16, 8)).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    with _use_mesh(mesh):
        d, ids = forest_knn(forest, mesh, jnp.asarray(Q), k=5,
                            max_frontier=256)
    D = pairwise("d_inf", Q, X)
    want = np.sort(D, axis=1)[:, :5]
    np.testing.assert_allclose(np.asarray(d), want, atol=1e-5)
    # ids must point at actual matching-distance objects
    got_d = np.take_along_axis(D, np.asarray(ids), axis=1)
    np.testing.assert_allclose(got_d, want, atol=1e-5)


def scenario_forest_brute_matches_tree():
    from repro.core.distributed import build_forest, brute_force_knn, forest_knn
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    X = np.random.default_rng(3).random((2048, 16)).astype(np.float32)
    Q = np.random.default_rng(4).random((8, 16)).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    with _use_mesh(mesh):
        d1, _ = forest_knn(forest, mesh, jnp.asarray(Q), k=3, max_frontier=256)
        Xs = jax.device_put(jnp.asarray(X), jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("model")))
        d2, _ = brute_force_knn(Xs, mesh, jnp.asarray(Q), k=3)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


def scenario_forest_delete():
    from repro.core.distributed import build_forest, forest_delete, forest_knn
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    X = np.random.default_rng(5).random((4096, 8)).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    victims = np.arange(0, 256)
    with _use_mesh(mesh):
        forest, found = forest_delete(
            forest, mesh, jnp.asarray(X[victims]),
            jnp.asarray(victims, jnp.int32))
        d, ids = forest_knn(forest, mesh, jnp.asarray(X[victims][:16]), k=1,
                            max_frontier=256)
    assert np.asarray(found).mean() > 0.9, "most deletes should hit fast path"
    # deleted points must no longer be their own nearest neighbour at d=0
    ids = np.asarray(ids)[:, 0]
    found_np = np.asarray(found)[:16]
    for i in range(16):
        if found_np[i]:
            assert ids[i] != victims[i], f"victim {victims[i]} still present"


def scenario_forest_stream():
    """Batched mutation hook under shard_map: owner-routed insert/delete
    batches through the fused apply_mutations scan, then exact kNN via the
    static-height cohort fast path."""
    from repro.core.distributed import (build_forest, common_static_height,
                                        forest_apply_mutations, forest_knn)
    from repro.core.metric import pairwise
    from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    rng = np.random.default_rng(9)
    X = rng.random((4096, 8)).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    assert common_static_height(forest) is not None, \
        "balanced round-robin build should give equal shard heights"
    # mixed batch: delete 128 existing (owner = oid % 8), insert 64 new
    victims = np.arange(0, 896, 7)           # 128 ids covering all 8 shards
    new_ids = 4096 + np.arange(64)
    ops = np.concatenate([np.full(128, OP_DELETE), np.full(64, OP_INSERT)])
    oids = np.concatenate([victims, new_ids]).astype(np.int32)
    xs = np.concatenate([X[victims],
                         rng.random((64, 8)).astype(np.float32)])
    owner = oids % 8
    with _use_mesh(mesh):
        forest, status = forest_apply_mutations(
            forest, mesh, jnp.asarray(ops, jnp.int32), jnp.asarray(xs),
            jnp.asarray(oids), jnp.asarray(owner, jnp.int32))
        status = np.asarray(status)
        assert (status == ST_APPLIED).mean() > 0.9, np.bincount(status)
        d, ids = forest_knn(forest, mesh, jnp.asarray(xs[-64:]), k=1,
                            max_frontier=256)
    # the fresh inserts that applied must be findable at distance 0
    ok = status[128:] == ST_APPLIED
    d = np.asarray(d)[:, 0]
    ids0 = np.asarray(ids)[:, 0]
    assert ok.any()
    np.testing.assert_allclose(d[ok], 0.0, atol=1e-6)
    assert (ids0[ok] == new_ids[ok]).all()


def scenario_forest_device_splits():
    """Mesh-resident mutation control plane on 8 shards: near-capacity
    bulk builds force leaf splits, the StreamingForest mesh path resolves
    them through the forest_apply_splits collective, and every shard stays
    bitwise-equal to the host-centric batcher path."""
    from repro.core.distributed import build_forest_trees
    from repro.core.engine import SMTreeEngine
    from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED
    from repro.stream import StreamingForest
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(17)
    X = rng.random((2048, 6)).astype(np.float32)

    def build():
        return [t for t in build_forest_trees(X, 8, capacity=8)]

    sf_mesh = StreamingForest(build(), mesh=mesh)
    sf_host = StreamingForest(build())
    live = set(range(2048))
    vec = {i: X[i] for i in range(2048)}
    nid = 10_000
    n_split = 0
    with _use_mesh(mesh):
        for step in range(5):
            ops, xs, oids = [], [], []
            for _ in range(128):
                if live and rng.random() < 0.2:
                    v = int(sorted(live)[rng.integers(len(live))])
                    live.discard(v)
                    ops.append(OP_DELETE)
                    oids.append(v)
                    xs.append(vec[v])
                else:
                    ops.append(OP_INSERT)
                    oids.append(nid)
                    v = rng.random(6).astype(np.float32)
                    xs.append(v)
                    vec[nid] = v
                    live.add(nid)
                    nid += 1
            ops = np.array(ops, np.int32)
            xs = np.stack(xs).astype(np.float32)
            oids = np.array(oids, np.int32)
            rm = sf_mesh.apply(ops, xs, oids)
            rh = sf_host.apply(ops, xs, oids)
            assert (rm.statuses == rh.statuses).all(), step
            assert (rm.statuses == ST_APPLIED).all(), np.bincount(rm.statuses)
            n_split += rm.n_split
            assert rm.n_split == rh.n_split, (rm.n_split, rh.n_split)
            for s, (a, b) in enumerate(zip(sf_mesh.trees, sf_host.trees)):
                for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    np.testing.assert_array_equal(
                        np.asarray(la), np.asarray(lb),
                        err_msg=f"shard {s} diverged at step {step}")
    assert n_split > 0, "workload never exercised a device split"
    assert sf_mesh.owner == sf_host.owner
    for t in sf_mesh.trees:
        SMTreeEngine(t).validate()


def scenario_forest_device_merges():
    """Delete-heavy mesh drill on 8 shards: underflow merges resolve
    through the forest_apply_merges collective (zero host escalations),
    every shard stays bitwise-equal to the host-centric batcher path, and
    the packed free ring keeps matching the wholesale recompute after
    device pushes."""
    from repro.core.distributed import build_forest_trees
    from repro.core.engine import SMTreeEngine
    from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED
    from repro.core.smtree import packed_free_list
    from repro.stream import StreamingForest
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(23)
    X = rng.random((2048, 6)).astype(np.float32)

    def build():
        return [t for t in build_forest_trees(X, 8, capacity=8)]

    sf_mesh = StreamingForest(build(), mesh=mesh)
    sf_host = StreamingForest(build())
    live = set(range(2048))
    vec = {i: X[i] for i in range(2048)}
    nid = 10_000
    n_merge = 0
    with _use_mesh(mesh):
        for step in range(5):
            ops, xs, oids = [], [], []
            for _ in range(128):
                if live and rng.random() < 0.75:
                    v = int(sorted(live)[rng.integers(len(live))])
                    live.discard(v)
                    ops.append(OP_DELETE)
                    oids.append(v)
                    xs.append(vec[v])
                else:
                    ops.append(OP_INSERT)
                    oids.append(nid)
                    v = rng.random(6).astype(np.float32)
                    xs.append(v)
                    vec[nid] = v
                    live.add(nid)
                    nid += 1
            ops = np.array(ops, np.int32)
            xs = np.stack(xs).astype(np.float32)
            oids = np.array(oids, np.int32)
            rm = sf_mesh.apply(ops, xs, oids)
            rh = sf_host.apply(ops, xs, oids)
            assert (rm.statuses == rh.statuses).all(), step
            assert (rm.statuses == ST_APPLIED).all(), np.bincount(rm.statuses)
            assert rm.n_escalated == 0, \
                f"device merges must absorb all underflows, step {step}"
            assert rm.n_merge == rh.n_merge, (rm.n_merge, rh.n_merge)
            n_merge += rm.n_merge
            for s, (a, b) in enumerate(zip(sf_mesh.trees, sf_host.trees)):
                for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    np.testing.assert_array_equal(
                        np.asarray(la), np.asarray(lb),
                        err_msg=f"shard {s} diverged at step {step}")
    assert n_merge > 0, "workload never exercised a device merge"
    for t in sf_mesh.trees:
        fl, fh = packed_free_list(np.asarray(t.alive))
        np.testing.assert_array_equal(np.asarray(t.free_list), fl)
        assert int(t.free_head) == int(fh)
        SMTreeEngine(t).validate()


def scenario_forest_knn_cohort_parity():
    """forest_knn static-height cohort path == per-query fallback."""
    from repro.core.distributed import build_forest, forest_knn
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    X = np.random.default_rng(12).random((2048, 8)).astype(np.float32)
    Q = np.random.default_rng(13).random((16, 8)).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    with _use_mesh(mesh):
        d1, i1 = forest_knn(forest, mesh, jnp.asarray(Q), k=4,
                            max_frontier=256)
        os.environ["REPRO_FRONTIER_IMPL"] = "perquery"
        try:
            d2, i2 = forest_knn(forest, mesh, jnp.asarray(Q), k=4,
                                max_frontier=256)
        finally:
            del os.environ["REPRO_FRONTIER_IMPL"]
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


def scenario_forest_parent_prune_parity():
    """8-shard mesh forest: kNN with the parent-distance pre-filter on is
    bitwise identical to the unpruned collective — both via the explicit
    kwarg and via the REPRO_PARENT_PRUNE env toggle."""
    from repro.core.distributed import build_forest, forest_knn
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    X = np.random.default_rng(41).random((4096, 8)).astype(np.float32)
    # near-data queries (the regime where the filter actually fires)
    Q = (X[:32] + np.random.default_rng(42)
         .normal(0, 0.01, (32, 8))).astype(np.float32)
    forest, _ = build_forest(X, mesh, capacity=16)
    with _use_mesh(mesh):
        d_on, i_on = forest_knn(forest, mesh, jnp.asarray(Q), k=5,
                                max_frontier=64, parent_prune=True)
        d_off, i_off = forest_knn(forest, mesh, jnp.asarray(Q), k=5,
                                  max_frontier=64, parent_prune=False)
        os.environ["REPRO_PARENT_PRUNE"] = "0"
        try:
            d_env, i_env = forest_knn(forest, mesh, jnp.asarray(Q), k=5,
                                      max_frontier=64)
        finally:
            del os.environ["REPRO_PARENT_PRUNE"]
    np.testing.assert_array_equal(np.asarray(d_on), np.asarray(d_off))
    np.testing.assert_array_equal(np.asarray(i_on), np.asarray(i_off))
    np.testing.assert_array_equal(np.asarray(d_env), np.asarray(d_off))
    np.testing.assert_array_equal(np.asarray(i_env), np.asarray(i_off))


def scenario_replica_forest_mesh():
    """WAL-shipping follower of a StreamingForest: tails the leader's
    segments on host, verifies bitwise equality by digest exchange, then
    places its shards on the mesh (place_forest) and serves exact kNN
    through the same forest_knn collectives as the leader."""
    import tempfile
    from repro.core.distributed import (build_forest_trees, forest_knn,
                                        place_forest)
    from repro.core.metric import pairwise
    from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED
    from repro.stream import (Replica, StreamingForest, WriteAheadLog,
                              ledger_digest)
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(29)
    X = rng.random((2048, 8)).astype(np.float32)
    live = set(range(2048))
    vec = {i: X[i] for i in range(2048)}
    nid = 10_000
    with tempfile.TemporaryDirectory() as d:
        wal_dir = os.path.join(d, "wal")
        leader = StreamingForest(build_forest_trees(X, 8, capacity=8),
                                 wal=WriteAheadLog(wal_dir,
                                                   segment_max_records=2))
        rep = Replica(StreamingForest(build_forest_trees(X, 8, capacity=8)),
                      wal_dir)
        for _ in range(3):
            ops, xs, oids = [], [], []
            for _ in range(128):
                if live and rng.random() < 0.4:
                    v = int(sorted(live)[rng.integers(len(live))])
                    live.discard(v)
                    ops.append(OP_DELETE)
                    oids.append(v)
                    xs.append(vec[v])
                else:
                    x = rng.random(8).astype(np.float32)
                    vec[nid] = x
                    live.add(nid)
                    ops.append(OP_INSERT)
                    oids.append(nid)
                    xs.append(x)
                    nid += 1
            res = leader.apply(np.array(ops, np.int32),
                               np.stack(xs).astype(np.float32),
                               np.array(oids, np.int32))
            assert (res.statuses == ST_APPLIED).all()
        seq, dg = ledger_digest(leader)
        rep.verify(seq, dg)                # bitwise, or DigestMismatch
        # read fan-out: the follower's published epoch goes mesh-resident
        with rep.epochs.reading() as shards:
            forest = place_forest(list(shards), mesh)
            Q = np.stack([vec[o] for o in sorted(live)[:16]]) + 0.003
            with _use_mesh(mesh):
                d_got, ids = forest_knn(forest, mesh,
                                        jnp.asarray(Q, jnp.float32), k=3,
                                        max_frontier=256)
        keys = np.stack([vec[o] for o in sorted(live)])
        want = np.sort(pairwise(shards[0].metric, Q, keys), axis=1)[:, :3]
        np.testing.assert_allclose(np.asarray(d_got), want, atol=1e-5)


def scenario_promote_follower_mesh():
    """Full failover into the mesh: a socket-shipped forest follower
    drains a dead leader's tail, is promoted under a new fencing token
    (stream.lease), and its verified epoch goes mesh-resident via
    core.distributed.promote_follower — then serves exact kNN through
    the same collectives, and accepts fenced appends as the new leader."""
    import tempfile
    from repro.core.distributed import (build_forest_trees, forest_knn,
                                        promote_follower)
    from repro.core.metric import pairwise
    from repro.core.smtree import ST_APPLIED
    from repro.stream import (StreamingForest, WriteAheadLog, ledger_digest)
    from repro.stream.lease import FenceGuard, LeaseStore, promote
    from repro.stream.transport import ShippedReplica, WalShipServer

    class _Clock:
        t = 0.0

        def __call__(self):
            return self.t

    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(31)
    X = rng.random((2048, 8)).astype(np.float32)
    vec = {i: X[i] for i in range(2048)}
    with tempfile.TemporaryDirectory() as d:
        clock = _Clock()
        store = LeaseStore(os.path.join(d, "lease"), ttl_s=5.0, clock=clock)
        grant = store.try_acquire("leader")
        wal_dir = os.path.join(d, "wal")
        wal = WriteAheadLog(wal_dir, segment_max_records=2,
                            fence=FenceGuard(store, "leader", grant.token))
        leader = StreamingForest(build_forest_trees(X, 8, capacity=8),
                                 wal=wal)
        srv = WalShipServer(wal_dir, wal=wal).start()
        rep = ShippedReplica(
            StreamingForest(build_forest_trees(X, 8, capacity=8)),
            srv.address, os.path.join(d, "mirror"))
        nid = 10_000
        for i in range(3):
            xs = rng.random((64, 8)).astype(np.float32)
            oids = np.arange(nid, nid + 64, dtype=np.int32)
            for o, x in zip(oids, xs):
                vec[int(o)] = x
            nid += 64
            res = leader.insert_batch(xs, oids)
            assert (res.statuses == ST_APPLIED).all()
        seq, dg = ledger_digest(leader)
        wal.close()                      # leader dies; disk + server live
        clock.t = 6.0
        promo = promote(rep, store, "follower-1", target=(seq, dg))
        assert promo.lease.token > grant.token
        forest, epoch = promote_follower(rep, mesh, expect=(seq, dg))
        live = sorted(vec)
        Q = np.stack([vec[o] for o in live[:16]]) + 0.003
        with _use_mesh(mesh):
            d_got, ids = forest_knn(forest, mesh,
                                    jnp.asarray(Q, jnp.float32), k=3,
                                    max_frontier=256)
        keys = np.stack([vec[o] for o in live])
        with rep.epochs.reading() as shards:
            metric = shards[0].metric
        want = np.sort(pairwise(metric, Q, keys), axis=1)[:, :3]
        np.testing.assert_allclose(np.asarray(d_got), want, atol=1e-5)
        # the promoted follower leads: appends land under the new fence
        rep.follower.insert_batch(rng.random((4, 8)).astype(np.float32),
                                  np.arange(90_000, 90_004, dtype=np.int32))
        assert promo.wal.next_seq == seq + 2
        rep.stop()
        srv.stop()


def scenario_train_step_sharded():
    """2x4 mesh end-to-end: sharded train step runs and loss decreases."""
    import dataclasses
    from repro.configs.all_archs import smoke_config
    from repro.data.pipeline import DataConfig, synth_batch
    from repro.models import model as M
    from repro.train.train_step import TrainSettings, make_train_step, init_all
    from repro.train.optimizer import AdamWConfig

    cfg = dataclasses.replace(smoke_config("qwen2.5-3b"), n_layers=2,
                              block_pattern=("attn",))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    batch0 = synth_batch(dc, 0)
    inputs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch0.items()}
    settings = TrainSettings(opt=AdamWConfig(lr=1e-2, warmup_steps=2,
                                             total_steps=50))
    with _use_mesh(mesh):
        step_fn, sh = make_train_step(cfg, mesh, inputs, settings)
        params, opt = init_all(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, sh["params"])
        opt = jax.device_put(opt, sh["opt"])
        jitted = jax.jit(step_fn,
                         in_shardings=(sh["params"], sh["opt"], sh["batch"]),
                         out_shardings=(sh["params"], sh["opt"], sh["metrics"]),
                         donate_argnums=(0, 1))
        losses = []
        for step in range(8):
            batch = jax.device_put(synth_batch(dc, step), sh["batch"])
            params, opt, metrics = jitted(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0], f"no learning: {losses}"


def scenario_elastic_reshard():
    """Checkpoint written under a 2x4 mesh restores onto 1x8 and 4x2."""
    import dataclasses, tempfile
    from repro.configs.all_archs import smoke_config
    from repro.dist.checkpoint import restore_checkpoint, save_checkpoint
    from repro.dist import sharding as shd
    from repro.models import model as M

    cfg = smoke_config("qwen2.5-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    spec_a = shd.param_pspecs(cfg, params, mesh_a)
    pa = jax.device_put(params, shd.to_named(spec_a, mesh_a))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": pa})
        for shape in [(1, 8), (4, 2)]:
            mesh_b = jax.make_mesh(shape, ("data", "model"))
            spec_b = shd.param_pspecs(cfg, params, mesh_b)
            out, manifest = restore_checkpoint(
                d, {"params": params},
                shardings={"params": shd.to_named(spec_b, mesh_b)})
            assert manifest["step"] == 3
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(out["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def scenario_compressed_psum():
    """int8 compressed gradient all-reduce: mean within quantisation error,
    error feedback captures the residual."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.compression import compressed_psum_mean
    from repro.dist.sharding import shard_map
    import functools
    mesh = jax.make_mesh((8,), ("data",))
    g = np.random.default_rng(11).normal(size=(8, 4096)).astype(np.float32)

    @functools.partial(shard_map, mesh=mesh, in_specs=P("data"),
                       out_specs=(P("data"), P("data")), check_rep=False)
    def run(gs):
        mean, err = compressed_psum_mean({"g": gs}, "data")
        return mean["g"], err["g"]

    with _use_mesh(mesh):
        mean, err = run(jnp.asarray(g))
    true_mean = g.mean(0, keepdims=True)
    got = np.asarray(mean)[0:1]
    scale = np.abs(g).max() / 127
    assert np.abs(got - true_mean).max() < 4 * scale, \
        (np.abs(got - true_mean).max(), scale)
    # error feedback residual is bounded by one quantisation step
    assert np.abs(np.asarray(err)).max() <= scale * 1.01




def scenario_moe_ep_equivalence():
    """shard_map expert-parallel MoE == single-device dense-dispatch MoE
    (same routing, dropless capacity)."""
    import dataclasses
    from repro.configs.all_archs import smoke_config
    from repro.models import moe as moe_mod
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(smoke_config("grok-1-314b"),
                              n_experts=8, experts_per_token=2,
                              expert_pad_to=0, capacity_factor=64.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    y_ref, aux_ref = moe_mod.moe_apply(p, cfg, x)           # dense dispatch
    cfg_ep = dataclasses.replace(cfg, moe_ep=True)
    with _use_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_mod.moe_apply(p, cfg_ep, x))(p, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def scenario_forest_migration_mesh():
    """Incremental migration on an 8-shard mesh forest: a skewed delete
    drill trips the planner, bounded migration steps run through the
    mesh extract + cohort-apply collectives with the stacked forest
    staying device-resident throughout, and every shard stays bitwise
    equal to the host-path forest after each step."""
    from repro.core.distributed import build_forest_trees
    from repro.core.engine import SMTreeEngine
    from repro.stream import StreamingForest, collect_stats
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(23)
    X = rng.random((4096, 6)).astype(np.float32)

    def build():
        return StreamingForest(
            [t for t in build_forest_trees(X, 8, capacity=8)],
            mesh=mesh if build.on_mesh else None,
            max_skew=1.3, min_objects=64, rebalance_mode="incremental",
            migration_step_objects=48)

    build.on_mesh = True
    sf_mesh = build()
    build.on_mesh = False
    sf_host = build()
    victims = np.asarray([o for o in range(4096) if o % 8 < 3], np.int32)
    with _use_mesh(mesh):
        for c in range(0, len(victims), 512):
            chunk = victims[c:c + 512]
            sf_mesh.delete_batch(X[chunk], chunk)
            sf_host.delete_batch(X[chunk], chunk)
        assert collect_stats(sf_mesh.trees).skew >= 2.0
        steps = 0
        while sf_mesh.maintenance():
            assert sf_host.maintenance()
            steps += 1
            # mesh steps must not bounce the forest off the devices
            assert sf_mesh._stacked is not None, \
                f"stacked forest left the mesh at step {steps}"
            for s, (a, b) in enumerate(zip(sf_mesh.trees, sf_host.trees)):
                for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    np.testing.assert_array_equal(
                        np.asarray(la), np.asarray(lb),
                        err_msg=f"shard {s} diverged at step {steps}")
        assert not sf_host.maintenance()
    assert steps >= 2, "drill completed without incremental steps"
    assert sf_mesh.owner == sf_host.owner
    assert sf_mesh.objects_migrated == sf_host.objects_migrated > 0
    assert collect_stats(sf_mesh.trees).skew <= 1.3
    for t in sf_mesh.trees:
        SMTreeEngine(t).validate()


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print(f"PASS {name}")
