"""The PR-3 acceptance drill, end to end:

build a sharded SM-forest → serve exact kNN from pinned epochs while a
heavily skewed 90%-delete stream flows through the WAL-backed batcher →
the background rebalancer fires on the induced shard skew → a restore
from the mid-stream snapshot + WAL tail replay reproduces the final
forest **bitwise**.
"""
import jax
import numpy as np

from repro.core.engine import SMTreeEngine
from repro.core.metric import pairwise
from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED
from repro.core.distributed import build_forest_trees
from repro.data.datagen import clustered, uniform
from repro.dist.checkpoint import CheckpointManager
from repro.stream import StreamingForest, WriteAheadLog, collect_stats
from repro.stream.rebalance import live_objects


N = 1600
DIM = 8
SHARDS = 4
CAPACITY = 8


def _forest_live_set(trees):
    vecs, ids = [], []
    for t in trees:
        v, o = live_objects(t)
        vecs.append(v)
        ids.append(o)
    return np.concatenate(vecs), np.concatenate(ids)


def test_streaming_forest_drill(tmp_path):
    X = clustered(N, dims=DIM, seed=21)
    fresh = uniform(600, dims=DIM, seed=22)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=4)
    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)
    sf = StreamingForest(build_forest_trees(X, SHARDS, capacity=CAPACITY),
                         wal=wal, ckpt=ck, min_objects=128, max_skew=1.4)
    assert sf.n_objects == N

    rng = np.random.default_rng(23)
    vec_of = {i: X[i] for i in range(N)}
    live = set(range(N))
    nid = N
    n_fresh = 0
    rebalances = 0
    served_epochs = set()

    for step in range(12):
        # ---- reader: pin the current epoch and serve exact kNN from it
        epoch, pinned = sf.epochs.acquire()
        served_epochs.add(epoch)
        pinned_vecs, pinned_ids = _forest_live_set(pinned)
        Q = pinned_vecs[rng.integers(0, len(pinned_ids), 8)] + 0.005
        d_got, _ = sf.knn(Q, k=3, max_frontier=512)

        # ---- writer: 90%-delete batch, victims biased onto shards 0/1
        n_ops = 96
        ops, xs, oids = [], [], []
        for _ in range(n_ops):
            skewed = sorted(o for o in live if o % SHARDS < 2)
            if live and rng.random() < 0.9:
                pool = skewed if (skewed and rng.random() < 0.9) \
                    else sorted(live)
                victim = int(pool[rng.integers(len(pool))])
                live.discard(victim)
                ops.append(OP_DELETE)
                oids.append(victim)
                xs.append(vec_of[victim])
            else:
                v = fresh[n_fresh % len(fresh)]
                n_fresh += 1
                ops.append(OP_INSERT)
                oids.append(nid)
                xs.append(v)
                vec_of[nid] = v
                live.add(nid)
                nid += 1
        res = sf.apply(np.array(ops, np.int32),
                       np.stack(xs).astype(np.float32),
                       np.array(oids, np.int32))
        assert (res.statuses == ST_APPLIED).all()

        # the pinned epoch was untouched by the writer: results still match
        # brute force over the *pinned* live set
        want = np.sort(pairwise("d_inf", Q, pinned_vecs), axis=1)[:, :3]
        np.testing.assert_allclose(d_got, want, atol=1e-5)
        sf.epochs.release(epoch)

        if sf.maintenance():
            rebalances += 1
        if step == 5:
            sf.snapshot()

    # ---- the skewed stream must actually have fired the rebalancer
    assert rebalances >= 1, "skewed delete stream never triggered rebalance"
    assert collect_stats(sf.trees).skew < 1.4
    assert len(served_epochs) >= 12
    assert sf.n_objects == len(live)
    for t in sf.trees:
        SMTreeEngine(t).validate()

    # ---- live set is exactly right after the whole stream
    vecs_now, ids_now = _forest_live_set(sf.trees)
    assert sorted(ids_now.tolist()) == sorted(live)

    # ---- restore = snapshot + WAL tail replay, bitwise
    restored = StreamingForest.restore(str(tmp_path / "ck"), wal=wal,
                                       min_objects=128, max_skew=1.4)
    final = sf.stacked()
    for a, b in zip(jax.tree.leaves(final),
                    jax.tree.leaves(restored.stacked())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored.owner == sf.owner
    assert restored.n_rebalances == sf.n_rebalances


def test_streaming_forest_routing_follows_rebalance(tmp_path):
    """After a rebalance migrates objects, deletes must still find them
    (ownership map routing, not the static hash)."""
    X = clustered(800, dims=6, seed=31)
    sf = StreamingForest(build_forest_trees(X, 4, capacity=8),
                         min_objects=64, max_skew=1.3)
    victims = np.array([o for o in range(800) if o % 4 == 0][:150])
    r = sf.delete_batch(X[victims], victims)
    assert (r.statuses == ST_APPLIED).all()
    assert sf.maintenance(), "skew should trigger"
    # delete objects that were migrated off their hash shard
    migrated = [o for o, s in sf.owner.items() if s != o % 4]
    assert migrated, "rebalance should have moved objects across shards"
    pick = np.array(sorted(migrated)[:32], np.int32)
    vec_lookup = {int(o): v for t in sf.trees
                  for v, o in zip(*live_objects(t))}
    xs = np.stack([vec_lookup[int(o)] for o in pick])
    r = sf.delete_batch(xs, pick)
    assert (r.statuses == ST_APPLIED).all()
    assert sf.n_objects == 800 - 150 - 32
