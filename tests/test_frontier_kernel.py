"""Interpret-mode parity for the fused frontier-scoring Pallas kernel.

The kernel (kernels/frontier.py) must be *bitwise* identical to the XLA
gather path on every output — the cohort descent's xla-vs-pallas parity
guarantee reduces to this plus determinism of top_k.  Runs the real kernel
code through the Pallas interpreter on CPU.

The parent-distance pre-filter variant (DESIGN.md §17) additionally must:
drop exactly the entries with |qpd − pdist| > rq + r (+ the documented
pad), keep the *boundary* case |qpd − pdist| == rq + r (never prune on
equality — mirrors the descent's _EPS-padded prune test), and leave every
kept entry's outputs bitwise equal to the unfiltered kernel's.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.frontier import (_PRUNE_PAD, frontier_scores,
                                    frontier_scores_pallas,
                                    frontier_scores_xla)

METRICS = ["d_inf", "l2", "l1"]
OUT_NAMES = ("dmax", "score", "leaf_d", "dq")


def _random_tree_pages(rng, N=40, cap=16, dim=10):
    vecs = rng.normal(size=(N, cap, dim)).astype(np.float32)
    radius = np.abs(rng.normal(size=(N, cap))).astype(np.float32)
    valid = rng.random((N, cap)) < 0.8
    is_leaf = rng.random(N) < 0.5
    internal_valid = valid & ~is_leaf[:, None]
    leaf_valid = valid & is_leaf[:, None]
    return (jnp.asarray(vecs), jnp.asarray(radius),
            jnp.asarray(internal_valid), jnp.asarray(leaf_valid))


def _random_frontier(rng, N, b, w):
    # frontier includes empty (-1) slots, duplicates, and boundary ids
    fids = rng.integers(-1, N, size=(b, w)).astype(np.int32)
    fids[0, :] = -1                      # fully-done query
    fids[1, :] = 0                       # duplicated node
    fids[2, 0] = N - 1                   # last row
    return jnp.asarray(fids)


def _random_prune_inputs(rng, fids, N, cap):
    b, w = fids.shape
    pdist = np.abs(rng.normal(size=(N, cap))).astype(np.float32)
    qpd = np.abs(rng.normal(size=(b, w))).astype(np.float32)
    qpd[np.asarray(fids) < 0] = np.inf   # empty slots carry +inf
    rq = np.abs(rng.normal(size=(b,))).astype(np.float32)
    return jnp.asarray(pdist), jnp.asarray(qpd), jnp.asarray(rq)


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_matches_xla_bitwise(metric):
    rng = np.random.default_rng(0)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 8, 5
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    fids = _random_frontier(rng, vecs.shape[0], b, w)

    got = frontier_scores_pallas(fids, queries, vecs, radius, iv, lv,
                                 metric=metric, interpret=True)
    want = frontier_scores_xla(fids, queries, vecs, radius, iv, lv,
                               metric=metric)
    assert len(got) == len(want) == 4
    for g, wv, name in zip(got, want, OUT_NAMES):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv),
                                      err_msg=f"{metric}/{name}")


@pytest.mark.parametrize("metric", METRICS)
def test_pruned_kernel_matches_xla_bitwise(metric):
    """With the parent filter engaged, pallas and xla must still agree on
    every output bit — same keep mask, same distances."""
    rng = np.random.default_rng(3)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 8, 5
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    fids = _random_frontier(rng, vecs.shape[0], b, w)
    pdist, qpd, rq = _random_prune_inputs(rng, fids, vecs.shape[0],
                                          vecs.shape[1])

    got = frontier_scores_pallas(fids, queries, vecs, radius, iv, lv,
                                 metric=metric, interpret=True,
                                 pdist=pdist, qpd=qpd, rq=rq)
    want = frontier_scores_xla(fids, queries, vecs, radius, iv, lv,
                               metric=metric, pdist=pdist, qpd=qpd, rq=rq)
    for g, wv, name in zip(got, want, OUT_NAMES):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv),
                                      err_msg=f"{metric}/{name}")


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("metric", METRICS)
def test_pruned_outputs_subset_of_unpruned(metric, impl):
    """The filter only ever *removes* evaluations: kept entries' outputs are
    bitwise those of the unfiltered kernel; dropped entries are exactly the
    |qpd − pdist| > rq + r + pad set and emit +inf."""
    rng = np.random.default_rng(4)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 8, 5
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    fids = _random_frontier(rng, vecs.shape[0], b, w)
    pdist, qpd, rq = _random_prune_inputs(rng, fids, vecs.shape[0],
                                          vecs.shape[1])

    plain = frontier_scores(fids, queries, vecs, radius, iv, lv,
                            metric=metric, impl=impl, interpret=True)
    pruned = frontier_scores(fids, queries, vecs, radius, iv, lv,
                             metric=metric, impl=impl, interpret=True,
                             pdist=pdist, qpd=qpd, rq=rq)
    nodes = np.maximum(np.asarray(fids), 0)
    lb = np.abs(np.asarray(qpd)[:, :, None] - np.asarray(pdist)[nodes])
    keep = lb <= (np.asarray(rq)[:, None, None] + np.asarray(radius)[nodes]
                  + np.float32(_PRUNE_PAD))
    for g_plain, g_pruned, name in zip(plain, pruned, OUT_NAMES):
        a, p = np.asarray(g_plain), np.asarray(g_pruned)
        np.testing.assert_array_equal(p[keep], a[keep],
                                      err_msg=f"{metric}/{impl}/{name}/kept")
        assert np.isposinf(p[~keep]).all(), f"{metric}/{impl}/{name}/dropped"


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_prune_boundary_is_inclusive(impl):
    """|qpd − pdist| == rq + r must NOT prune (consistent with the _EPS
    padding of the descent's prune test: equality always survives), while a
    gap clearly above the pad must."""
    cap, dim = 4, 6
    vecs = jnp.zeros((1, cap, dim), jnp.float32)
    radius = jnp.asarray([[0.0, 0.25, 0.0, 0.0]], jnp.float32)
    iv = jnp.ones((1, cap), bool)
    lv = jnp.zeros((1, cap), bool)
    # exactly representable f32 values: lb = |1.5 − pdist|, rq = 0.5
    #   slot0: lb = 0.5  == rq + r (0.5)   -> keep (boundary)
    #   slot1: lb = 0.75 == rq + r (0.75)  -> keep (boundary, r > 0)
    #   slot2: lb = 0.5 + pad/2            -> keep (inside the pad)
    #   slot3: lb = 0.625 > rq + r + pad   -> prune
    pdist = jnp.asarray([[1.0, 0.75, 1.0 - _PRUNE_PAD / 2, 0.875]],
                        jnp.float32)
    qpd = jnp.asarray([[1.5]], jnp.float32)
    rq = jnp.asarray([0.5], jnp.float32)
    fids = jnp.zeros((1, 1), jnp.int32)
    queries = jnp.zeros((1, dim), jnp.float32)

    dmax, score, leaf_d, dq = frontier_scores(
        fids, queries, vecs, radius, iv, lv, metric="d_inf", impl=impl,
        interpret=True, pdist=pdist, qpd=qpd, rq=rq)
    finite = np.isfinite(np.asarray(dmax))[0, 0]
    np.testing.assert_array_equal(finite, [True, True, True, False],
                                  err_msg=impl)


@pytest.mark.parametrize("metric", ["d_inf", "l2"])
def test_empty_frontier_emits_inf(metric):
    rng = np.random.default_rng(1)
    vecs, radius, iv, lv = _random_tree_pages(rng, N=8, cap=4, dim=6)
    fids = jnp.full((3, 4), -1, jnp.int32)
    queries = jnp.asarray(rng.normal(size=(3, 6)).astype(np.float32))
    out = frontier_scores_pallas(fids, queries, vecs, radius, iv, lv,
                                 metric=metric, interpret=True)
    for arr in out:
        assert np.isposinf(np.asarray(arr)).all()


def test_masks_partition_outputs():
    """An entry is internal xor leaf xor invalid: dmax/score/dq finite
    exactly where internal-valid, leaf_d finite exactly where leaf-valid."""
    rng = np.random.default_rng(2)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 4, 6
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    fids = jnp.asarray(rng.integers(0, vecs.shape[0], size=(b, w)).astype(np.int32))
    dmax, score, leaf_d, dq = frontier_scores_pallas(
        fids, queries, vecs, radius, iv, lv, metric="d_inf", interpret=True)
    iv_g = np.asarray(iv)[np.asarray(fids)]
    lv_g = np.asarray(lv)[np.asarray(fids)]
    assert (np.isfinite(np.asarray(dmax)) == iv_g).all()
    assert (np.isfinite(np.asarray(score)) == iv_g).all()
    assert (np.isfinite(np.asarray(dq)) == iv_g).all()
    assert (np.isfinite(np.asarray(leaf_d)) == lv_g).all()
    # no entry is both internal and leaf
    assert not (iv_g & lv_g).any()


def test_dq_is_raw_distance():
    """dq must be the *unmodified* metric value for internal entries — the
    carry the next level reuses as d(q, parent) must match what pdist of
    the children was computed against."""
    rng = np.random.default_rng(5)
    vecs, radius, iv, lv = _random_tree_pages(rng, N=10, cap=6, dim=8)
    fids = jnp.asarray(rng.integers(0, 10, size=(3, 4)).astype(np.int32))
    queries = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    dmax, score, leaf_d, dq = frontier_scores_xla(
        fids, queries, vecs, radius, iv, lv, metric="d_inf")
    r_g = np.asarray(radius)[np.maximum(np.asarray(fids), 0)]
    fin = np.isfinite(np.asarray(dq))
    np.testing.assert_array_equal(np.asarray(dmax)[fin],
                                  (np.asarray(dq) + r_g)[fin])


def test_unknown_impl_raises():
    rng = np.random.default_rng(6)
    vecs, radius, iv, lv = _random_tree_pages(rng, N=4, cap=4, dim=4)
    fids = jnp.zeros((1, 1), jnp.int32)
    queries = jnp.zeros((1, 4), jnp.float32)
    with pytest.raises(ValueError, match=r"pallas.*xla"):
        frontier_scores(fids, queries, vecs, radius, iv, lv,
                        metric="d_inf", impl="bogus")


def test_partial_prune_args_raise():
    rng = np.random.default_rng(7)
    vecs, radius, iv, lv = _random_tree_pages(rng, N=4, cap=4, dim=4)
    fids = jnp.zeros((1, 1), jnp.int32)
    queries = jnp.zeros((1, 4), jnp.float32)
    with pytest.raises(ValueError, match="pdist"):
        frontier_scores(fids, queries, vecs, radius, iv, lv,
                        metric="d_inf", impl="xla",
                        qpd=jnp.zeros((1, 1), jnp.float32))
