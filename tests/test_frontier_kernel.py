"""Interpret-mode parity for the fused frontier-scoring Pallas kernel.

The kernel (kernels/frontier.py) must be *bitwise* identical to the XLA
gather path on every output — the cohort descent's xla-vs-pallas parity
guarantee reduces to this plus determinism of top_k.  Runs the real kernel
code through the Pallas interpreter on CPU.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.frontier import frontier_scores_pallas, frontier_scores_xla

METRICS = ["d_inf", "l2", "l1"]


def _random_tree_pages(rng, N=40, cap=16, dim=10):
    vecs = rng.normal(size=(N, cap, dim)).astype(np.float32)
    radius = np.abs(rng.normal(size=(N, cap))).astype(np.float32)
    valid = rng.random((N, cap)) < 0.8
    is_leaf = rng.random(N) < 0.5
    internal_valid = valid & ~is_leaf[:, None]
    leaf_valid = valid & is_leaf[:, None]
    return (jnp.asarray(vecs), jnp.asarray(radius),
            jnp.asarray(internal_valid), jnp.asarray(leaf_valid))


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_matches_xla_bitwise(metric):
    rng = np.random.default_rng(0)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 8, 5
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    # frontier includes empty (-1) slots, duplicates, and boundary ids
    fids = rng.integers(-1, vecs.shape[0], size=(b, w)).astype(np.int32)
    fids[0, :] = -1                      # fully-done query
    fids[1, :] = 0                       # duplicated node
    fids[2, 0] = vecs.shape[0] - 1       # last row
    fids = jnp.asarray(fids)

    got = frontier_scores_pallas(fids, queries, vecs, radius, iv, lv,
                                 metric=metric, interpret=True)
    want = frontier_scores_xla(fids, queries, vecs, radius, iv, lv,
                               metric=metric)
    for g, wv, name in zip(got, want, ("dmax", "score", "leaf_d")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv),
                                      err_msg=f"{metric}/{name}")


@pytest.mark.parametrize("metric", ["d_inf", "l2"])
def test_empty_frontier_emits_inf(metric):
    rng = np.random.default_rng(1)
    vecs, radius, iv, lv = _random_tree_pages(rng, N=8, cap=4, dim=6)
    fids = jnp.full((3, 4), -1, jnp.int32)
    queries = jnp.asarray(rng.normal(size=(3, 6)).astype(np.float32))
    out = frontier_scores_pallas(fids, queries, vecs, radius, iv, lv,
                                 metric=metric, interpret=True)
    for arr in out:
        assert np.isposinf(np.asarray(arr)).all()


def test_masks_partition_outputs():
    """An entry is internal xor leaf xor invalid: dmax/score finite exactly
    where internal-valid, leaf_d finite exactly where leaf-valid."""
    rng = np.random.default_rng(2)
    vecs, radius, iv, lv = _random_tree_pages(rng)
    b, w = 4, 6
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[-1])).astype(np.float32))
    fids = jnp.asarray(rng.integers(0, vecs.shape[0], size=(b, w)).astype(np.int32))
    dmax, score, leaf_d = frontier_scores_pallas(
        fids, queries, vecs, radius, iv, lv, metric="d_inf", interpret=True)
    iv_g = np.asarray(iv)[np.asarray(fids)]
    lv_g = np.asarray(lv)[np.asarray(fids)]
    assert (np.isfinite(np.asarray(dmax)) == iv_g).all()
    assert (np.isfinite(np.asarray(score)) == iv_g).all()
    assert (np.isfinite(np.asarray(leaf_d)) == lv_g).all()
    # no entry is both internal and leaf
    assert not (iv_g & lv_g).any()
