"""Lease store semantics (monotonic fencing tokens, expiry, CAS), the
fence guard rejecting deposed leaders' appends, and promote() failing a
follower over into leadership."""
import numpy as np
import pytest

from repro.core.smtree import OP_INSERT, bulk_build
from repro.stream import (FencedOut, Replica, StreamingEngine,
                          WriteAheadLog, ledger_digest, tree_digest)
from repro.stream.lease import (FenceGuard, LeaseLost, LeaseStore, promote)
from repro.stream.transport import ShippedReplica, WalShipServer

DIM = 6


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _batch(rng, n, start_oid):
    ops = np.full(n, OP_INSERT, np.int8)
    xs = rng.random((n, DIM)).astype(np.float32)
    oids = (start_oid + np.arange(n)).astype(np.int32)
    return ops, xs, oids


# -- lease store -----------------------------------------------------------

def test_lease_acquire_renew_expire_takeover(tmp_path):
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=10.0, clock=clock)
    a = store.try_acquire("a")
    assert a is not None and a.token == 0
    assert store.try_acquire("b") is None        # held, unexpired
    a2 = store.renew("a", a.token)
    assert a2.token == a.token                   # renewal: same term
    clock.t = 11.0                               # lease lapses
    b = store.try_acquire("b")
    assert b is not None and b.token == 1        # takeover: token bumps
    with pytest.raises(LeaseLost):
        store.renew("a", a.token)                # deposed
    store.release("b", b.token)
    assert store.read() is None
    c = store.try_acquire("c")
    assert c.token == 2                          # monotonic across release


def test_lease_reacquire_after_own_expiry_keeps_monotonicity(tmp_path):
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=1.0, clock=clock)
    a = store.try_acquire("a")
    clock.t = 2.0
    # expired-but-untaken: the same holder re-acquiring is a NEW term
    # (its old token may have been beaten by a concurrent claim it never
    # saw), so the token must bump
    a2 = store.try_acquire("a")
    assert a2.token == a.token + 1


# -- fencing ---------------------------------------------------------------

def test_fence_guard_blocks_deposed_leader(tmp_path):
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=5.0, clock=clock)
    rng = np.random.default_rng(0)
    grant = store.try_acquire("leader")
    wal = WriteAheadLog(str(tmp_path / "wal"),
                        fence=FenceGuard(store, "leader", grant.token))
    wal.append_batch(*_batch(rng, 4, 0))         # fine under own lease
    clock.t = 6.0
    takeover = store.try_acquire("usurper")
    assert takeover.token > grant.token
    seq_before = wal.next_seq
    import os
    seg = os.path.join(str(tmp_path / "wal"),
                       sorted(os.listdir(tmp_path / "wal"))[-1])
    size_before = os.path.getsize(seg)
    with pytest.raises(FencedOut):
        wal.append_batch(*_batch(rng, 4, 100))
    # the fenced append touched nothing: no seq burn, no bytes
    assert wal.next_seq == seq_before
    assert os.path.getsize(seg) == size_before


# -- promotion -------------------------------------------------------------

def _run_leader(tmp_path, rng, *, steps=5):
    X = rng.random((300, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    leader = StreamingEngine(tree0, wal=wal)
    for i in range(steps):
        leader.insert_batch(rng.random((12, DIM)).astype(np.float32),
                            np.arange(1000 + 12 * i, 1012 + 12 * i,
                                      dtype=np.int32))
    return leader, wal, tree0


def test_promote_local_replica_takes_over_wal(tmp_path):
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=5.0, clock=clock)
    rng = np.random.default_rng(1)
    old_grant = store.try_acquire("leader")
    leader, wal, tree0 = _run_leader(tmp_path, rng)
    wal.fence = FenceGuard(store, "leader", old_grant.token)
    seq, dg = ledger_digest(leader)
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))

    # leader "dies": lease lapses without a release
    wal.close()
    clock.t = 6.0
    promo = promote(rep, store, "follower-1", target=(seq, dg))
    assert promo.lease.token > old_grant.token
    assert promo.applied_seq == seq and promo.digest == dg
    # the follower is now the leader: log=True appends flow to the mirror
    new_leader = rep.follower
    assert new_leader.wal is promo.wal
    new_leader.insert_batch(rng.random((8, DIM)).astype(np.float32),
                            np.arange(5000, 5008, dtype=np.int32))
    assert promo.wal.next_seq == seq + 2          # seq numbering continues
    # ...and the deposed leader's appends bounce without landing a byte
    with pytest.raises(FencedOut):
        wal.append_batch(*_batch(rng, 4, 9000))


def test_promote_shipped_replica_drains_dead_leaders_tail(tmp_path):
    """The crashed-leader drill: the leader process is gone but its disk
    (ship server) survives; the follower pulls the remaining tail through
    the socket, verifies the digest, and takes over."""
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=5.0, clock=clock)
    rng = np.random.default_rng(2)
    store.try_acquire("leader")
    leader, wal, tree0 = _run_leader(tmp_path, rng)
    seq, dg = ledger_digest(leader)
    with WalShipServer(str(tmp_path / "wal"), leader_seq_fn=lambda: seq) \
            as srv:
        rep = ShippedReplica(StreamingEngine(tree0), srv.address,
                             str(tmp_path / "mirror"))
        rep.poll()                      # partially caught up, then crash:
        wal.close()                     # the WAL handle dies, disk stays
        clock.t = 6.0
        promo = promote(rep, store, "follower-1", target=(seq, dg))
        rep.stop()
    assert promo.applied_seq == seq and promo.digest == dg
    # the mirror is now the authoritative log; state continues bitwise
    new_leader = rep.follower
    new_leader.insert_batch(rng.random((8, DIM)).astype(np.float32),
                            np.arange(5000, 5008, dtype=np.int32))
    assert new_leader.wal.next_seq == seq + 2
    with new_leader.epochs.reading() as pinned:
        assert tree_digest(pinned) != dg          # the write took


def test_promote_refuses_live_lease(tmp_path):
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=100.0, clock=clock)
    rng = np.random.default_rng(3)
    store.try_acquire("leader")
    leader, wal, tree0 = _run_leader(tmp_path, rng, steps=1)
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))
    with pytest.raises(LeaseLost, match="not expired"):
        promote(rep, store, "follower-1")
