"""The PR-6 acceptance drill, end to end:

concurrent client threads submit kNN queries through the async front-end
while a writer streams insert/delete batches through the cohort scheduler
— and a WAL-shipping replica tails the leader's segments the whole time.
Every ticket's answer is verified **exactly** (brute force) against the
live set of the epoch that served it, which proves no cohort ever
observed a tree swap mid-descent; the drill ends with the digest
exchange asserting the replica is bitwise identical to the leader."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.metric import pairwise
from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED, bulk_build
from repro.serve.frontend import FrontendConfig, ServeFrontend
from repro.stream import Replica, StreamingEngine, WriteAheadLog, ledger_digest

N, DIM, K = 600, 6, 3
N_CLIENTS, QUERIES_PER_CLIENT, WRITER_STEPS = 4, 15, 10


@pytest.mark.timeout(300)
def test_serve_e2e_drill(tmp_path):
    rng = np.random.default_rng(42)
    X = rng.random((N, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    metric = tree0.metric
    leader = StreamingEngine(tree0, wal=WriteAheadLog(
        str(tmp_path / "wal"), segment_max_records=4))
    replica = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))

    vec = {i: X[i] for i in range(N)}
    # epoch -> (live oids, their keys): the ground truth each served
    # ticket is checked against
    hist_lock = threading.Lock()
    oid0 = np.arange(N)
    history = {0: (oid0, X[oid0])}
    errors = []

    fe = ServeFrontend(leader, FrontendConfig(cohort_width=8, slo_ms=10.0,
                                              k=K, max_frontier=256))

    def writer():
        wrng = np.random.default_rng(1)
        live, nid = set(range(N)), N
        try:
            for _ in range(WRITER_STEPS):
                ops, xs, oids = [], [], []
                for _ in range(24):
                    if live and wrng.random() < 0.5:
                        v = int(sorted(live)[wrng.integers(len(live))])
                        live.discard(v)
                        ops.append(OP_DELETE)
                        oids.append(v)
                        xs.append(vec[v])
                    else:
                        x = wrng.random(DIM).astype(np.float32)
                        vec[nid] = x
                        live.add(nid)
                        ops.append(OP_INSERT)
                        oids.append(nid)
                        xs.append(x)
                        nid += 1
                tk = fe.submit_mutations(np.array(ops, np.int32),
                                         np.stack(xs).astype(np.float32),
                                         np.array(oids, np.int32))
                res = tk.result(120)
                assert (res.statuses == ST_APPLIED).all()
                e = leader.epochs.epoch     # writer is the only mutator
                oid_arr = np.array(sorted(live))
                with hist_lock:
                    history[e] = (oid_arr,
                                  np.stack([vec[o] for o in oid_arr]))
        except Exception as exc:  # noqa: BLE001 — surface to main thread
            errors.append(exc)

    def client(seed):
        crng = np.random.default_rng(seed)
        try:
            for _ in range(QUERIES_PER_CLIENT):
                q = crng.random(DIM).astype(np.float32)
                tk = fe.submit(q)
                d, ids = tk.result(120)
                # the serving epoch's ground truth may be recorded a beat
                # after the publish — wait for it, then verify exactly
                deadline = time.monotonic() + 60
                while True:
                    with hist_lock:
                        snap = history.get(tk.epoch)
                    if snap is not None:
                        break
                    assert time.monotonic() < deadline, \
                        f"epoch {tk.epoch} never recorded"
                    time.sleep(0.002)
                oid_arr, keys = snap
                D = pairwise(metric, q[None], keys)[0]
                want = np.sort(D)[:K]
                np.testing.assert_allclose(d, want, atol=1e-5)
                pos = {int(o): j for j, o in enumerate(oid_arr)}
                for dist, oid in zip(d, ids):
                    assert int(oid) in pos, \
                        f"id {oid} not live at epoch {tk.epoch}"
                    np.testing.assert_allclose(dist, D[pos[int(oid)]],
                                               atol=1e-5)
        except Exception as exc:  # noqa: BLE001 — surface to main thread
            errors.append(exc)

    with fe, replica:
        threads = [threading.Thread(target=writer, name="writer")]
        threads += [threading.Thread(target=client, args=(100 + i,),
                                     name=f"client-{i}")
                    for i in range(N_CLIENTS)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=240)
        assert not any(th.is_alive() for th in threads), "drill hung"
        assert not errors, errors[0]
        fe.drain(timeout=60)
        # digest exchange: the replica that tailed the WAL concurrently
        # must be bitwise identical to the leader at the same seq
        seq, dg = ledger_digest(leader)
        replica.verify(seq, dg)

    for a, b in zip(jax.tree.leaves(leader.tree),
                    jax.tree.leaves(replica.follower.tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    s = fe.stats
    assert s.n_queries == N_CLIENTS * QUERIES_PER_CLIENT
    assert s.n_mutation_batches == WRITER_STEPS
    assert s.n_full_dispatch + s.n_deadline_dispatch == s.n_cohorts
    assert 1.0 <= s.mean_fill <= 8.0
