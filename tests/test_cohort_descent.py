"""Cohort-descent engine: parity across frontier implementations, parity vs
the paper-faithful reference, and adversarial data (ISSUE 2 satellite).

The bitwise tests here are the PR's acceptance parity suite: knn and
range_search results must be identical between ``REPRO_FRONTIER_IMPL=xla``
and ``=pallas`` (interpret mode on CPU), down to stats and tie-broken ids.
"""
import numpy as np
import pytest

from repro.core.engine import SMTreeEngine
from repro.core.metric import pairwise
from repro.data.datagen import clustered, uniform

FIELDS = ("dists", "ids", "page_hits", "dist_evals", "overflow")


def assert_results_equal(a, b, msg=""):
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


def brute_knn_dists(metric, X, Q, k):
    return np.sort(pairwise(metric, Q, X), axis=1)[:, :k]


# --------------------------------------------------------------------------
# xla vs pallas bitwise parity (the acceptance suite)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("metric", ["d_inf", "l2"])
def test_knn_bitwise_xla_vs_pallas(metric):
    X = clustered(1500, dims=8, seed=3)
    eng = SMTreeEngine.build(X, capacity=16, metric=metric)
    Q = uniform(24, dims=8, seed=4)
    for k, F in ((1, 64), (10, 64), (10, 256)):
        a = eng.knn(Q, k=k, max_frontier=F, impl="xla")
        b = eng.knn(Q, k=k, max_frontier=F, impl="pallas")
        assert_results_equal(a, b, f"knn k={k} F={F} {metric}")


@pytest.mark.parametrize("metric", ["d_inf", "l2"])
def test_range_search_bitwise_xla_vs_pallas(metric):
    X = clustered(1500, dims=8, seed=5)
    eng = SMTreeEngine.build(X, capacity=16, metric=metric)
    Q = X[::100].copy()
    for r in (0.0, 0.05, 0.5):
        a = eng.range_search(Q, r, max_results=64, impl="xla")
        b = eng.range_search(Q, r, max_results=64, impl="pallas")
        assert_results_equal(a, b, f"range r={r} {metric}")


def test_env_toggle_routes_impl(monkeypatch):
    X = clustered(600, dims=6, seed=6)
    eng = SMTreeEngine.build(X, capacity=8)
    Q = uniform(8, dims=6, seed=7)
    explicit = eng.knn(Q, k=3, impl="pallas")
    monkeypatch.setenv("REPRO_FRONTIER_IMPL", "pallas")
    via_env = eng.knn(Q, k=3)
    assert_results_equal(explicit, via_env, "env routing")
    monkeypatch.setenv("REPRO_FRONTIER_IMPL", "bogus")
    with pytest.raises(ValueError):
        eng.knn(Q, k=3)


# --------------------------------------------------------------------------
# parent-distance pre-filter (DESIGN.md §17): results bitwise identical with
# pruning on vs off; only dist_evals (evaluations *performed*) may shrink
# --------------------------------------------------------------------------
RESULT_FIELDS = ("dists", "ids", "page_hits", "overflow")


def assert_results_equal_ex_evals(a, b, msg=""):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["d_inf", "l2", "l1"])
def test_knn_parent_prune_bitwise(metric, impl):
    X = clustered(1500, dims=8, seed=3)
    eng = SMTreeEngine.build(X, capacity=16, metric=metric)
    Q = np.vstack([uniform(16, dims=8, seed=4), X[:16] + 0.003])
    for k, F in ((1, 64), (10, 64), (10, 256)):
        off = eng.knn(Q, k=k, max_frontier=F, impl=impl, parent_prune=False)
        on = eng.knn(Q, k=k, max_frontier=F, impl=impl, parent_prune=True)
        assert_results_equal_ex_evals(off, on, f"knn k={k} F={F} {metric}")
        # the filter only removes work, never adds it
        assert (np.asarray(on.dist_evals) <= np.asarray(off.dist_evals)).all()


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["d_inf", "l2", "l1"])
def test_range_search_parent_prune_bitwise(metric, impl):
    X = clustered(1500, dims=8, seed=5)
    eng = SMTreeEngine.build(X, capacity=16, metric=metric)
    Q = X[::100].copy()
    for r in (0.0, 0.05, 0.5):
        off = eng.range_search(Q, r, max_results=64, impl=impl,
                               parent_prune=False)
        on = eng.range_search(Q, r, max_results=64, impl=impl,
                              parent_prune=True)
        assert_results_equal_ex_evals(off, on, f"range r={r} {metric}")
        assert (np.asarray(on.dist_evals) <= np.asarray(off.dist_evals)).all()


def test_parent_prune_env_toggle(monkeypatch):
    X = clustered(600, dims=6, seed=6)
    eng = SMTreeEngine.build(X, capacity=8)
    Q = uniform(8, dims=6, seed=7)
    explicit_off = eng.knn(Q, k=3, impl="xla", parent_prune=False)
    monkeypatch.setenv("REPRO_PARENT_PRUNE", "0")
    via_env = eng.knn(Q, k=3, impl="xla")
    assert_results_equal(explicit_off, via_env, "env off routing")
    monkeypatch.setenv("REPRO_PARENT_PRUNE", "1")
    on_env = eng.knn(Q, k=3, impl="xla")
    assert_results_equal_ex_evals(explicit_off, on_env, "env on routing")
    monkeypatch.setenv("REPRO_PARENT_PRUNE", "yes")
    with pytest.raises(ValueError, match="REPRO_PARENT_PRUNE"):
        eng.knn(Q, k=3, impl="xla")


def _collinear_tree(metric="d_inf"):
    """Planted adversarial geometry: points on a line at exactly-
    representable f32 coordinates.  For collinear same-side points the
    triangle inequality is *tight* — |d(q,p) − d(e,p)| == d(q,e) exactly,
    in f32 too — so the parent filter sits exactly on its boundary for
    every entry: any over-aggressive filtering (a missing pad, a stale
    pdist/radius) drops true neighbors."""
    n, dims = 192, 4
    X = np.zeros((n, dims), np.float32)
    X[:, 0] = np.arange(n, dtype=np.float32) / 64.0
    eng = SMTreeEngine.build(X, capacity=4, metric=metric)
    return eng, X


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_parent_prune_adversarial_collinear(impl):
    eng, X = _collinear_tree()
    # far collinear queries: every frontier entry is same-side, the filter's
    # lower bound equals the true distance bit-for-bit
    q = np.zeros((3, 4), np.float32)
    q[:, 0] = [X[-1, 0] + 8.0, -5.0, X[96, 0]]
    for k in (1, 5, 17):
        off = eng.knn(q, k=k, max_frontier=64, impl=impl, parent_prune=False)
        on = eng.knn(q, k=k, max_frontier=64, impl=impl, parent_prune=True)
        assert_results_equal_ex_evals(off, on, f"collinear k={k}")
        np.testing.assert_allclose(np.asarray(on.dists),
                                   brute_knn_dists("d_inf", X, q, k),
                                   atol=1e-6)


def test_parent_prune_rides_on_pdist_invariant():
    """Corrupting pdist makes the filter wrongly prune — the demonstration
    that pruning correctness rides on the pdist invariant (pinned
    independently by tests/test_pdist_invariant.py), while the unfiltered
    path is immune."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core import smtree
    eng, X = _collinear_tree()
    q = np.zeros((1, 4), np.float32)
    q[0, 0] = X[96, 0]
    want = brute_knn_dists("d_inf", X, q, 5)
    # stale-pdist plant: every entry claims to sit 1000 from its routing
    # object, so |d(q,p) − pdist| dwarfs rq + r and the filter drops
    # everything below the root
    bad = dataclasses.replace(eng.tree,
                              pdist=jnp.full_like(eng.tree.pdist, 1000.0))
    res_off = smtree.knn(bad, q, k=5, max_frontier=64, impl="xla",
                         parent_prune=False)
    np.testing.assert_allclose(np.asarray(res_off.dists), want, atol=1e-6)
    res_on = smtree.knn(bad, q, k=5, max_frontier=64, impl="xla",
                        parent_prune=True)
    assert not np.allclose(np.asarray(res_on.dists), want), \
        "corrupt pdist must break the filtered path (else the filter is dead)"


def test_level_stats_parent_counts():
    """level_stats returns (by_bound, by_parent); parent counts are zero at
    the root level and with the filter off, and account exactly for the
    dist_evals delta.  At internal levels, every parent-filtered entry
    provably fails the d_min bound too (DESIGN.md §17), so in the
    unfiltered trace it shows up as pruned-by-bound instead:
    bb_off == bb_on + bp_on at those levels."""
    from repro.core import smtree
    X = clustered(2000, dims=8, seed=23)
    eng = SMTreeEngine.build(X, capacity=16)
    Q = np.asarray(X[:32] + 0.002, np.float32)
    res_on, (bb_on, bp_on) = smtree.knn(eng.tree, Q, k=5, max_frontier=64,
                                        impl="xla", level_stats=True,
                                        parent_prune=True)
    res_off, (bb_off, bp_off) = smtree.knn(eng.tree, Q, k=5, max_frontier=64,
                                           impl="xla", level_stats=True,
                                           parent_prune=False)
    assert np.asarray(bp_off).sum() == 0
    assert np.asarray(bp_on)[0].sum() == 0          # root has no parent
    n_internal = np.asarray(bb_on).shape[0]
    np.testing.assert_array_equal(
        np.asarray(bb_off),
        np.asarray(bb_on) + np.asarray(bp_on)[:n_internal])
    delta = (np.asarray(res_off.dist_evals) - np.asarray(res_on.dist_evals))
    np.testing.assert_array_equal(np.asarray(bp_on).sum(axis=0), delta)
    assert np.asarray(bp_on).sum() > 0              # the filter actually bites


# --------------------------------------------------------------------------
# cohort vs legacy per-query engine (results, not stats — the cohort path's
# min-fill-aware d_max bound prunes tighter, so page_hits legitimately
# differ; distances and ids may not)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("metric", ["d_inf", "l2"])
def test_cohort_matches_perquery_results(metric):
    X = clustered(1200, dims=8, seed=9)
    eng = SMTreeEngine.build(X, capacity=16, metric=metric)
    Q = uniform(16, dims=8, seed=10)
    a = eng.knn(Q, k=8, max_frontier=256, impl="xla")
    p = eng.knn(Q, k=8, max_frontier=256, impl="perquery")
    assert not np.asarray(a.overflow).any()
    assert not np.asarray(p.overflow).any()
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(p.dists))
    # ids may tie-break differently only between equal distances; verify
    # every returned id really sits at the reported distance
    D = pairwise(metric, Q, X)
    ids = np.asarray(a.ids)
    dists = np.asarray(a.dists)
    for qi in range(len(Q)):
        for j, (i, d) in enumerate(zip(ids[qi], dists[qi])):
            if i >= 0:
                np.testing.assert_allclose(D[qi, i], d, atol=1e-5)


# --------------------------------------------------------------------------
# adversarial data (vs brute force and the paper-faithful reference)
# --------------------------------------------------------------------------
def test_duplicate_points():
    rng = np.random.default_rng(11)
    base = rng.random((200, 6)).astype(np.float32)
    X = np.repeat(base, 4, axis=0)          # every point appears 4 times
    eng = SMTreeEngine.build(X, capacity=8)
    Q = base[:16] + 0.001
    for impl in ("xla", "pallas", "perquery"):
        res = eng.knn(Q, k=8, max_frontier=512, impl=impl)
        assert not np.asarray(res.overflow).any()
        np.testing.assert_allclose(np.asarray(res.dists),
                                   brute_knn_dists("d_inf", X, Q, 8),
                                   atol=1e-5, err_msg=impl)


def test_all_points_equidistant():
    """One-hot points scaled by c: every pairwise d_inf distance is exactly
    c, and the origin sees every point at distance c — maximal tie stress
    for the d_max bound and top-k tie-breaking."""
    n = dim = 48
    c = 0.7
    X = (np.eye(n, dim) * c).astype(np.float32)
    eng = SMTreeEngine.build(X, capacity=8)
    Q = np.zeros((1, dim), np.float32)
    for impl in ("xla", "pallas", "perquery"):
        res = eng.knn(Q, k=5, max_frontier=512, impl=impl)
        assert not np.asarray(res.overflow).any()
        np.testing.assert_allclose(np.asarray(res.dists), np.full((1, 5), c),
                                   atol=1e-6, err_msg=impl)
        ids = np.asarray(res.ids)[0]
        assert len(set(ids.tolist())) == 5 and (ids >= 0).all()
    # a query at one of the points: itself at 0, the rest at c
    res = eng.knn(X[:1], k=5, max_frontier=512, impl="xla")
    d = np.asarray(res.dists)[0]
    np.testing.assert_allclose(d, [0.0, c, c, c, c], atol=1e-6)


def test_k_exceeds_n_objects():
    X = uniform(10, dims=5, seed=13)
    eng = SMTreeEngine.build(X, capacity=8)
    Q = uniform(4, dims=5, seed=14)
    for impl in ("xla", "pallas", "perquery"):
        res = eng.knn(Q, k=32, max_frontier=64, impl=impl)
        d = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        np.testing.assert_allclose(d[:, :10], brute_knn_dists("d_inf", X, Q, 10),
                                   atol=1e-5, err_msg=impl)
        assert np.isposinf(d[:, 10:]).all()
        assert (ids[:, 10:] == -1).all()
        assert (np.sort(ids[:, :10], axis=1) == np.arange(10)).all()


def test_parity_vs_ref_impl_on_clustered_and_duplicates():
    """Engine (all impls) returns the same kNN distances as the
    paper-faithful reference on clustered data salted with duplicates."""
    from repro.core.ref_impl import SMTree
    X = clustered(900, dims=10, seed=15)
    X = np.vstack([X, X[:60]])               # salt with duplicates
    eng = SMTreeEngine.build(X, capacity=16)
    ref = SMTree(dim=10, capacity=16, n_dims=10)
    for i, x in enumerate(X):
        ref.insert(x, i)
    Q = uniform(8, dims=10, seed=16)
    for impl in ("xla", "pallas", "perquery"):
        res = eng.knn(Q, k=10, max_frontier=512, impl=impl)
        assert not np.asarray(res.overflow).any()
        for qi, q in enumerate(Q):
            want = np.array([d for d, _ in ref.knn_query(q, 10)])
            np.testing.assert_allclose(np.asarray(res.dists)[qi], want,
                                       atol=1e-5, err_msg=impl)


# --------------------------------------------------------------------------
# range_search overflow-flag semantics at exactly max_results
# --------------------------------------------------------------------------
def test_range_overflow_flag_at_exact_capacity():
    """Cluster of exactly m in-radius points: max_results == m sets the
    (conservative) overflow flag, max_results > m does not; the returned id
    set is exact either way and identical across impls."""
    rng = np.random.default_rng(17)
    m = 12
    near = (rng.random((m, 6)) * 0.02).astype(np.float32)         # within 0.1
    far = (rng.random((120, 6)) * 0.5 + 5.0).astype(np.float32)   # way outside
    X = np.vstack([near, far])
    eng = SMTreeEngine.build(X, capacity=8)
    q = np.zeros((1, 6), np.float32)
    want_ids = set(range(m))

    for impl in ("xla", "pallas", "perquery"):
        # exactly max_results matches -> flag set (cannot rule out truncation)
        res = eng.range_search(q, 0.1, max_results=m, max_frontier=256,
                               impl=impl)
        assert bool(np.asarray(res.overflow)[0]), impl
        got = set(int(i) for i in np.asarray(res.ids)[0] if i >= 0)
        assert got == want_ids, impl
        # headroom -> no flag, same ids
        res = eng.range_search(q, 0.1, max_results=m + 1, max_frontier=256,
                               impl=impl)
        assert not bool(np.asarray(res.overflow)[0]), impl
        got = set(int(i) for i in np.asarray(res.ids)[0] if i >= 0)
        assert got == want_ids, impl

    a = eng.range_search(q, 0.1, max_results=m, impl="xla")
    b = eng.range_search(q, 0.1, max_results=m, impl="pallas")
    assert_results_equal(a, b, "range exact-capacity")


def test_small_awkward_builds_keep_min_fill_and_exactness():
    """bulk_build sizes that used to split below min_fill (e.g. 23 points at
    capacity 32 -> 11/12-entry leaves vs floor 13) broke the cohort d_max
    bound's coverage premise, silently dropping neighbors with
    overflow=False.  Non-root nodes must meet min_fill and knn must stay
    exact for every k up to n."""
    rng = np.random.default_rng(21)
    for n in (5, 13, 23, 24, 25, 33, 47):
        # two well-separated clusters: the adversarial case for a bound
        # that overestimates a subtree's coverage
        a = rng.random((n // 2, 4)).astype(np.float32)
        b2 = rng.random((n - n // 2, 4)).astype(np.float32) + 200.0
        X = np.vstack([a, b2])
        eng = SMTreeEngine.build(X, capacity=32)
        eng.validate()
        q = X[:2]
        for k in (1, n // 2 + 1, n):
            for impl in ("xla", "perquery"):
                res = eng.knn(q, k=k, max_frontier=256, impl=impl)
                assert not np.asarray(res.overflow).any()
                np.testing.assert_allclose(
                    np.asarray(res.dists), brute_knn_dists("d_inf", X, q, k),
                    atol=1e-5, err_msg=f"n={n} k={k} {impl}")


# --------------------------------------------------------------------------
# l1 rides the shared metric registry through all three call sites
# --------------------------------------------------------------------------
def test_l1_metric_end_to_end():
    X = clustered(500, dims=6, seed=19)
    eng = SMTreeEngine.build(X, capacity=8, metric="l1")
    Q = uniform(8, dims=6, seed=20)
    a = eng.knn(Q, k=4, max_frontier=256, impl="xla")
    b = eng.knn(Q, k=4, max_frontier=256, impl="pallas")
    assert_results_equal(a, b, "l1")
    assert not np.asarray(a.overflow).any()
    np.testing.assert_allclose(np.asarray(a.dists),
                               brute_knn_dists("l1", X, Q, 4), atol=1e-5)
