"""WAL tailing + WAL-shipping read replicas.

Covers the follower cursor (incremental tail, segment rotation, snapshot
fast-forward, torn-tail resume), bitwise replication of a StreamingEngine
and a rebalancing StreamingForest, snapshot bootstrap, and the digest
exchange catching real divergence."""
import os

import jax
import numpy as np
import pytest

from repro.core.distributed import build_forest_trees
from repro.core.smtree import OP_DELETE, OP_INSERT, ST_APPLIED, bulk_build
from repro.dist.checkpoint import CheckpointManager
from repro.stream import (DigestMismatch, Replica, StreamingEngine,
                          StreamingForest, WalCursor, WalTailStall,
                          WriteAheadLog, ledger_digest, tail_wal,
                          tree_digest)
from repro.stream.wal import KIND_BATCH, WalRecord, _encode

DIM = 6


def _batch(rng, n, start_oid):
    ops = np.full(n, OP_INSERT, np.int8)
    xs = rng.random((n, DIM)).astype(np.float32)
    oids = (start_oid + np.arange(n)).astype(np.int32)
    return ops, xs, oids


# -- tail_wal cursor ------------------------------------------------------

def test_tail_wal_incremental(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(str(tmp_path), segment_max_records=3)
    cur = WalCursor()
    for i in range(7):
        wal.append_batch(*_batch(rng, 4, 10 * i))
        recs, cur = tail_wal(str(tmp_path), cur)
        assert [r.seq for r in recs] == [i]     # exactly the new record
        assert cur.seq == i
    recs, cur = tail_wal(str(tmp_path), cur)
    assert recs == []                           # idempotent at the tip


def test_tail_wal_snapshot_fast_forward(tmp_path):
    """A cursor born from a snapshot (seq set, position 0) skips sealed
    segments wholly below it without re-yielding their records."""
    rng = np.random.default_rng(1)
    wal = WriteAheadLog(str(tmp_path), segment_max_records=2)
    for i in range(6):
        wal.append_batch(*_batch(rng, 2, 10 * i))
    recs, cur = tail_wal(str(tmp_path), WalCursor(seq=3))
    assert [r.seq for r in recs] == [4, 5]
    np.testing.assert_array_equal(recs[0].oids, np.arange(40, 42))


def test_tail_wal_torn_tail_resume(tmp_path):
    """A frame the leader is mid-append on parks the cursor at the last
    complete frame; once the rest of the bytes land, the same cursor picks
    the record up whole."""
    rng = np.random.default_rng(2)
    wal = WriteAheadLog(str(tmp_path), segment_max_records=100)
    wal.append_batch(*_batch(rng, 4, 0))
    wal.append_batch(*_batch(rng, 4, 10))
    wal.close()
    seg = sorted(p for p in os.listdir(tmp_path)
                 if p.endswith(".wal"))[-1]
    path = tmp_path / seg
    whole = os.path.getsize(path)
    ops, xs, oids = _batch(rng, 4, 20)
    frame = _encode(WalRecord(KIND_BATCH, 2, ops=ops, oids=oids, xs=xs))
    with open(path, "ab") as f:                 # half a frame: torn tail
        f.write(frame[:len(frame) // 2])
    recs, cur = tail_wal(str(tmp_path), WalCursor())
    assert [r.seq for r in recs] == [0, 1]
    assert cur.offset == whole                  # parked before the torn frame
    recs, cur = tail_wal(str(tmp_path), cur)
    assert recs == []                           # still torn: no progress
    with open(path, "ab") as f:
        f.write(frame[len(frame) // 2:])        # append completes
    recs, cur = tail_wal(str(tmp_path), cur)
    assert [r.seq for r in recs] == [2]
    np.testing.assert_array_equal(recs[0].oids, oids)
    np.testing.assert_array_equal(recs[0].xs, xs)


def test_tail_wal_bounded_records_resumes_exactly(tmp_path):
    """max_records stops on a frame boundary; repeated bounded polls
    drain the backlog with no loss or duplication."""
    rng = np.random.default_rng(8)
    wal = WriteAheadLog(str(tmp_path), segment_max_records=4)
    for i in range(11):
        wal.append_batch(*_batch(rng, 2, 10 * i))
    cur = WalCursor()
    seen = []
    for _ in range(20):
        recs, cur = tail_wal(str(tmp_path), cur, max_records=3)
        assert len(recs) <= 3
        seen.extend(r.seq for r in recs)
        if not recs:
            break
    assert seen == list(range(11))


def test_tail_wal_stall_diagnostic_on_planted_corruption(tmp_path):
    """Planted mid-segment corruption: the cursor parks (correct), the
    stall counter climbs (diagnostic), and max_stalls turns park-forever
    into WalTailStall — while a benign torn tail never trips it."""
    rng = np.random.default_rng(9)
    wal = WriteAheadLog(str(tmp_path), segment_max_records=100)
    wal.append_batch(*_batch(rng, 4, 0))
    wal.append_batch(*_batch(rng, 4, 10))
    wal.close()
    seg = sorted(p for p in os.listdir(tmp_path) if p.endswith(".wal"))[-1]
    path = tmp_path / seg
    recs, cur = tail_wal(str(tmp_path), WalCursor())
    assert cur.stalls == 0
    # corrupt bytes in the *middle* of the active segment's unread tail:
    # a whole frame of garbage that will never complete into a record
    with open(path, "ab") as f:
        f.write(b"\xde\xad" * 40)
    for _ in range(4):
        recs, cur = tail_wal(str(tmp_path), cur, max_stalls=5)
        assert recs == []
    assert cur.stalls == 4
    with pytest.raises(WalTailStall, match="undecodable bytes"):
        tail_wal(str(tmp_path), cur, max_stalls=5)
    # progress (a complete frame landing) clears the counter — even
    # though the corrupt bytes will now never parse, any *new* complete
    # record resets the benign-vs-corrupt clock... but appends land
    # AFTER the garbage, which never parses: the stall persists, which
    # is exactly why this raises instead of parking silently.


def test_replica_bounded_poll_and_lag(tmp_path):
    from repro.core.smtree import bulk_build as _bb
    rng = np.random.default_rng(10)
    X = rng.random((200, DIM)).astype(np.float32)
    tree0 = _bb(X, capacity=8)
    leader = StreamingEngine(tree0, wal=WriteAheadLog(
        str(tmp_path / "wal"), segment_max_records=4))
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"),
                  max_records_per_poll=2)
    for i in range(7):
        leader.insert_batch(rng.random((8, DIM)).astype(np.float32),
                            np.arange(500 + 8 * i, 508 + 8 * i,
                                      dtype=np.int32))
    rep.note_leader_seq(6)
    assert rep.lag == 7
    n = rep.poll()
    assert n <= 2                     # bounded slice of the backlog
    assert rep.lag == 7 - rep.applied_seq - 1
    total = n
    while (n := rep.poll()) > 0:
        assert n <= 2
        total += n
    assert total == 7 and rep.lag == 0
    seq, dg = ledger_digest(leader)
    rep.verify(seq, dg)


# -- replicas -------------------------------------------------------------

def _mixed_stream(leader, rng, vec, live, nid, steps=4, n=48):
    for _ in range(steps):
        ops, xs, oids = [], [], []
        for _ in range(n):
            if live and rng.random() < 0.4:
                v = int(sorted(live)[rng.integers(len(live))])
                live.discard(v)
                ops.append(OP_DELETE)
                oids.append(v)
                xs.append(vec[v])
            else:
                x = rng.random(DIM).astype(np.float32)
                vec[nid] = x
                live.add(nid)
                ops.append(OP_INSERT)
                oids.append(nid)
                xs.append(x)
                nid += 1
        res = leader.apply(np.array(ops, np.int32),
                           np.stack(xs).astype(np.float32),
                           np.array(oids, np.int32))
        assert (res.statuses == ST_APPLIED).all()
    return nid


def test_replica_engine_bitwise(tmp_path):
    rng = np.random.default_rng(3)
    X = rng.random((400, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    leader = StreamingEngine(tree0, wal=WriteAheadLog(
        str(tmp_path / "wal"), segment_max_records=3))
    rep = Replica(StreamingEngine(tree0), str(tmp_path / "wal"))
    vec = {i: X[i] for i in range(400)}
    _mixed_stream(leader, rng, vec, set(range(400)), 400)
    seq, dg = ledger_digest(leader)
    rep.verify(seq, dg)                         # raises on any divergence
    assert rep.applied_seq == seq
    for a, b in zip(jax.tree.leaves(leader.tree),
                    jax.tree.leaves(rep.follower.tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replica_from_snapshot_and_background_tail(tmp_path):
    rng = np.random.default_rng(4)
    X = rng.random((400, DIM)).astype(np.float32)
    leader = StreamingEngine(
        bulk_build(X, capacity=8),
        wal=WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3),
        ckpt=CheckpointManager(str(tmp_path / "ck"), async_write=False))
    vec = {i: X[i] for i in range(400)}
    live = set(range(400))
    nid = _mixed_stream(leader, rng, vec, live, 400, steps=2)
    leader.snapshot()
    rep = Replica.from_snapshot(str(tmp_path / "ck"), str(tmp_path / "wal"))
    assert rep.applied_seq == 1                 # snapshot high-water mark
    with rep:                                   # background tailing thread
        _mixed_stream(leader, rng, vec, live, nid, steps=3)
        seq, dg = ledger_digest(leader)
        rep.verify(seq, dg)
    for a, b in zip(jax.tree.leaves(leader.tree),
                    jax.tree.leaves(rep.follower.tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replica_forest_replays_rebalance(tmp_path):
    """A follower replays rebalance records (recorded seed) at the same
    point in the mutation order and lands bitwise on the leader's shards,
    ownership map included."""
    rng = np.random.default_rng(5)
    X = rng.random((800, DIM)).astype(np.float32)
    leader = StreamingForest(
        build_forest_trees(X, 4, capacity=8),
        wal=WriteAheadLog(str(tmp_path / "wal"), segment_max_records=4),
        min_objects=64, max_skew=1.3)
    rep = Replica(StreamingForest(build_forest_trees(X, 4, capacity=8),
                                  min_objects=64, max_skew=1.3),
                  str(tmp_path / "wal"))
    victims = np.array([o for o in range(800) if o % 4 == 0][:150])
    res = leader.delete_batch(X[victims], victims)
    assert (res.statuses == ST_APPLIED).all()
    assert leader.maintenance(), "skew should trigger a rebalance"
    vec = {i: X[i] for i in range(800)}
    _mixed_stream(leader, rng, vec, set(range(800)) - set(victims.tolist()),
                  800, steps=2)
    seq, dg = ledger_digest(leader)
    rep.verify(seq, dg)
    assert rep.follower.n_rebalances == 1
    assert rep.follower.owner == leader.owner
    for a, b in zip(jax.tree.leaves(leader.stacked()),
                    jax.tree.leaves(rep.follower.stacked())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replica_rejects_wal_owning_follower(tmp_path):
    tree = bulk_build(np.random.default_rng(6).random(
        (64, DIM)).astype(np.float32), capacity=8)
    follower = StreamingEngine(tree, wal=WriteAheadLog(str(tmp_path / "w2")))
    with pytest.raises(ValueError, match="must not own a WAL"):
        Replica(follower, str(tmp_path / "wal"))


def test_digest_exchange_catches_divergence(tmp_path):
    rng = np.random.default_rng(7)
    X = rng.random((300, DIM)).astype(np.float32)
    leader = StreamingEngine(bulk_build(X, capacity=8),
                             wal=WriteAheadLog(str(tmp_path / "wal")))
    # follower bootstrapped from the WRONG snapshot: replay still runs,
    # digests must disagree
    Y = rng.random((300, DIM)).astype(np.float32)
    rep = Replica(StreamingEngine(bulk_build(Y, capacity=8)),
                  str(tmp_path / "wal"))
    leader.insert_batch(rng.random((16, DIM)).astype(np.float32),
                        np.arange(300, 316, dtype=np.int32))
    seq, dg = ledger_digest(leader)
    with pytest.raises(DigestMismatch):
        rep.verify(seq, dg)
    assert tree_digest(leader.tree) != tree_digest(rep.follower.tree)
