"""Socket WAL shipping: byte-identical mirroring, idempotent redelivery
under injected frame faults, kill-and-restart of both endpoints, and the
ShippedReplica composition (ship -> replay -> digest verify)."""
import os

import numpy as np
import pytest

from repro.core.smtree import OP_INSERT, bulk_build
from repro.stream import (StreamingEngine, WriteAheadLog, ledger_digest)
from repro.stream.faults import FaultInjector, FaultPlan
from repro.stream.transport import (ShippedReplica, TransportError,
                                    WalShipClient, WalShipServer)
from repro.stream.wal import _scan_dir

DIM = 6


def _batch(rng, n, start_oid):
    ops = np.full(n, OP_INSERT, np.int8)
    xs = rng.random((n, DIM)).astype(np.float32)
    oids = (start_oid + np.arange(n)).astype(np.int32)
    return ops, xs, oids


def _dir_bytes(d):
    return {n: open(os.path.join(d, n), "rb").read() for n in _scan_dir(d)}


def _pump(client, wal, *, rounds=400):
    """Poll until the mirror holds every leader byte (bounded)."""
    want = sum(os.path.getsize(os.path.join(wal.directory, n))
               for n in _scan_dir(wal.directory))
    for _ in range(rounds):
        client.poll()
        got = sum(os.path.getsize(os.path.join(client.mirror_dir, n))
                  for n in _scan_dir(client.mirror_dir))
        if got >= want:
            return
    raise AssertionError(f"mirror stuck at {got}/{want} bytes")


def test_ship_mirror_byte_identical(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    for i in range(8):
        wal.append_batch(*_batch(rng, 16, 100 * i))
    with WalShipServer(str(tmp_path / "wal"), wal=wal) as srv:
        client = WalShipClient(srv.address, str(tmp_path / "mirror"))
        _pump(client, wal)
        client.close()
    assert _dir_bytes(str(tmp_path / "wal")) == \
        _dir_bytes(str(tmp_path / "mirror"))
    assert client.leader_seq == 7


def test_ship_resumes_and_tracks_live_appends(tmp_path):
    rng = np.random.default_rng(1)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=4)
    wal.append_batch(*_batch(rng, 8, 0))
    with WalShipServer(str(tmp_path / "wal"), wal=wal) as srv:
        client = WalShipClient(srv.address, str(tmp_path / "mirror"))
        _pump(client, wal)
        for i in range(1, 10):          # keep appending under shipping
            wal.append_batch(*_batch(rng, 8, 100 * i))
            _pump(client, wal)
        client.close()
    assert _dir_bytes(str(tmp_path / "wal")) == \
        _dir_bytes(str(tmp_path / "mirror"))


@pytest.mark.parametrize("seed", [11, 12, 13])
def test_ship_converges_under_frame_faults(tmp_path, seed):
    """Drop/dup/reorder/torn injection: the append-at-size invariant plus
    resync-truncate must still converge to a byte-identical mirror."""
    rng = np.random.default_rng(seed)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    for i in range(10):
        wal.append_batch(*_batch(rng, 24, 100 * i))
    fault = FaultInjector(FaultPlan(seed=seed, drop_p=0.1, dup_p=0.1,
                                    reorder_p=0.1, torn_p=0.05))
    with WalShipServer(str(tmp_path / "wal"), wal=wal, fault=fault,
                       chunk_bytes=256) as srv:
        client = WalShipClient(srv.address, str(tmp_path / "mirror"),
                               seed=seed)
        _pump(client, wal, rounds=2000)
        client.close()
    assert _dir_bytes(str(tmp_path / "wal")) == \
        _dir_bytes(str(tmp_path / "mirror"))
    # the faults actually fired (otherwise this test proves nothing)
    assert sum(fault.counts.values()) > 0


def test_ship_server_kill_and_restart(tmp_path):
    rng = np.random.default_rng(3)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=4)
    wal.append_batch(*_batch(rng, 8, 0))
    srv = WalShipServer(str(tmp_path / "wal"), wal=wal).start()
    client = WalShipClient(srv.address, str(tmp_path / "mirror"))
    _pump(client, wal)
    srv.stop()                              # leader endpoint dies
    wal.append_batch(*_batch(rng, 8, 100))
    with pytest.raises(TransportError):
        for _ in range(3):                  # an in-flight round may still
            client.poll()                   # be served; then refused/broken
    srv.start()                             # rebinds the same port
    try:
        _pump(client, wal)
        assert _dir_bytes(str(tmp_path / "wal")) == \
            _dir_bytes(str(tmp_path / "mirror"))
    finally:
        client.close()
        srv.stop()


def test_ship_client_kill_and_restart_resyncs(tmp_path):
    """A new client over an existing mirror resumes from the mirror's
    scanned valid length — no re-shipping from zero, no duplication."""
    rng = np.random.default_rng(4)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=4)
    for i in range(4):
        wal.append_batch(*_batch(rng, 8, 100 * i))
    with WalShipServer(str(tmp_path / "wal"), wal=wal) as srv:
        c1 = WalShipClient(srv.address, str(tmp_path / "mirror"))
        _pump(c1, wal)
        c1.close()                          # killed
        # mutilate the mirror tail: simulates dying mid-append
        names = _scan_dir(str(tmp_path / "mirror"))
        tail = os.path.join(str(tmp_path / "mirror"), names[-1])
        with open(tail, "ab") as f:
            f.write(b"\x07garbage")
        for i in range(4, 7):
            wal.append_batch(*_batch(rng, 8, 100 * i))
        c2 = WalShipClient(srv.address, str(tmp_path / "mirror"))
        _pump(c2, wal)
        c2.close()
    assert _dir_bytes(str(tmp_path / "wal")) == \
        _dir_bytes(str(tmp_path / "mirror"))


def test_shipped_replica_end_to_end(tmp_path):
    rng = np.random.default_rng(5)
    X = rng.random((300, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    leader = StreamingEngine(tree0, wal=wal)
    with WalShipServer(str(tmp_path / "wal"), wal=wal) as srv:
        rep = ShippedReplica(StreamingEngine(tree0), srv.address,
                             str(tmp_path / "mirror"))
        for i in range(6):
            leader.insert_batch(rng.random((12, DIM)).astype(np.float32),
                                np.arange(1000 + 12 * i, 1012 + 12 * i,
                                          dtype=np.int32))
        seq, dg = ledger_digest(leader)
        rep.catch_up(seq)
        rep.verify(seq, dg)                 # bitwise across the socket
        assert rep.lag == 0
        rep.stop()


def test_shipped_replica_background_pump_under_faults(tmp_path):
    rng = np.random.default_rng(6)
    X = rng.random((300, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    leader = StreamingEngine(tree0, wal=wal)
    fault = FaultInjector(FaultPlan(seed=6, drop_p=0.05, reorder_p=0.05))
    with WalShipServer(str(tmp_path / "wal"), wal=wal, fault=fault,
                       chunk_bytes=512) as srv:
        with ShippedReplica(StreamingEngine(tree0), srv.address,
                            str(tmp_path / "mirror"), seed=6) as rep:
            for i in range(5):
                leader.insert_batch(
                    rng.random((10, DIM)).astype(np.float32),
                    np.arange(2000 + 10 * i, 2010 + 10 * i,
                              dtype=np.int32))
            seq, dg = ledger_digest(leader)
            rep.verify(seq, dg, timeout=60.0)
