"""pdist exactness — the invariant the parent-distance filter rides on.

The cohort descent's pre-filter (DESIGN.md §17) prunes an entry when
``|d(q, parent) − pdist| > r_q + r`` without ever evaluating the metric,
which is only sound if every stored ``pdist[n, s]`` is *exactly* the f32
metric value ``d(vecs[n, s], routing vector of node n)`` — the vector the
parent stores at ``vecs[parent[n], pslot[n]]``.  Every mutation path
(bulk build, fast insert/delete, host and device splits/merges, batch
migration) must maintain this bitwise, not merely to tolerance: the
bitwise-identity argument for the filter assumes the stored value equals
the recomputed one, and all writers share the fixed-association metric
fold in core/metric.py, so exact equality is the honest contract.

``SMTreeEngine.validate`` checks pdist only to atol=1e-4; this file is
the strict version, drilled through randomized mutation interleavings.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import smtree
from repro.core.engine import SMTreeEngine
from repro.core.metric import get_metric
from repro.core.smtree import (OP_DELETE, OP_INSERT, OP_NOP, apply_mutations,
                               bulk_build)
from repro.data.datagen import clustered, uniform

DIM = 6
METRICS = ["d_inf", "l2", "l1"]


def assert_pdist_exact(tree, msg=""):
    """Bitwise check: every valid entry of every alive non-root node has
    ``pdist == metric(vec, parent routing vector)`` exactly."""
    g = lambda a: np.asarray(jax.device_get(a))
    valid, alive = g(tree.valid), g(tree.alive)
    parent, pslot = g(tree.parent), g(tree.pslot)
    vecs, pdist = g(tree.vecs), g(tree.pdist)
    root = int(g(tree.root))
    N = alive.shape[0]
    has_parent = alive & (np.arange(N) != root) & (parent >= 0)
    pn = np.where(has_parent, parent, 0)
    ps = np.where(has_parent, np.maximum(pslot, 0), 0)
    routing = vecs[pn, ps]                                   # [N, dim]
    want = np.asarray(get_metric(tree.metric)(vecs, routing[:, None, :]))
    mask = valid & has_parent[:, None]
    assert want.dtype == np.float32
    np.testing.assert_array_equal(pdist[mask], want[mask], err_msg=msg)


# ---------------------------------------------------------------------------
# static builds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("gen", [uniform, clustered])
def test_bulk_build_pdist_exact(metric, gen):
    X = gen(500, dims=DIM, seed=11)
    t = bulk_build(X, capacity=8, metric=metric)
    assert_pdist_exact(t, f"bulk_build/{metric}/{gen.__name__}")


# ---------------------------------------------------------------------------
# randomized host-engine interleavings (fast path + host splits/merges)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_engine_mutations_keep_pdist_exact(seed):
    rng = np.random.default_rng(seed)
    metric = ["d_inf", "l2", "l1"][seed % 3]
    X = uniform(600, dims=DIM, seed=seed).astype(np.float32)
    eng = SMTreeEngine.build(X[:200], ids=np.arange(200), capacity=4,
                             metric=metric, slack=3.0)
    live = set(range(200))
    next_id = 200
    for step in range(120):
        if live and rng.random() < 0.4:
            oid = int(rng.choice(sorted(live)))
            assert eng.delete(X[oid], oid)
            live.discard(oid)
        elif next_id < len(X):
            eng.insert(X[next_id], next_id)
            live.add(next_id)
            next_id += 1
        if step % 40 == 39:                 # mid-drill, not only at the end
            assert_pdist_exact(eng.tree, f"host seed={seed} step={step}")
    assert_pdist_exact(eng.tree, f"host seed={seed} final")
    eng.validate()


# ---------------------------------------------------------------------------
# device batch path (fused scan + device splits/merges)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_device_mutations_keep_pdist_exact(seed):
    rng = np.random.default_rng(seed ^ 0x5EED)
    metric = ["d_inf", "l2", "l1"][seed % 3]
    X = clustered(700, dims=DIM, seed=seed).astype(np.float32)
    t = bulk_build(X[:300], ids=np.arange(300), capacity=4, metric=metric,
                   slack=3.0)
    live = list(range(300))
    next_id = 300
    for batch in range(3):
        ops, xs, oids = [], [], []
        # conflict-free cohort: each oid at most once per batch
        dels = rng.choice(live, size=min(24, len(live)), replace=False)
        for oid in dels:
            ops.append(OP_DELETE); xs.append(X[oid]); oids.append(oid)
        n_ins = min(40, len(X) - next_id)
        for oid in range(next_id, next_id + n_ins):
            ops.append(OP_INSERT); xs.append(X[oid]); oids.append(oid)
        ops.append(OP_NOP); xs.append(np.zeros(DIM, np.float32)); oids.append(-1)
        t, st_ = apply_mutations(
            t, np.asarray(ops, np.int32), np.asarray(xs, np.float32),
            np.asarray(oids, np.int32), splits=True, merges=True)
        st_ = np.asarray(st_)
        applied = np.isin(st_, (smtree.ST_APPLIED, smtree.ST_SPLIT,
                                smtree.ST_MERGE))
        for op, oid, ok in zip(ops, oids, applied):
            if not ok or oid < 0:
                continue
            if op == OP_DELETE:
                live.remove(oid)
            elif op == OP_INSERT:
                live.append(oid)
        next_id += n_ins
        assert_pdist_exact(t, f"device seed={seed} batch={batch}")


# ---------------------------------------------------------------------------
# batch migration between trees (extract + cohort apply on both sides)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
def test_move_objects_keeps_pdist_exact(metric):
    X = uniform(400, dims=DIM, seed=5).astype(np.float32)
    donor = bulk_build(X[:200], ids=np.arange(200), capacity=4,
                       metric=metric, slack=3.0)
    receiver = bulk_build(X[200:], ids=np.arange(200, 400), capacity=4,
                          metric=metric, slack=3.0)
    rng = np.random.default_rng(9)
    ids = rng.choice(200, size=48, replace=False).astype(np.int32)
    donor, receiver, moved = smtree.move_objects(donor, receiver, ids,
                                                 splits=True, merges=True)
    assert int(np.asarray(moved).sum()) > 0
    assert_pdist_exact(donor, f"move/{metric}/donor")
    assert_pdist_exact(receiver, f"move/{metric}/receiver")
