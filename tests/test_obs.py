"""Observability plane unit tests: registry instruments (bounded
histograms, percentile estimates, snapshot flattening), trace spans
(thread-local parenting, connectedness), the flight-recorder ring
(bounded, JSON dumps), the disabled-path no-op contract, and the
planted-``FencedOut`` dump trigger through the real WAL fence hook."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs.export import metrics_snapshot, missing_rows
from repro.obs.recorder import FlightRecorder
from repro.obs.registry import Histogram
from repro.stream.wal import FencedOut, WriteAheadLog


@pytest.fixture
def obs_on(monkeypatch, tmp_path):
    """Enabled plane with a clean slate; dumps land in tmp_path."""
    monkeypatch.setenv("REPRO_OBS_DUMP_DIR", str(tmp_path))
    obs.reset()
    obs.enable()
    obs.set_trace_sampling(1)        # trace every root: tests need them all
    yield
    obs.disable()
    obs.set_trace_sampling(obs.TRACE_SAMPLE_EVERY)
    obs.reset()


# ----------------------------------------------------------------- registry

def test_counter_gauge_roundtrip(obs_on):
    obs.counter("t.hits_total").inc()
    obs.counter("t.hits_total").inc(4)
    obs.gauge("t.depth").set(7.0)
    snap = obs.REGISTRY.snapshot()
    assert snap["t.hits_total"] == 5
    assert snap["t.depth"] == 7.0


def test_histogram_percentiles_and_bounds(obs_on):
    h = obs.histogram("t.lat_s", buckets=(1.0, 2.0, 4.0, 8.0))
    h.observe_many([0.5] * 50 + [3.0] * 45 + [100.0] * 5)
    assert h.count == 100
    # p50 lands in the first bucket (<=1.0), p95 in (2,4], p99 overflows
    # to the exact observed max
    assert h.percentile(50) == 1.0
    assert h.percentile(95) == 4.0
    assert h.percentile(99) == 100.0
    full = h.full_snapshot()
    assert full["min"] == 0.5 and full["max"] == 100.0
    # bounded memory: bucket table only, never a sample list
    assert len(h._counts) == 5


def test_histogram_single_sample_clamps_to_max(obs_on):
    h = Histogram("t.one", buckets=(1e-3, 1.0, 1000.0))
    h.observe(2.5)     # alone in the huge (1, 1000] bucket
    assert h.percentile(50) == 2.5   # clamped, not the 1000.0 ceiling


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("t.bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("t.empty", buckets=())


def test_registry_kind_mismatch(obs_on):
    obs.counter("t.kind")
    with pytest.raises(TypeError):
        obs.gauge("t.kind")


def test_registry_snapshot_flattens_histograms(obs_on):
    obs.histogram("t.h", buckets=(1.0, 2.0)).observe(0.5)
    snap = obs.REGISTRY.snapshot()
    for k in ("t.h.count", "t.h.sum", "t.h.p50", "t.h.p95", "t.h.p99"):
        assert k in snap
    assert snap["t.h.count"] == 1


def test_counters_are_thread_safe(obs_on):
    c = obs.counter("t.mt_total")

    def work():
        for _ in range(10_000):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert c.value == 40_000


# -------------------------------------------------------------------- trace

def test_span_nesting_and_connectedness(obs_on):
    with obs.span("root") as root:
        tid = root.trace_id
        with obs.span("child"):
            # thread-local parenting: no explicit ctx plumbing
            with obs.span("grandchild"):
                pass
        s = obs.start_span("sibling", parent=root.ctx)
        s.end()
    records = obs.RECORDER.records()
    spans = obs.assemble_trace(records, tid)
    assert sorted(x["name"] for x in spans) == [
        "child", "grandchild", "root", "sibling"]
    assert obs.trace_connected(records, tid)
    by_name = {x["name"]: x for x in spans}
    assert by_name["child"]["parent_id"] == by_name["root"]["span_id"]
    assert by_name["grandchild"]["parent_id"] == by_name["child"]["span_id"]
    assert all(x["duration_s"] >= 0.0 for x in spans)


def test_cohort_fan_in_via_links(obs_on):
    a = obs.start_span("frontend.query")     # two independent tickets
    b = obs.start_span("frontend.query", parent=None, trace_id=None)
    cohort = obs.start_span("frontend.cohort", parent=a.ctx,
                            links=(b.trace_id,))
    comp = obs.start_span("frontend.device_compute", parent=cohort.ctx)
    comp.end()
    cohort.end()
    a.end()
    b.end()
    records = obs.RECORDER.records()
    # the primary ticket owns the cohort subtree; the linked ticket still
    # reaches the shared cohort span through its link
    got_a = {s["name"] for s in obs.assemble_trace(records, a.trace_id)}
    assert {"frontend.query", "frontend.cohort",
            "frontend.device_compute"} <= got_a
    got_b = {s["name"] for s in obs.assemble_trace(records, b.trace_id)}
    assert {"frontend.query", "frontend.cohort"} <= got_b
    assert obs.trace_connected(records, a.trace_id)
    assert obs.trace_connected(records, b.trace_id)


def test_span_error_attr_on_exception(obs_on):
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (rec,) = obs.RECORDER.spans()
    assert rec["attrs"]["error"] == "RuntimeError"


def test_head_sampling_thins_roots_not_children(obs_on):
    obs.set_trace_sampling(4)
    roots = [obs.start_span("ticket", sampled=True) for _ in range(16)]
    real = [s for s in roots if s is not obs.NULL_SPAN]
    assert len(real) == 4                     # 1 in 4, counter-aligned
    # a child of a traced root is always real, never re-sampled
    child = obs.start_span("child", parent=real[0].ctx, sampled=True)
    assert child is not obs.NULL_SPAN
    assert child.trace_id == real[0].trace_id
    # unsampled roots (no sampled=True) are unaffected by the rate
    assert obs.start_span("mutation") is not obs.NULL_SPAN
    obs.set_trace_sampling(1)
    assert obs.start_span("ticket", sampled=True) is not obs.NULL_SPAN


# ----------------------------------------------------------------- recorder

def test_ring_is_bounded():
    rec = FlightRecorder(capacity=8)
    for i in range(100):
        rec.record_event("e", i=i)
    records = rec.records()
    assert len(records) == 8
    assert [r["attrs"]["i"] for r in records] == list(range(92, 100))
    assert rec.stats()["n_events"] == 100


def test_dump_roundtrip(obs_on, tmp_path):
    obs.record_event("lease.acquired", holder="n0", token=3)
    with obs.span("mutation.apply", n=4):
        pass
    path = obs.RECORDER.dump(reason="manual", metrics=obs.REGISTRY.snapshot())
    with open(path) as f:
        doc = json.load(f)
    assert doc["reason"] == "manual"
    kinds = {r["kind"] for r in doc["records"]}
    assert kinds == {"event", "span"}
    assert obs.RECORDER.last_dump_path == path


def test_record_fault_attaches_exception_and_metrics(obs_on):
    obs.counter("t.pre_total").inc(2)
    path = obs.record_fault("transport.ship_stall",
                            ConnectionError("pump died"), rounds=7)
    with open(path) as f:
        doc = json.load(f)
    assert doc["reason"] == "transport.ship_stall"
    ev = [r for r in doc["records"] if r["kind"] == "event"][-1]
    assert ev["attrs"]["exc_type"] == "ConnectionError"
    assert ev["attrs"]["rounds"] == 7
    assert doc["metrics"]["t.pre_total"] == 2


def test_planted_fenced_out_dumps(obs_on, tmp_path):
    """The real WAL fence hook: a fenced append must raise AND leave a
    flight-recorder dump behind (reason wal.fenced_out)."""
    def fence():
        raise FencedOut("planted: higher token exists")

    wal = WriteAheadLog(str(tmp_path / "wal"))
    ops = np.zeros(2, np.int8)
    xs = np.zeros((2, 3), np.float32)
    oids = np.arange(2, dtype=np.int32)
    wal.append_batch(ops, xs, oids)          # healthy append first
    wal.fence = fence
    with pytest.raises(FencedOut):
        wal.append_batch(ops, xs, oids)
    wal.close()
    path = obs.RECORDER.last_dump_path
    assert path is not None and "wal.fenced_out" in path
    with open(path) as f:
        doc = json.load(f)
    assert doc["reason"] == "wal.fenced_out"
    names = [r["name"] for r in doc["records"] if r["kind"] == "event"]
    assert "wal.fenced_out" in names
    # the healthy append's counters rode into the attached snapshot
    assert doc["metrics"]["wal.appends_total"] == 1


# ------------------------------------------------------------ disabled path

def test_disabled_everything_is_noop(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DUMP_DIR", str(tmp_path))
    obs.reset()
    obs.disable()
    obs.counter("t.off_total").inc(5)
    obs.gauge("t.off").set(1.0)
    obs.histogram("t.off_s").observe(0.1)
    assert obs.counter("t.off_total").value == 0
    assert obs.histogram("t.off_s").count == 0
    s = obs.start_span("t.span")
    assert s is obs.NULL_SPAN and s.ctx is None
    with obs.span("t.cm") as inner:
        assert inner is obs.NULL_SPAN
    obs.record_event("t.event")
    assert obs.record_fault("t.fault", RuntimeError("x")) is None
    assert obs.RECORDER.records() == []
    assert obs.RECORDER.last_dump_path is None


def test_direct_instruments_are_always_on():
    """FrontendStats latency lives on a directly-constructed histogram:
    it must keep observing with the plane off (the bench gate reads its
    percentiles)."""
    obs.disable()
    h = Histogram("standalone", buckets=(1.0, 2.0))
    h.observe(0.5)
    assert h.count == 1


# ------------------------------------------------------------------- export

def test_metrics_snapshot_and_missing_rows(obs_on):
    obs.counter("frontend.queries_total").inc(3)
    obs.counter("wal.appends_total").inc(1)
    snap = metrics_snapshot()
    assert snap["enabled"] is True
    assert snap["metrics"]["frontend.queries_total"] == 3
    assert missing_rows(snap, ["frontend.", "wal."]) == []
    assert missing_rows(snap, ["router.", "frontend."]) == ["router."]
    # the snapshot is JSON all the way down
    json.dumps(snap)
