"""PR-4 device split pass + free-list allocator tests.

The contract under test: single-level leaf splits resolved on device
(``smtree.apply_splits`` / the ``forest_apply_splits`` collective) are
**bitwise-transparent** — applying a mutation log with device splits on
yields exactly the tree the host escalation path produces, because the
device pass replays ``_HostView.insert_with_split`` decision-for-decision
(same mM_RAD promotion tie-breaks, same sequential-rebalance member order,
same lowest-free-id allocation) and the escalation ladder preserves log
order around the rows it cannot absorb.

Also covered: the packed free-ring invariants, negative-oid boundary
rejection, and the pad-row sentinel hardening (a stored sentinel-colliding
id can never be touched by a pad row).
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import smtree
from repro.core.engine import SMTreeEngine
from repro.core.metric import pairwise
from repro.core.smtree import (OP_DELETE, OP_INSERT, ST_APPLIED, ST_NOTFOUND,
                               bulk_build, empty_tree, packed_free_list)
from repro.data.datagen import clustered, uniform
from repro.stream import StreamingEngine, StreamingForest
from repro.stream.batcher import MutationBatcher

DIM = 5


def _trees_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _live_oids(tree):
    mask = (np.asarray(tree.valid) & np.asarray(tree.is_leaf)[:, None]
            & np.asarray(tree.alive)[:, None])
    return sorted(int(o) for o in np.asarray(tree.oid)[mask])


def _random_stream(rng, live, vec, nid, n, del_frac=0.4):
    """Mixed log over the mutable live-set bookkeeping (log order applies
    insert-then-delete of the same id correctly)."""
    ops, xs, oids = [], [], []
    for _ in range(n):
        if live and rng.random() < del_frac:
            v = int(sorted(live)[rng.integers(len(live))])
            live.discard(v)
            ops.append(OP_DELETE)
            oids.append(v)
            xs.append(vec[v])
        else:
            v = rng.random(DIM).astype(np.float32)
            ops.append(OP_INSERT)
            oids.append(nid)
            xs.append(v)
            vec[nid] = v
            live.add(nid)
            nid += 1
    return (np.array(ops, np.int32), np.stack(xs).astype(np.float32),
            np.array(oids, np.int32), nid)


# ---------------------------------------------------------------------------
# free-ring invariants
# ---------------------------------------------------------------------------
def _check_ring(tree):
    fl = np.asarray(tree.free_list)
    fh = int(tree.free_head)
    want = np.nonzero(~np.asarray(tree.alive))[0][::-1]
    assert fh == len(want)
    np.testing.assert_array_equal(fl[:fh], want)
    assert (fl[fh:] == -1).all()


def test_free_ring_empty_and_bulk():
    _check_ring(empty_tree(dim=DIM, capacity=8, max_nodes=64))
    _check_ring(bulk_build(uniform(300, dims=DIM, seed=1), capacity=8))


def test_free_ring_after_host_edits():
    """Host merges free nodes; to_tree must repack the ring (descending,
    -1 beyond) so subsequent device pops keep matching host allocs."""
    X = uniform(250, dims=DIM, seed=2)
    eng = SMTreeEngine.build(X, capacity=8)
    for i in range(200):
        assert eng.delete(X[i], i)
    assert eng.tree.n_free_nodes > 0
    _check_ring(eng.tree)
    # refill through splits (device + host) and re-check
    b = MutationBatcher(eng.tree)
    fresh = uniform(200, dims=DIM, seed=3)
    r = b.apply(np.full(200, OP_INSERT, np.int32), fresh,
                np.arange(1000, 1200, dtype=np.int32))
    assert (r.statuses == ST_APPLIED).all()
    _check_ring(b.tree)
    SMTreeEngine(b.tree).validate()


def test_device_split_pops_lowest_free_id():
    """The ring is descending, so the device allocates the same node id the
    host's lowest-free-index alloc would — pinned here directly."""
    X = clustered(300, dims=DIM, seed=4)
    tree = bulk_build(X, capacity=8, fill_frac=0.95)
    lowest_free = int(np.nonzero(~np.asarray(tree.alive))[0][0])
    assert int(tree.free_list[tree.free_head - 1]) == lowest_free


# ---------------------------------------------------------------------------
# device split == host split, bitwise
# ---------------------------------------------------------------------------
def test_single_overflow_insert_bitwise():
    """Single inserts aimed at full leaves: batcher (device split) vs
    SMTreeEngine.insert (host split) must agree bitwise op-for-op, and at
    least one op must resolve as a device split."""
    X = clustered(300, dims=DIM, seed=5)
    tree = bulk_build(X, capacity=8, fill_frac=0.95)
    near_full = np.nonzero((np.asarray(tree.count) >= 7)
                           & np.asarray(tree.is_leaf)
                           & np.asarray(tree.alive))[0]
    assert len(near_full), "build produced no near-full leaf"
    b = MutationBatcher(tree)
    eng = SMTreeEngine(tree)
    n_split = 0
    oid = 9000
    for leaf in near_full[:4]:
        for j in range(3):   # fill the leaf, then overflow it
            x = np.asarray(tree.vecs)[leaf, 0] + 1e-4 * (j + 1)
            r = b.apply(np.array([OP_INSERT], np.int32), x[None],
                        np.array([oid], np.int32))
            assert (r.statuses == ST_APPLIED).all()
            n_split += r.n_split
            eng.insert(x, oid)
            _trees_equal(b.tree, eng.tree, "device split != host split")
            oid += 1
    assert n_split > 0, "no insert resolved as a device split"
    SMTreeEngine(b.tree).validate()


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_interleaved_stream_device_splits_bitwise_transparent(seed):
    """Property: a mixed insert/delete stream (near-capacity tree, heavy
    split pressure) applied with device splits on == device splits off,
    bitwise, with the live set exactly matching the log semantics."""
    rng = np.random.default_rng(seed)
    X = clustered(350, dims=DIM, seed=seed % 97)
    tree = bulk_build(X, capacity=8, fill_frac=0.95, seed=seed % 13)
    bd = MutationBatcher(tree, device_splits=True)
    bh = MutationBatcher(tree, device_splits=False)
    live = set(range(350))
    vec = {i: X[i] for i in range(350)}
    nid = 1000
    n_split = 0
    for _ in range(3):
        ops, xs, oids, nid = _random_stream(rng, live, vec, nid, 48)
        rd = bd.apply(ops, xs, oids)
        rh = bh.apply(ops, xs, oids)
        np.testing.assert_array_equal(rd.statuses, rh.statuses)
        n_split += rd.n_split
        _trees_equal(bd.tree, bh.tree, f"seed {seed}")
    assert _live_oids(bd.tree) == sorted(live)
    SMTreeEngine(bd.tree).validate()
    # the workload is near-capacity: the device pass must actually fire
    assert n_split > 0


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_forest_mesh_matches_host_and_reference(seed):
    """Property: the mesh-resident StreamingForest (collective apply +
    device-split collective under shard_map) stays bitwise-equal to the
    host-centric batcher path, and both match brute force over the live
    set — exact queries, correct semantics vs the one-at-a-time log."""
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    if mesh.shape["model"] != 1:
        pytest.skip("main-process test assumes a single host device")
    rng = np.random.default_rng(seed)
    X = clustered(260, dims=DIM, seed=seed % 89)
    sf_mesh = StreamingForest(
        [bulk_build(X, capacity=8, fill_frac=0.9, seed=1)], mesh=mesh)
    sf_host = StreamingForest(
        [bulk_build(X, capacity=8, fill_frac=0.9, seed=1)])
    live = set(range(260))
    vec = {i: X[i] for i in range(260)}
    nid = 5000
    for _ in range(3):
        ops, xs, oids, nid = _random_stream(rng, live, vec, nid, 40)
        rm = sf_mesh.apply(ops, xs, oids)
        rh = sf_host.apply(ops, xs, oids)
        np.testing.assert_array_equal(rm.statuses, rh.statuses)
        assert (rm.statuses == ST_APPLIED).all()
        for a, b in zip(sf_mesh.trees, sf_host.trees):
            _trees_equal(a, b, f"seed {seed}")
    assert sf_mesh.owner == sf_host.owner
    for t in sf_mesh.trees:
        SMTreeEngine(t).validate()
    assert sorted(sf_mesh.owner) == sorted(live)
    # exact retrieval over the final live set
    lv = np.stack([vec[o] for o in sorted(live)])
    Q = lv[rng.integers(0, len(lv), 8)] + 0.002
    d, _ = sf_mesh.knn(Q, k=3, max_frontier=512)
    want = np.sort(pairwise("d_inf", Q, lv), axis=1)[:, :3]
    np.testing.assert_allclose(d, want, atol=1e-5)


# ---------------------------------------------------------------------------
# negative oids + pad-row sentinel hardening
# ---------------------------------------------------------------------------
def test_negative_oid_rejected_at_boundaries(tmp_path):
    X = uniform(100, dims=DIM, seed=6)
    tree = bulk_build(X, capacity=8)
    xs = np.zeros((1, DIM), np.float32)
    bad = np.array([-3], np.int32)
    with pytest.raises(ValueError, match="negative"):
        MutationBatcher(tree).apply(np.array([OP_INSERT], np.int32), xs, bad)
    eng = StreamingEngine(tree)
    with pytest.raises(ValueError, match="negative"):
        eng.insert_batch(xs, bad)
    sf = StreamingForest([tree])
    with pytest.raises(ValueError, match="negative"):
        sf.delete_batch(xs, bad)
    # a rejected batch must not have been WAL-framed
    from repro.stream import WriteAheadLog, iter_wal
    wal = WriteAheadLog(str(tmp_path / "wal"))
    eng2 = StreamingEngine(tree, wal=wal)
    with pytest.raises(ValueError, match="negative"):
        eng2.insert_batch(xs, bad)
    wal.close()
    assert list(iter_wal(str(tmp_path / "wal"))) == []


def test_forest_apply_mutations_validate_flag():
    from repro.core.distributed import forest_apply_mutations, stack_trees
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    if mesh.shape["model"] != 1:
        pytest.skip("main-process test assumes a single host device")
    X = uniform(120, dims=DIM, seed=7)
    forest = stack_trees([bulk_build(X, capacity=8)])
    xs = np.zeros((2, DIM), np.float32)
    owner = np.zeros(2, np.int32)
    dup = np.array([5, 5], np.int32)
    neg = np.array([3, -1], np.int32)
    ops = np.full(2, OP_DELETE, np.int32)
    with pytest.raises(ValueError, match="unique"):
        forest_apply_mutations(forest, mesh, ops, xs, dup, owner,
                               validate=True)
    with pytest.raises(ValueError, match="negative"):
        forest_apply_mutations(forest, mesh, ops, xs, neg, owner,
                               validate=True)
    # default: no validation, duplicate-free batch applies fine
    out, st = forest_apply_mutations(forest, mesh, ops, xs,
                                     np.array([5, 6], np.int32), owner)
    assert (np.asarray(st) == ST_APPLIED).all()


def test_pad_rows_cannot_touch_sentinel_colliding_entry():
    """Plant an oid == -1 entry (bypassing the boundary check, as a
    corrupted upstream could) and verify NOP pad rows — which carry the -1
    sentinel — never locate, delete, or swap it."""
    X = uniform(90, dims=DIM, seed=8)
    tree = bulk_build(X, capacity=8)
    leaf = int(np.nonzero(np.asarray(tree.is_leaf)
                          & np.asarray(tree.alive))[0][0])
    tree = dataclasses.replace(tree, oid=tree.oid.at[leaf, 0].set(-1))
    n_before = tree.n_objects
    b = MutationBatcher(tree)
    # 3 rows pad to a 4-bucket: one pad row with oid -1 rides along
    ops = np.full(3, OP_INSERT, np.int32)
    r = b.apply(ops, uniform(3, dims=DIM, seed=9),
                np.array([500, 501, 502], np.int32))
    assert (r.statuses == ST_APPLIED).all()
    assert b.tree.n_objects == n_before + 3
    assert int(np.asarray(b.tree.oid)[leaf, 0]) == -1, \
        "pad row clobbered the sentinel-colliding entry"
    # an explicit delete of -1 through the jitted path reports NOTFOUND
    t2, st = smtree.apply_mutations(b.tree, np.array([OP_DELETE], np.int32),
                                    np.zeros((1, DIM), np.float32),
                                    np.array([-1], np.int32))
    assert int(np.asarray(st)[0]) == ST_NOTFOUND
    assert int(np.asarray(t2.oid)[leaf, 0]) == -1


def test_delete_fast_ignores_negative_ids():
    from repro.core.smtree import delete_fast
    X = uniform(80, dims=DIM, seed=10)
    tree = bulk_build(X, capacity=8)
    leaf = int(np.nonzero(np.asarray(tree.is_leaf)
                          & np.asarray(tree.alive))[0][0])
    tree = dataclasses.replace(tree, oid=tree.oid.at[leaf, 0].set(-1))
    _, found, _, _ = delete_fast(tree, np.zeros(DIM, np.float32),
                                 np.int32(-1))
    assert not bool(found)


# ---------------------------------------------------------------------------
# epoch read-path pinning
# ---------------------------------------------------------------------------
def test_reading_context_manager_pins_and_releases():
    from repro.stream import EpochManager
    mgr = EpochManager("v0")
    with mgr.reading() as t:
        assert t == "v0"
        mgr.publish("v1")
        mgr.publish("v2")
        # the pinned version survives both publishes
        assert 0 in mgr.resident
    # released on exit: superseded version retired
    assert mgr.resident == [2]
    with pytest.raises(RuntimeError):
        with mgr.reading():
            raise RuntimeError("reader crashed")
    assert mgr.resident == [2]   # pin released despite the exception


def test_packed_free_list_helper():
    alive = np.array([True, False, True, False, False])
    fl, fh = packed_free_list(alive)
    assert fh == 3
    np.testing.assert_array_equal(fl, [4, 3, 1, -1, -1])
