"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train-grad step + one decode step on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.all_archs import smoke_config
from repro.models import model as M

ARCHS = list_archs()


def make_batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.is_encdec:
        return {
            "frames": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                  jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 16)),
                                  jnp.int32),
        }
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    s_total = s
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        s_total = s + cfg.n_image_tokens
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_total)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_shapes(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    b = batch["tokens"].shape[0]
    s_out = batch["labels"].shape[1] if not cfg.is_encdec else batch["tokens"].shape[1]
    assert logits.shape == (b, s_out, cfg.vocab_size), logits.shape
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_grad_step(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg)

    def loss(p):
        logits, aux = M.forward(p, cfg, batch, remat=True)
        mask = jnp.ones_like(batch["labels"], jnp.float32)
        return M.loss_fn(logits, batch["labels"], mask) + 0.01 * aux["lb_loss"]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), f"{arch}: loss {val}"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, L = 2, 64
    cache = M.init_cache(cfg, b, L)
    if cfg.is_encdec:
        from repro.models.encdec import encdec_prefill_cache
        frames = jnp.asarray(np.random.default_rng(3).normal(
            size=(b, L, cfg.d_model)), jnp.float32)
        cache = encdec_prefill_cache(params, cfg, frames, cache)
    tok = jnp.array([1, 2], jnp.int32)
    for pos in range(3):
        logits, cache = jax.jit(M.decode_step, static_argnums=1)(
            params, cfg, tok, cache, jnp.int32(pos))
        assert logits.shape == (b, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: step {pos}"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "xlstm-1.3b", "jamba-v0.1-52b"])
def test_decode_matches_forward(arch):
    """Cached decode must reproduce the full-sequence forward logits."""
    import dataclasses
    cfg = smoke_config(arch)
    if cfg.n_experts:   # dropless on both paths for exact equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    b, s = 2, 12
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, b, s + 1)
    outs = []
    for pos in range(s):
        lg, cache = M.decode_step(params, cfg, toks[:, pos], cache,
                                  jnp.int32(pos))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_exact_param_counts_in_range():
    """eval_shape param counts must be within 15% of the analytic estimate
    used for MODEL_FLOPS (and grok must be ~314B)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        exact = M.exact_param_count(cfg)
        approx = cfg.param_count
        assert abs(exact - approx) / exact < 0.15, \
            f"{arch}: exact {exact/1e9:.2f}B vs analytic {approx/1e9:.2f}B"
