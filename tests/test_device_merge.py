"""PR-5 device merge pass + free-ring push + headroom growth tests.

The contract under test mirrors tests/test_device_split.py for the Delete
side: underflow deletes resolved on device (``smtree.apply_merges`` / the
``forest_apply_merges`` collective) are **bitwise-transparent** — applying
a mutation log with device merges on yields exactly the tree the host
escalation path produces, because the device pass replays
``_HostView.delete_with_merge`` decision-for-decision (same first-hit
relocation, same nearest-sibling tie-breaks, same merge-vs-redistribute
choice with minmax_split's member order, same root collapse) and pushes
freed node ids back onto the packed free ring at their *sorted* position,
so interleaved pops keep matching host allocs.

Also covered: ring push/pop interleavings, pad-sentinel inertness in merge
chunks, and ahead-of-time free-ring headroom growth (``grow_tree`` +
``StreamingEngine``/``StreamingForest`` watermarks).
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import smtree
from repro.core.engine import SMTreeEngine
from repro.core.smtree import (MAX_HEIGHT, OP_DELETE, OP_INSERT, ST_APPLIED,
                               ST_NOP, ST_NOTFOUND, bulk_build, grow_tree,
                               needs_headroom, packed_free_list)
from repro.data.datagen import clustered, uniform
from repro.stream import StreamingEngine, StreamingForest
from repro.stream.batcher import MutationBatcher

DIM = 5


def _trees_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _check_ring(tree):
    fl = np.asarray(tree.free_list)
    fh = int(tree.free_head)
    want = np.nonzero(~np.asarray(tree.alive))[0][::-1]
    assert fh == len(want)
    np.testing.assert_array_equal(fl[:fh], want)
    assert (fl[fh:] == -1).all()


def _random_stream(rng, live, vec, nid, n, del_frac=0.6):
    ops, xs, oids = [], [], []
    for _ in range(n):
        if live and rng.random() < del_frac:
            v = int(sorted(live)[rng.integers(len(live))])
            live.discard(v)
            ops.append(OP_DELETE)
            oids.append(v)
            xs.append(vec[v])
        else:
            v = rng.random(DIM).astype(np.float32)
            ops.append(OP_INSERT)
            oids.append(nid)
            xs.append(v)
            vec[nid] = v
            live.add(nid)
            nid += 1
    return (np.array(ops, np.int32), np.stack(xs).astype(np.float32),
            np.array(oids, np.int32), nid)


# ---------------------------------------------------------------------------
# free-ring push invariant
# ---------------------------------------------------------------------------
def test_device_merge_repacks_ring_sorted():
    """Device merges free nodes; the ring must stay equal to the host's
    wholesale recompute (descending ids, -1 beyond) — a LIFO push would
    diverge the moment a lower id sits buried below the top."""
    X = uniform(300, dims=DIM, seed=1)
    tree = bulk_build(X, capacity=8)
    b = MutationBatcher(tree)
    r = b.apply(np.full(220, OP_DELETE, np.int32), X[:220],
                np.arange(220, dtype=np.int32))
    assert (r.statuses == ST_APPLIED).all()
    assert r.n_merge > 0, "workload never exercised a device merge"
    assert r.n_escalated == 0, "device merges must absorb every underflow"
    _check_ring(b.tree)
    SMTreeEngine(b.tree).validate()


def test_ring_push_pop_interleaving_matches_host():
    """Alternating delete-heavy and insert-heavy batches: device merges
    push freed ids, device splits pop them back — allocation choices must
    keep matching the host control plane bitwise throughout."""
    rng = np.random.default_rng(7)
    X = clustered(300, dims=DIM, seed=7)
    tree = bulk_build(X, capacity=8, fill_frac=0.9)
    bd = MutationBatcher(tree)                       # device splits+merges
    bh = MutationBatcher(tree, device_splits=False,
                         device_merges=False)        # all-host reference
    live = set(range(300))
    vec = {i: X[i] for i in range(300)}
    nid = 1000
    n_merge = n_split = 0
    for phase in range(4):
        frac = 0.85 if phase % 2 == 0 else 0.15
        ops, xs, oids, nid = _random_stream(rng, live, vec, nid, 64,
                                            del_frac=frac)
        rd = bd.apply(ops, xs, oids)
        rh = bh.apply(ops, xs, oids)
        np.testing.assert_array_equal(rd.statuses, rh.statuses)
        n_merge += rd.n_merge
        n_split += rd.n_split
        _trees_equal(bd.tree, bh.tree, f"phase {phase}")
        _check_ring(bd.tree)
    assert n_merge > 0 and n_split > 0, (n_merge, n_split)
    SMTreeEngine(bd.tree).validate()


# ---------------------------------------------------------------------------
# device merge == host merge, bitwise
# ---------------------------------------------------------------------------
def test_single_underflow_delete_bitwise():
    """Single deletes aimed at min-fill leaves: batcher (device merge) vs
    SMTreeEngine.delete (host merge) must agree bitwise op-for-op, and at
    least one op must resolve as a device merge."""
    X = uniform(280, dims=DIM, seed=2)
    tree = bulk_build(X, capacity=8)
    b = MutationBatcher(tree)
    eng = SMTreeEngine(tree)
    n_merge = 0
    for i in range(140):
        r = b.apply(np.array([OP_DELETE], np.int32), X[i][None],
                    np.array([i], np.int32))
        assert (r.statuses == ST_APPLIED).all()
        n_merge += r.n_merge
        assert eng.delete(X[i], i)
        _trees_equal(b.tree, eng.tree, f"device merge != host merge at {i}")
    assert n_merge > 0, "no delete resolved as a device merge"
    SMTreeEngine(b.tree).validate()


def test_redistribute_branch_bitwise():
    """Force the re-split (total > capacity) branch: a near-capacity build
    makes the nearest sibling too full to merge into, so underflow must
    redistribute — and stay bitwise-equal to the host's minmax re-split."""
    X = clustered(300, dims=DIM, seed=3)
    tree = bulk_build(X, capacity=8, fill_frac=0.95)
    bd = MutationBatcher(tree)
    bh = MutationBatcher(tree, device_merges=False)
    order = np.random.default_rng(3).permutation(300)
    n_merge = 0
    for c in range(0, 160, 16):
        idx = order[c:c + 16].astype(np.int32)
        rd = bd.apply(np.full(16, OP_DELETE, np.int32), X[idx], idx)
        rh = bh.apply(np.full(16, OP_DELETE, np.int32), X[idx], idx)
        np.testing.assert_array_equal(rd.statuses, rh.statuses)
        n_merge += rd.n_merge
        _trees_equal(bd.tree, bh.tree, f"chunk at {c}")
    assert n_merge > 0
    SMTreeEngine(bd.tree).validate()


def test_cascade_to_root_collapse_and_singleton_root():
    """Delete down to a handful of objects: multi-level underflow cascades,
    merge-into-singleton-root and repeated on-device root collapse (height
    shrinks) — bitwise vs the engine's host path the whole way down."""
    X = uniform(260, dims=DIM, seed=4)
    tree = bulk_build(X, capacity=8)
    assert int(tree.height) >= 3, "need a deep tree for cascades"
    b = MutationBatcher(tree)
    eng = SMTreeEngine(tree)
    for i in range(254):
        r = b.apply(np.array([OP_DELETE], np.int32), X[i][None],
                    np.array([i], np.int32))
        assert (r.statuses == ST_APPLIED).all()
        assert eng.delete(X[i], i)
        _trees_equal(b.tree, eng.tree, f"delete {i}")
    assert int(b.tree.height) == 1, "root should have collapsed to a leaf"
    assert b.tree.n_objects == 6
    _check_ring(b.tree)
    SMTreeEngine(b.tree).validate()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_interleaved_stream_device_merges_bitwise_transparent(seed):
    """Property: a delete-heavy mixed stream applied with device merges on
    == device merges off (host escalation), bitwise, with the live set
    exactly matching the log semantics."""
    rng = np.random.default_rng(seed)
    X = clustered(320, dims=DIM, seed=seed % 97)
    tree = bulk_build(X, capacity=8, seed=seed % 13)
    bd = MutationBatcher(tree, device_merges=True)
    bh = MutationBatcher(tree, device_merges=False)
    live = set(range(320))
    vec = {i: X[i] for i in range(320)}
    nid = 1000
    n_merge = 0
    for _ in range(3):
        ops, xs, oids, nid = _random_stream(rng, live, vec, nid, 48)
        rd = bd.apply(ops, xs, oids)
        rh = bh.apply(ops, xs, oids)
        np.testing.assert_array_equal(rd.statuses, rh.statuses)
        n_merge += rd.n_merge
        _trees_equal(bd.tree, bh.tree, f"seed {seed}")
    live_oids = sorted(
        int(o) for o in np.asarray(bd.tree.oid)[
            np.asarray(bd.tree.valid)
            & np.asarray(bd.tree.is_leaf)[:, None]
            & np.asarray(bd.tree.alive)[:, None]])
    assert live_oids == sorted(live)
    SMTreeEngine(bd.tree).validate()
    assert n_merge > 0, "delete-heavy workload never exercised the pass"


# ---------------------------------------------------------------------------
# pad-sentinel rows in merge chunks
# ---------------------------------------------------------------------------
def test_merge_chunk_pad_rows_inert():
    """Merge chunks pad with OP_NOP / oid -1; a planted sentinel-colliding
    entry must never be located, removed, or merged by a pad row."""
    X = uniform(200, dims=DIM, seed=5)
    tree = bulk_build(X, capacity=8)
    leaf = int(np.nonzero(np.asarray(tree.is_leaf)
                          & np.asarray(tree.alive))[0][0])
    lost = int(np.asarray(tree.oid)[leaf, 0])   # overwritten below
    tree = dataclasses.replace(tree, oid=tree.oid.at[leaf, 0].set(-1))
    planted_vec = np.asarray(tree.vecs)[leaf, 0].copy()
    # underflow deletes -> MERGE_CHUNK dispatches whose tails are pads
    b = MutationBatcher(tree)
    n_merge = 0
    for i in range(120):
        if i == lost:
            continue
        r = b.apply(np.array([OP_DELETE], np.int32),
                    X[i][None].astype(np.float32),
                    np.array([i], np.int32))
        assert (r.statuses == ST_APPLIED).all()
        n_merge += r.n_merge
    assert n_merge > 0, "no merge chunk (with pad rows) ever dispatched"
    # the planted entry survives wherever merges moved it (internal
    # entries carry oid -1 by design; only leaf rows can hold the plant)
    mask = ((np.asarray(b.tree.oid) == -1) & np.asarray(b.tree.valid)
            & np.asarray(b.tree.is_leaf)[:, None]
            & np.asarray(b.tree.alive)[:, None])
    assert mask.sum() == 1, "pad rows touched the sentinel-colliding entry"
    where = np.argwhere(mask)[0]
    np.testing.assert_array_equal(
        np.asarray(b.tree.vecs)[where[0], where[1]], planted_vec)
    # direct pad-shaped rows through apply_merges are pure NOPs
    t2, st = smtree.apply_merges(
        b.tree, np.full(smtree.MERGE_CHUNK, smtree.OP_NOP, np.int32),
        np.full(smtree.MERGE_CHUNK, -1, np.int32), donate=False)
    assert (np.asarray(st) == ST_NOP).all()
    _trees_equal(b.tree, t2, "NOP merge chunk mutated the tree")
    # an explicit OP_DELETE of oid -1 reports NOTFOUND, tree untouched
    t3, st3 = smtree.apply_merges(
        b.tree, np.array([OP_DELETE], np.int32),
        np.array([-1], np.int32), donate=False)
    assert int(np.asarray(st3)[0]) == ST_NOTFOUND
    _trees_equal(b.tree, t3, "oid -1 merge row mutated the tree")


# ---------------------------------------------------------------------------
# mesh collective parity (single-device main process; 8-shard drill lives
# in tests/_dist_worker.py::scenario_forest_device_merges)
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_forest_mesh_merges_match_host(seed):
    """Property: the mesh-resident StreamingForest (apply + split + merge
    collectives under shard_map) stays bitwise-equal to the host-centric
    batcher path on delete-heavy streams."""
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    if mesh.shape["model"] != 1:
        pytest.skip("main-process test assumes a single host device")
    rng = np.random.default_rng(seed)
    X = clustered(260, dims=DIM, seed=seed % 89)
    sf_mesh = StreamingForest(
        [bulk_build(X, capacity=8, fill_frac=0.9, seed=1)], mesh=mesh)
    sf_host = StreamingForest(
        [bulk_build(X, capacity=8, fill_frac=0.9, seed=1)])
    live = set(range(260))
    vec = {i: X[i] for i in range(260)}
    nid = 5000
    n_merge = 0
    for _ in range(3):
        ops, xs, oids, nid = _random_stream(rng, live, vec, nid, 40)
        rm = sf_mesh.apply(ops, xs, oids)
        rh = sf_host.apply(ops, xs, oids)
        np.testing.assert_array_equal(rm.statuses, rh.statuses)
        assert (rm.statuses == ST_APPLIED).all()
        assert rm.n_merge == rh.n_merge
        n_merge += rm.n_merge
        for a, b in zip(sf_mesh.trees, sf_host.trees):
            _trees_equal(a, b, f"seed {seed}")
    assert sf_mesh.owner == sf_host.owner
    for t in sf_mesh.trees:
        SMTreeEngine(t).validate()


# ---------------------------------------------------------------------------
# ahead-of-time headroom growth
# ---------------------------------------------------------------------------
def test_grow_tree_ring_and_transparency():
    X = clustered(200, dims=DIM, seed=6)
    t0 = bulk_build(X, capacity=8)
    tg = grow_tree(t0)
    assert tg.max_nodes == 2 * t0.max_nodes
    _check_ring(tg)
    # new rows are dead, detached and leaf-typed (the host _grow layout)
    N = t0.max_nodes
    assert not np.asarray(tg.alive)[N:].any()
    assert (np.asarray(tg.parent)[N:] == -1).all()
    assert (np.asarray(tg.child)[N:] == -1).all()
    assert np.asarray(tg.is_leaf)[N:].all()
    # growth is behaviour-transparent: the same mutation stream lands
    # identically on the original (where it fits) and the grown tree
    bg = MutationBatcher(tg)
    bo = MutationBatcher(t0)
    ops = np.full(64, OP_INSERT, np.int32)
    xs = uniform(64, dims=DIM, seed=7)
    oids = np.arange(5000, 5064, dtype=np.int32)
    rg = bg.apply(ops, xs, oids)
    ro = bo.apply(ops, xs, oids)
    np.testing.assert_array_equal(rg.statuses, ro.statuses)
    for f in ("root", "height", "count", "oid", "valid"):
        a = np.asarray(getattr(bg.tree, f))
        b = np.asarray(getattr(bo.tree, f))
        np.testing.assert_array_equal(a[:N] if a.ndim else a,
                                      b[:N] if b.ndim else b, err_msg=f)
    SMTreeEngine(bg.tree).validate()


def test_streaming_engine_headroom_growth_preempts_exhaustion():
    """A tiny node table under sustained inserts: the watermark fires at a
    publish point, the table doubles, and no host escalation for ring
    exhaustion ever happens mid-batch."""
    X = clustered(120, dims=DIM, seed=8)
    tree = bulk_build(X, capacity=8, slack=1.1)
    eng = StreamingEngine(tree)
    n0 = eng.tree.max_nodes
    fresh = uniform(640, dims=DIM, seed=9)
    for c in range(0, 640, 64):
        r = eng.insert_batch(fresh[c:c + 64],
                             np.arange(1000 + c, 1064 + c, dtype=np.int32))
        assert (r.statuses == ST_APPLIED).all()
    assert eng.n_grows >= 1, "watermark never fired"
    assert eng.tree.max_nodes > n0
    assert not needs_headroom(eng.tree)
    assert eng.tree.n_objects == 120 + 640
    _check_ring(eng.tree)
    SMTreeEngine(eng.tree).validate()


def test_headroom_watermark_floor():
    # the floor (MAX_HEIGHT + 1, the worst case one overflow row can
    # allocate) applies even at frac=0: a 16-row table can never hold it
    t = bulk_build(uniform(60, dims=DIM, seed=10), capacity=8, slack=1.05)
    assert t.max_nodes - int(t.free_head) >= 0
    assert int(t.free_head) < MAX_HEIGHT + 1 <= t.max_nodes + 1
    assert needs_headroom(t, frac=0.0)


def test_streaming_forest_growth_bitwise_across_modes(tmp_path):
    """Host-mode and mesh-mode StreamingForests grow at identical points
    (same watermark reads), so they stay bitwise-interchangeable; WAL
    replay after a snapshot reproduces the grown geometry exactly."""
    from repro.dist.checkpoint import CheckpointManager
    from repro.stream import WriteAheadLog
    mesh = jax.make_mesh((jax.device_count(),), ("model",))
    if mesh.shape["model"] != 1:
        pytest.skip("main-process test assumes a single host device")
    X = clustered(100, dims=DIM, seed=11)

    def build():
        return [bulk_build(X, capacity=8, slack=1.1)]

    sf_mesh = StreamingForest(build(), mesh=mesh)
    sf_host = StreamingForest(build())
    wal = WriteAheadLog(str(tmp_path / "wal"))
    ckpt = CheckpointManager(str(tmp_path / "ckpt"), async_write=False)
    sf_wal = StreamingForest(build(), wal=wal, ckpt=ckpt)
    fresh = uniform(512, dims=DIM, seed=12)
    for c in range(0, 512, 64):
        oids = np.arange(2000 + c, 2064 + c, dtype=np.int32)
        rm = sf_mesh.insert_batch(fresh[c:c + 64], oids)
        rh = sf_host.insert_batch(fresh[c:c + 64], oids)
        sf_wal.insert_batch(fresh[c:c + 64], oids)
        np.testing.assert_array_equal(rm.statuses, rh.statuses)
    assert sf_mesh.n_grows == sf_host.n_grows >= 1
    for a, b in zip(sf_mesh.trees, sf_host.trees):
        _trees_equal(a, b, "growth diverged across control-plane modes")
    sf_wal.snapshot()
    restored = StreamingForest.restore(str(tmp_path / "ckpt"), wal=wal)
    for a, b in zip(sf_wal.trees, restored.trees):
        _trees_equal(a, b, "snapshot restore lost grown geometry")


def test_packed_free_list_roundtrip_after_push():
    """_push_free inserts at the sorted position (property, pure jit)."""
    alive = np.ones(32, bool)
    dead = [3, 7, 19, 28]
    for d in dead:
        alive[d] = False
    fl, fh = packed_free_list(alive)
    t = smtree.empty_tree(dim=2, capacity=4, max_nodes=32)
    t = dataclasses.replace(
        t, free_list=jax.numpy.asarray(fl), free_head=jax.numpy.asarray(fh))
    for f in (12, 1, 30, 5):
        t = smtree._push_free(t, jax.numpy.int32(f), jax.numpy.asarray(True))
        alive[f] = False
        want_fl, want_fh = packed_free_list(alive)
        np.testing.assert_array_equal(np.asarray(t.free_list), want_fl)
        assert int(t.free_head) == want_fh
