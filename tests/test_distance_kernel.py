"""Pallas distance kernel: interpret-mode shape/dtype sweeps vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


SHAPES = [(8, 8, 4), (128, 128, 128), (100, 130, 20), (1, 257, 96), (300, 7, 160)]
METRICS = ["d_inf", "sqeuclidean", "ip"]


@pytest.mark.parametrize("nq,ne,d", SHAPES)
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_distance_matches_oracle(nq, ne, d, metric, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(nq * 1000 + ne + d))
    q = jax.random.normal(k1, (nq, d), dtype)
    e = jax.random.normal(k2, (ne, d), dtype)
    got = ops.pairwise_distance(q, e, metric=metric, impl="interpret")
    want = ref.pairwise_distance_ref(q.astype(jnp.float32),
                                     e.astype(jnp.float32), metric=metric)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nq,ne,d", [(64, 64, 32), (50, 200, 20), (9, 300, 130)])
@pytest.mark.parametrize("metric", ["d_inf", "sqeuclidean"])
def test_fused_prune_matches_oracle(nq, ne, d, metric):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.uniform(k1, (nq, d))
    e = jax.random.uniform(k2, (ne, d))
    r_q = jax.random.uniform(k3, (nq,), maxval=0.6)
    r_e = jax.random.uniform(k4, (ne,), maxval=0.6)
    got_d, got_m = ops.pairwise_distance_prune(q, e, r_q, r_e, metric=metric,
                                               impl="interpret")
    want_d, want_m = ops.pairwise_distance_prune(q, e, r_q, r_e, metric=metric,
                                                 impl="xla")
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-5)
    # mask can differ only where the prune test is within float tolerance of
    # equality; require exact match away from the boundary
    true_d = np.sqrt(np.maximum(np.asarray(want_d), 0)) if metric == "sqeuclidean" \
        else np.asarray(want_d)
    margin = np.abs(true_d - (np.asarray(r_q)[:, None] + np.asarray(r_e)[None, :]))
    decided = margin > 1e-5
    np.testing.assert_array_equal(np.asarray(got_m)[decided],
                                  np.asarray(want_m)[decided])


def test_distance_agrees_with_core_metric():
    """Kernel oracle must agree with the numpy metric used by the ref trees."""
    from repro.core.metric import pairwise
    rng = np.random.default_rng(0)
    X = rng.random((40, 20)).astype(np.float32)
    Y = rng.random((30, 20)).astype(np.float32)
    want = pairwise("d_inf", X, Y)
    got = ref.pairwise_distance_ref(jnp.asarray(X), jnp.asarray(Y), metric="d_inf")
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
